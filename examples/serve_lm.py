"""Batched serving example: prefill + autoregressive decode with per-layer
KV/SSM caches, across model families (dense / MoE / SSM / hybrid).

Run: PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-370m]
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    raise SystemExit(serve_mod.main(
        ["--arch", args.arch, "--smoke", "--batch", str(args.batch),
         "--prompt-len", str(args.prompt_len), "--gen", str(args.gen)]))


if __name__ == "__main__":
    main()
