"""Batched serving example: prefill + autoregressive decode with per-layer
KV/SSM caches, across model families (dense / MoE / SSM / hybrid).

Single-stream by default; pass ``--server`` to drive the decode-step region
through the multi-tenant ``repro.serving.RegionServer`` instead — N tenants
with private caches and a shared parameter set, whose structurally identical
per-token decode requests coalesce into one batched fused replay (queue /
batch-occupancy / latency metrics are printed at the end).

Run: PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-370m]
     PYTHONPATH=src python examples/serve_lm.py --server --tenants 4
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--server", action="store_true",
                    help="multi-tenant RegionServer decode (repro.serving)")
    ap.add_argument("--tenants", type=int, default=4)
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--smoke", "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len), "--gen", str(args.gen)]
    if args.server:
        argv += ["--server", "--tenants", str(args.tenants)]
    raise SystemExit(serve_mod.main(argv))


if __name__ == "__main__":
    main()
