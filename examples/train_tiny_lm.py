"""End-to-end training example: a ~100M-param dense LM through the full
stack (data pipeline -> model -> AdamW+cosine -> record/replay step ->
async checkpoints -> fault-tolerant supervisor).

Default runs a CPU-sized slice (~5M params, 60 steps, loss must fall).
``--full`` trains the ~100M config for --steps steps (the production run;
use on real hardware, or let it run long on CPU).

Run: PYTHONPATH=src python examples/train_tiny_lm.py [--full --steps 300]
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params (d=768, L=12, vocab=32k)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    if args.full:
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ModelConfig
        cfg = ModelConfig(
            name="tiny-lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32000,
            scan_layers=False, remat="none", dtype="float32")
        # route through the launch driver with an inline registry entry
        from repro import configs as C
        C._ARCH_MODULES = dict(C._ARCH_MODULES)
        import types, sys
        mod = types.ModuleType("repro.configs._tiny100m")
        mod.CONFIG = cfg
        sys.modules["repro.configs._tiny100m"] = mod
        C._ARCH_MODULES["tiny-lm-100m"] = "_tiny100m"
        C.ARCHS = tuple(C._ARCH_MODULES)
        argv = ["--arch", "tiny-lm-100m", "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq)]
        # argparse choices were captured at import; patch through smoke path
        raise SystemExit(train_mod.main(argv))
    argv = ["--arch", "qwen2.5-3b", "--smoke", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq)]
    raise SystemExit(train_mod.main(argv))


if __name__ == "__main__":
    main()
