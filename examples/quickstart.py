"""Quickstart: the Taskgraph framework on blocked Cholesky factorization.

Blocked Cholesky is the canonical task-dependency-graph workload (and one of
the paper's benchmarks): POTRF/TRSM/SYRK/GEMM tasks over matrix tiles with a
dense dependency web that vanilla runtimes resolve on every execution.

This example:
  1. declares the region with ``@taskgraph`` (depend-clause style),
  2. runs it once  -> record (executes while building the TDG),
  3. runs it again -> replay (single fused executable, no orchestration),
  4. times eager (dynamic per-task dispatch) vs replay,
  5. verifies both against jnp.linalg.cholesky.

Run: PYTHONPATH=src python examples/quickstart.py [--n 512 --nb 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EagerExecutor, ReplayExecutor, taskgraph, topo_waves


def cholesky_region(nb: int):
    """Build a taskgraph region factoring an (nb x nb)-tile SPD matrix."""

    def potrf(a):
        return jnp.linalg.cholesky(a)

    def trsm(l_kk, a):                      # A @ L_kk^-T
        return jax.scipy.linalg.solve_triangular(
            l_kk, a.T, lower=True).T

    def syrk(a, l):                         # A - L L^T
        return a - l @ l.T

    def gemm(a, l1, l2):                    # A - L1 L2^T
        return a - l1 @ l2.T

    @taskgraph(name=f"cholesky_{nb}")
    def region(g, **tiles):
        for k in range(nb):
            g.task(potrf, ins=[f"A{k}{k}"], outs=[f"L{k}{k}"],
                   name=f"potrf{k}")
            for i in range(k + 1, nb):
                g.task(trsm, ins=[f"L{k}{k}", f"A{i}{k}"], outs=[f"L{i}{k}"],
                       name=f"trsm{i}{k}")
            for i in range(k + 1, nb):
                g.task(syrk, ins=[f"A{i}{i}", f"L{i}{k}"], outs=[f"A{i}{i}"],
                       name=f"syrk{i}{k}")
                for j in range(k + 1, i):
                    g.task(gemm, ins=[f"A{i}{j}", f"L{i}{k}", f"L{j}{k}"],
                           outs=[f"A{i}{j}"], name=f"gemm{i}{j}{k}")

    return region


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--nb", type=int, default=8)
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    n, nb = args.n, args.nb
    bs = n // nb

    rng = np.random.default_rng(0)
    m = rng.standard_normal((n, n))
    spd = m @ m.T + n * np.eye(n)
    tiles = {f"A{i}{j}": jnp.asarray(spd[i * bs:(i + 1) * bs,
                                         j * bs:(j + 1) * bs])
             for i in range(nb) for j in range(nb) if j <= i}

    region = cholesky_region(nb)

    # 1st call records (paper: first execution builds the TDG)
    t0 = time.perf_counter()
    out = region(**tiles)
    t_record = time.perf_counter() - t0
    print(f"record : {t_record * 1e3:8.1f} ms   {region.tdg.summary()}")
    waves = topo_waves(region.tdg)
    print(f"         {len(waves)} waves, max width "
          f"{max(len(w) for w in waves)}")

    # subsequent calls replay the fused executable
    region(**tiles)  # compile
    t0 = time.perf_counter()
    for _ in range(args.reps):
        out = region(**tiles)
    t_replay = (time.perf_counter() - t0) / args.reps

    # vanilla-style eager dynamic scheduling for comparison
    eager = EagerExecutor(region.tdg, n_workers=4)
    eager.run(dict(tiles))  # warm per-task executables
    t0 = time.perf_counter()
    for _ in range(args.reps):
        out_e = eager.run(dict(tiles))
    t_eager = (time.perf_counter() - t0) / args.reps

    print(f"eager  : {t_eager * 1e3:8.1f} ms   (per-task dispatch, "
          f"{eager.stats.queue_ops} queue ops, {eager.stats.steals} steals)")
    print(f"replay : {t_replay * 1e3:8.1f} ms   (fused executable)")
    print(f"speedup: {t_eager / t_replay:8.2f}x")

    # verify
    L = np.zeros((n, n))
    for i in range(nb):
        for j in range(i + 1):
            L[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = np.asarray(
                out[f"L{i}{j}"] if i != j else out[f"L{i}{i}"])
    ref = np.linalg.cholesky(spd)
    np.testing.assert_allclose(L, ref, atol=1e-6 * n)
    for k in out:  # eager (per-task) vs replay (fused): f32 reassociation
        np.testing.assert_allclose(out[k], out_e[k], rtol=1e-5, atol=1e-4)
    print("verified against jnp.linalg.cholesky — OK")


if __name__ == "__main__":
    main()
