"""Pipeline parallelism from the pipeline TDG.

Shows the static 1F1B schedule that the Taskgraph scheduler emits (the
pipeline schedule IS a TDG), then executes a 4-stage GPipe forward+backward
on a 4-device CPU mesh via shard_map+ppermute and verifies against the
sequential model.

Run: XLA_FLAGS=--xla_force_host_platform_device_count=4 \
     PYTHONPATH=src python examples/pipeline_demo.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (list_schedule, one_f_one_b_order, pipeline_tdg,
                        topo_waves)
from repro.core.pipeline import bubble_fraction, pipeline_apply


def main():
    S, M = 4, 8
    tdg = pipeline_tdg(S, M)
    print(tdg.summary())
    waves = topo_waves(tdg)
    print(f"waves: {len(waves)} (fwd+bwd), "
          f"GPipe bubble fraction: {bubble_fraction(S, M):.2f}")
    print("1F1B stage streams:")
    for s, stream in enumerate(one_f_one_b_order(S, M)):
        print(f"  stage{s}: " + " ".join(f"{p}{m}" for p, m in stream))
    sched = list_schedule(tdg, n_workers=S)
    print(f"list-schedule makespan {sched.makespan:.0f} "
          f"(critical path bound: {len(waves)})")

    mesh = jax.make_mesh((S,), ("stage",),
                         devices=jax.devices()[:S])
    d, mb = 32, 4
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S, d, d)) * 0.3
    xs = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))

    def stage_fn(W, x):
        return jnp.tanh(x @ W)

    out = pipeline_apply(stage_fn, Ws, xs, mesh)
    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ Ws[s])
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    g = jax.grad(lambda W: (pipeline_apply(stage_fn, W, xs, mesh) ** 2).sum())(Ws)
    g_ref = jax.grad(lambda W: (jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(
        xs @ W[0]) @ W[1]) @ W[2]) @ W[3]) ** 2).sum())(Ws)
    np.testing.assert_allclose(g, g_ref, atol=1e-4, rtol=1e-4)
    print("pipeline forward+backward == sequential: OK")


if __name__ == "__main__":
    main()
