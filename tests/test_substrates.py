"""Substrate tests: optimizer, schedules, compression, data, checkpoint,
fault tolerance, elasticity."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, load_pytree, save_pytree
from repro.data import DataConfig, MixtureDataset, SyntheticLM, pack_documents
from repro.optim import adamw, apply_updates, global_norm, warmup_cosine, wsd
from repro.optim.compress import compress_leaf, decompress_leaf, ef_step, init_error_feedback
from repro.runtime import (RunState, StragglerPolicy, elastic_restart_plan,
                           run_with_recovery)


class TestOptim:
    def test_adamw_converges_quadratic(self):
        opt = adamw(0.1, weight_decay=0.0)
        params = {"w": jnp.ones((4,)) * 5.0}
        state = opt.init(params)
        target = jnp.asarray([1.0, -2.0, 3.0, 0.0])
        for _ in range(200):
            g = jax.grad(lambda p: ((p["w"] - target) ** 2).sum())(params)
            upd, state, _ = opt.update(g, state, params)
            params = apply_updates(params, upd)
        np.testing.assert_allclose(params["w"], target, atol=1e-2)

    def test_grad_clip(self):
        opt = adamw(0.1, grad_clip=1.0)
        params = {"w": jnp.zeros((3,))}
        state = opt.init(params)
        g = {"w": jnp.full((3,), 100.0)}
        _, _, m = opt.update(g, state, params)
        assert float(m["grad_norm"]) > 100

    def test_cosine_schedule(self):
        lr = warmup_cosine(1.0, 10, 100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)

    def test_wsd_schedule(self):
        lr = wsd(1.0, 10, 50, 20)
        assert float(lr(5)) == pytest.approx(0.5)
        assert float(lr(30)) == pytest.approx(1.0)   # stable plateau
        assert float(lr(59)) == pytest.approx(1.0)
        assert float(lr(80)) == pytest.approx(0.01, rel=0.2)

    def test_compression_roundtrip_small_error(self):
        g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = compress_leaf(g)
        assert q.dtype == jnp.int8
        err = float(jnp.abs(decompress_leaf(q, s) - g).max())
        assert err <= float(s) * 0.5 + 1e-6

    def test_error_feedback_unbiased_over_steps(self):
        """EF accumulates quantization error -> mean applied grad ~ true."""
        true_g = {"w": jnp.full((64,), 0.003)}   # tiny grads: worst case
        ef = init_error_feedback(true_g)
        applied = jnp.zeros((64,))
        for _ in range(50):
            dq, ef = ef_step(true_g, ef)
            applied = applied + dq["w"]
        np.testing.assert_allclose(applied / 50, true_g["w"], rtol=0.2)


class TestData:
    def test_deterministic_addressing(self):
        cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=4)
        ds = SyntheticLM(cfg)
        b1, b2 = ds.batch(7), ds.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])

    def test_host_sharding_disjoint(self):
        k = dict(vocab_size=100, seq_len=32, global_batch=8, num_hosts=2)
        b0 = SyntheticLM(DataConfig(host_id=0, **k)).batch(0)
        b1 = SyntheticLM(DataConfig(host_id=1, **k)).batch(0)
        assert b0["tokens"].shape == (4, 32)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_packing(self):
        docs = [np.arange(2, 12), np.arange(2, 30)]
        toks, mask = pack_documents(docs, 2, 16, eos_id=1, pad_id=0)
        assert toks.shape == (2, 16)
        assert (toks == 1).sum() >= 1            # EOS present
        assert mask.shape == (2, 16)
        assert set(np.unique(mask)) <= {0.0, 1.0}

    def test_mixture_deterministic(self):
        cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2)
        mix = MixtureDataset([SyntheticLM(cfg), SyntheticLM(
            DataConfig(vocab_size=50, seq_len=16, global_batch=2, seed=9))],
            weights=[0.5, 0.5])
        np.testing.assert_array_equal(mix.batch(3)["tokens"],
                                      mix.batch(3)["tokens"])


class TestCheckpoint:
    def test_roundtrip_with_integrity(self, tmp_path):
        tree = {"a": np.arange(10, dtype=np.float32),
                "b": {"c": np.ones((3, 3), np.int32)}}
        save_pytree(tree, tmp_path, 5)
        out = load_pytree(tree, tmp_path, 5)
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert latest_step(tmp_path) == 5

    def test_torn_checkpoint_ignored(self, tmp_path):
        tree = {"a": np.zeros(3, np.float32)}
        save_pytree(tree, tmp_path, 1)
        d = pathlib.Path(tmp_path) / "step_000002"
        d.mkdir()
        (d / "host_00000.npz").write_bytes(b"garbage")  # no _COMMITTED
        assert latest_step(tmp_path) == 1

    def test_corruption_detected(self, tmp_path):
        tree = {"a": np.arange(100, dtype=np.float32)}
        d = save_pytree(tree, tmp_path, 3)
        bad = {"a": np.arange(100, dtype=np.float32) + 1}
        np.savez(d / "host_00000.npz", a=bad["a"])
        with pytest.raises(IOError):
            load_pytree(tree, tmp_path, 3)

    def test_async_checkpointer_gc(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ck.save({"x": np.full(4, s, np.float32)}, s)
        ck.wait()
        ck._gc()
        assert latest_step(tmp_path) == 4
        restored, step = ck.restore({"x": np.zeros(4, np.float32)})
        assert step == 4
        np.testing.assert_allclose(restored["x"], 4.0)


class TestFaultTolerance:
    def _step(self, state, batch):
        p = jax.tree_util.tree_map(lambda x: x + 1.0, state.params)
        return RunState(p, state.opt_state, state.step), {"loss": jnp.ones(())}

    def test_recovery_from_injected_fault(self, tmp_path):
        ck = Checkpointer(tmp_path)
        boom = {"armed": True}

        def injector(step):
            if step == 7 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("synthetic node failure")

        state = RunState({"w": jnp.zeros(())}, {}, 0)
        state, report = run_with_recovery(
            self._step, state, lambda s: iter(lambda: {"x": 0}, None),
            num_steps=10, checkpointer=ck, checkpoint_every=2,
            fault_injector=injector)
        assert report["restarts"] == 1
        assert state.step == 10
        # params re-applied from checkpoint: 6 ckpt + 4 more steps
        assert float(state.params["w"]) == 10.0

    def test_exhausted_restarts_raise(self, tmp_path):
        ck = Checkpointer(tmp_path)

        def injector(step):
            raise RuntimeError("always failing")

        with pytest.raises(RuntimeError):
            run_with_recovery(self._step, RunState({"w": jnp.zeros(())}, {}, 0),
                              lambda s: iter(lambda: {"x": 0}, None),
                              num_steps=4, checkpointer=ck, max_restarts=2,
                              fault_injector=injector)

    def test_straggler_flagging(self):
        pol = StragglerPolicy(threshold=2.0, warmup_steps=4)
        from repro.runtime.fault_tolerance import StepTimer
        t = StepTimer()
        for _ in range(8):
            t.record(1.0)
        assert not pol.check(t, 1.5)
        assert pol.check(t, 5.0)
        assert pol.flagged == 1


class TestElastic:
    def test_plan_divisible(self):
        plan = elastic_restart_plan(256, 128, 256)
        assert plan["per_device_batch"] == 2 and plan["grad_accum"] == 1

    def test_plan_with_accum(self):
        plan = elastic_restart_plan(256, 192, 256)
        assert plan["per_device_batch"] * plan["grad_accum"] * 192 >= 256 \
            or plan["grad_accum"] > 1

    def test_reshard_checkpoint_roundtrip(self):
        from repro.runtime import reshard_checkpoint
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        tree = {"table": np.ones((16, 8), np.float32)}
        out = reshard_checkpoint(tree, mesh)
        np.testing.assert_allclose(np.asarray(out["table"]), 1.0)
