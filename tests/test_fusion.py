"""Wave-fused lowering: parity, jaxpr shrink, interning, fallbacks.

The tentpole invariants:

* fused replay == unfused replay == EagerExecutor, on every graph shape
  (chain / diamond / pipeline grid / MoE-style heterogeneous fan-out);
* an isomorphic-wave graph lowers to O(waves) task-body instances, not
  O(tasks) — asserted on the traced jaxpr;
* structurally identical TDGs intern to ONE shared compiled executable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TDG, EagerExecutor, ReplayExecutor, classify_wave,
                        clear_intern_cache, fused_tdg_as_function,
                        fusion_plan, intern_stats, lower_tdg, taskgraph,
                        tdg_as_function, topo_waves)


def _mm(x):
    return jnp.tanh(x @ x.T) @ x * 0.5 + x


def _grid_tdg(n_waves=4, n_tasks=8, dim=8):
    """`n_waves` waves of `n_tasks` isomorphic chains (paper Listing 1)."""
    tdg = TDG(f"grid{n_waves}x{n_tasks}")
    for w in range(n_waves):
        for t in range(n_tasks):
            tdg.add_task(_mm, inouts=[f"x{t}"], name=f"t{w}.{t}")
    rng = np.random.default_rng(0)
    bufs = {f"x{t}": jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
            for t in range(n_tasks)}
    return tdg, bufs


def _chain_tdg(n=12):
    tdg = TDG("chain")
    for i in range(n):
        tdg.add_task(lambda x: x * 1.001 + 0.5, inouts=["x"], name=f"c{i}")
    return tdg, {"x": jnp.arange(6.0)}


def _diamond_tdg():
    tdg = TDG("diamond")
    tdg.add_task(lambda x: x + 1.0, ins=["x"], outs=["a"])
    tdg.add_task(lambda a: a * 2.0, ins=["a"], outs=["b"])
    tdg.add_task(lambda a: a * 3.0, ins=["a"], outs=["c"])
    tdg.add_task(lambda b, c: b + c, ins=["b", "c"], outs=["y"])
    return tdg, {"x": jnp.arange(5.0)}


def _pipeline_grid_tdg(stages=4, micro=6, dim=8):
    """Forward pipeline over real matmul payloads (isomorphic diagonals)."""
    tdg = TDG("pipe")
    for m in range(micro):
        for s in range(stages):
            ins = [f"act[{m},{s-1}]"] if s > 0 else [f"in{m}"]
            tdg.add_task(_mm, ins=ins, outs=[f"act[{m},{s}]"],
                         name=f"F[{m},{s}]")
    rng = np.random.default_rng(1)
    bufs = {f"in{m}": jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
            for m in range(micro)}
    return tdg, bufs


def _moe_tdg(n_tokens_blocks=6, dim=16):
    """MoE-style: shared router weight + heterogeneous expert payloads."""
    tdg = TDG("moe")
    rng = np.random.default_rng(2)

    def route(x, w):
        return x @ w

    def expert_a(x):
        return jax.nn.gelu(x) * 1.5

    def expert_b(x):
        return jnp.tanh(x) - 0.1 * x

    for b in range(n_tokens_blocks):
        tdg.add_task(route, ins=[f"x{b}", "w"], outs=[f"r{b}"])
        fn = expert_a if b % 2 == 0 else expert_b
        tdg.add_task(fn, ins=[f"r{b}"], outs=[f"e{b}"])
    tdg.add_task(lambda *es: sum(es),
                 ins=[f"e{b}" for b in range(n_tokens_blocks)], outs=["y"])
    bufs = {f"x{b}": jnp.asarray(rng.standard_normal((4, dim)), jnp.float32)
            for b in range(n_tokens_blocks)}
    bufs["w"] = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    return tdg, bufs


GRAPHS = {
    "grid": _grid_tdg,
    "chain": _chain_tdg,
    "diamond": _diamond_tdg,
    "pipeline": _pipeline_grid_tdg,
    "moe": _moe_tdg,
}


class TestParity:
    @pytest.mark.parametrize("graph", sorted(GRAPHS))
    def test_fused_vs_unfused_vs_eager(self, graph):
        tdg, bufs = GRAPHS[graph]()
        eager = EagerExecutor(tdg, n_workers=3).run(dict(bufs))
        unfused = lower_tdg(tdg, fuse=False, intern=False)(dict(bufs))
        fused = lower_tdg(tdg, fuse=True, intern=False)(dict(bufs))
        assert set(eager) == set(unfused) == set(fused)
        for k in fused:
            np.testing.assert_allclose(np.asarray(fused[k]),
                                       np.asarray(unfused[k]),
                                       rtol=2e-5, atol=2e-5)
            np.testing.assert_allclose(np.asarray(fused[k]),
                                       np.asarray(eager[k]),
                                       rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("graph", ["grid", "pipeline", "moe"])
    def test_map_batcher_parity(self, graph):
        tdg, bufs = GRAPHS[graph]()
        vmapped = lower_tdg(tdg, fuse=True, intern=False)(dict(bufs))
        mapped = lower_tdg(tdg, fuse=True, intern=False,
                           batcher="map")(dict(bufs))
        for k in vmapped:
            np.testing.assert_allclose(np.asarray(mapped[k]),
                                       np.asarray(vmapped[k]),
                                       rtol=2e-5, atol=2e-5)

    def test_grad_through_fused(self):
        tdg = TDG("g")
        for t in range(4):
            tdg.add_task(lambda x: x * 2.0, ins=[f"x{t}"], outs=[f"y{t}"])
        tdg.add_task(lambda *ys: sum((y ** 2).sum() for y in ys),
                     ins=[f"y{t}" for t in range(4)], outs=["l"])
        f = lower_tdg(tdg, jit=False, fuse=True)
        x = jnp.arange(3.0)
        g = jax.grad(lambda x: f({f"x{t}": x for t in range(4)})["l"])(x)
        np.testing.assert_allclose(g, 4 * 8.0 * x)


class TestWaveAnalysis:
    def test_plan_groups_isomorphic_waves(self):
        tdg, bufs = _grid_tdg(n_waves=5, n_tasks=7)
        plan = fusion_plan(tdg, bufs)
        assert plan.num_tasks == 35
        assert plan.num_waves == 5
        assert plan.num_classes == 5          # one class per wave
        assert plan.fused_tasks == 35 and plan.fused_fraction == 1.0

    def test_plan_respects_shapes(self):
        # same payload, two shapes in one wave -> two classes
        tdg = TDG("shapes")
        fn = lambda x: x + 1.0  # noqa: E731
        for t in range(4):
            tdg.add_task(fn, ins=[f"a{t}"], outs=[f"b{t}"])
        bufs = {f"a{t}": jnp.zeros((4,) if t < 2 else (8,)) for t in range(4)}
        plan = fusion_plan(tdg, bufs)
        assert plan.num_classes == 2
        assert sorted(c.size for c in plan.classes) == [2, 2]

    def test_structural_plan_without_shapes(self):
        tdg, _ = _grid_tdg(n_waves=2, n_tasks=4)
        plan = fusion_plan(tdg)     # structural upper bound, no buffers
        assert plan.num_classes == 2 and plan.fused_tasks == 8

    def test_classify_shared_arg_positions(self):
        tdg = TDG("sh")
        fn = lambda x, w: x * w  # noqa: E731
        for t in range(3):
            tdg.add_task(fn, ins=[f"x{t}", "w"], outs=[f"y{t}"])
        waves = topo_waves(tdg)
        env = {f"x{t}": jnp.zeros(3) for t in range(3)}
        env["w"] = jnp.zeros(3)
        from repro.core.fuse import value_signature
        [cls] = classify_wave(tdg, 0, waves[0],
                              lambda s: value_signature(env[s]))
        assert cls.shared == (False, True)    # w broadcasts, x stacks

    def test_heterogeneous_wave_falls_back(self):
        tdg, bufs = _moe_tdg()
        f = fused_tdg_as_function(tdg)
        f(dict(bufs))
        plan = f.last_plan
        # router wave fuses, expert wave splits into the two payload classes
        assert plan.fused_classes >= 1
        assert plan.fused_tasks < plan.num_tasks  # reduce task is unrolled
        assert sum(c.size for c in plan.classes) == plan.num_tasks

    def test_identical_input_class_evaluates_once(self):
        # N tasks, same fn, same input slot -> single evaluation fans out
        calls = []

        def fn(x):
            calls.append(1)
            return x + 1.0

        tdg = TDG("allshared")
        for t in range(5):
            tdg.add_task(fn, ins=["x"], outs=[f"y{t}"])
        out = fused_tdg_as_function(tdg)({"x": jnp.arange(3.0)})
        assert len(calls) == 1
        for t in range(5):
            np.testing.assert_allclose(out[f"y{t}"], jnp.arange(3.0) + 1)


class TestJaxprSize:
    def test_isomorphic_wave_graph_lowers_to_o_waves_bodies(self):
        n_waves, n_tasks = 4, 16
        tdg, bufs = _grid_tdg(n_waves=n_waves, n_tasks=n_tasks)
        unfused = jax.make_jaxpr(lower_tdg(tdg, jit=False, fuse=False))(bufs)
        fused = jax.make_jaxpr(lower_tdg(tdg, jit=False, fuse=True))(bufs)

        def dots(jaxpr):
            return sum(1 for e in jaxpr.eqns
                       if e.primitive.name == "dot_general")

        # body instances: O(tasks) unrolled (2 dots/body), O(waves) fused
        assert dots(unfused) == 2 * n_waves * n_tasks
        assert dots(fused) == 2 * n_waves
        # total program shrinks even counting stack/unstack bookkeeping
        assert len(fused.eqns) < len(unfused.eqns)

    def test_fallback_when_explicit_order(self):
        tdg, bufs = _grid_tdg(2, 4)
        order = list(range(tdg.num_tasks))
        f = lower_tdg(tdg, order=order, jit=False)
        assert not hasattr(f, "last_plan")     # unrolled form was chosen
        out = f(dict(bufs))
        ref = lower_tdg(tdg, fuse=False, intern=False)(dict(bufs))
        for k in out:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                       rtol=1e-6)

    def test_fuse_env_var_kill_switch(self, monkeypatch):
        from repro.core import fuse_enabled
        monkeypatch.setenv("REPRO_FUSE", "0")
        assert not fuse_enabled("auto")
        monkeypatch.setenv("REPRO_FUSE", "1")
        assert fuse_enabled("auto")
        assert fuse_enabled(True) and not fuse_enabled(False)


class TestInterning:
    def setup_method(self):
        clear_intern_cache()

    def test_structurally_identical_tdgs_share_executable(self):
        def fn(x):
            return x * 2.0 + 1.0

        def mk(name):
            tdg = TDG(name)
            for w in range(3):
                for t in range(4):
                    tdg.add_task(fn, inouts=[f"b{t}"])
            return tdg

        bufs = {f"b{t}": jnp.arange(4.0) + t for t in range(4)}
        a, b = ReplayExecutor(mk("A")), ReplayExecutor(mk("B"))
        o1, o2 = a.run(dict(bufs)), b.run(dict(bufs))
        stats = intern_stats()
        assert stats["misses"] == 1 and stats["hits"] == 1
        assert stats["entries"] == 1           # ONE shared compiled executable
        for k in o1:
            np.testing.assert_allclose(o1[k], o2[k])

    def test_regions_with_renamed_slots_intern(self):
        def payload(x):
            return x * 3.0 - 1.0

        @taskgraph
        def region_a(g, u0, u1):
            g.task(payload, inouts=["u0"])
            g.task(payload, inouts=["u1"])

        @taskgraph
        def region_b(g, v0, v1):
            g.task(payload, inouts=["v0"])
            g.task(payload, inouts=["v1"])

        region_a(u0=jnp.ones(3), u1=jnp.zeros(3))   # record
        region_b(v0=jnp.ones(3), v1=jnp.zeros(3))   # record
        clear_intern_cache()
        ra = region_a(u0=jnp.ones(3), u1=jnp.zeros(3))   # replay: miss
        rb = region_b(v0=jnp.ones(3), v1=jnp.zeros(3))   # replay: HIT
        stats = intern_stats()
        assert (stats["misses"], stats["hits"]) == (1, 1)
        np.testing.assert_allclose(ra["u0"], rb["v0"])

    def test_different_payloads_do_not_collide(self):
        def f1(x):
            return x + 1.0

        def f2(x):
            return x - 1.0

        def mk(fn):
            tdg = TDG("p")
            tdg.add_task(fn, inouts=["x"])
            tdg.add_task(fn, inouts=["x"])
            return tdg

        bufs = {"x": jnp.zeros(3)}
        o1 = ReplayExecutor(mk(f1)).run(dict(bufs))
        o2 = ReplayExecutor(mk(f2)).run(dict(bufs))
        assert intern_stats()["entries"] == 2
        np.testing.assert_allclose(o1["x"], 2.0)
        np.testing.assert_allclose(o2["x"], -2.0)

    def test_different_structure_does_not_collide(self):
        def fn(x):
            return x + 1.0

        t1, t2 = TDG("a"), TDG("b")
        t1.add_task(fn, inouts=["x"])
        t2.add_task(fn, inouts=["x"])
        t2.add_task(fn, inouts=["x"])
        ReplayExecutor(t1).run({"x": jnp.zeros(2)})
        ReplayExecutor(t2).run({"x": jnp.zeros(2)})
        assert intern_stats()["entries"] == 2

    def test_explicit_intern_requires_jit_and_default_order(self):
        tdg, _ = _chain_tdg(3)
        with pytest.raises(ValueError, match="intern=True"):
            lower_tdg(tdg, jit=False, intern=True)
        with pytest.raises(ValueError, match="intern=True"):
            lower_tdg(tdg, order=[0, 1, 2], intern=True)

    def test_intern_cache_is_lru_bounded(self, monkeypatch):
        from repro.core import lower as lower_mod
        monkeypatch.setattr(lower_mod, "_INTERN_CAP", 2)
        bufs = {"x": jnp.zeros(2)}
        for i in range(4):
            tdg = TDG(f"lru{i}")
            tdg.add_task(lambda x, i=i: x + float(i), inouts=["x"])
            ReplayExecutor(tdg).run(dict(bufs))   # fresh closure: always miss
        stats = intern_stats()
        assert stats["entries"] <= 2
        assert stats["evictions"] == 2

    def test_kernel_mode_keys_intern_cache(self):
        from repro.kernels import ops

        def fn(x, w):
            return ops.rmsnorm(x, w)

        def mk():
            tdg = TDG("k")
            for t in range(2):
                tdg.add_task(fn, ins=[f"x{t}", "w"], outs=[f"y{t}"])
            return tdg

        bufs = {f"x{t}": jnp.ones((4, 8)) for t in range(2)}
        bufs["w"] = jnp.ones(8)
        ReplayExecutor(mk(), kernel_mode="ref").run(dict(bufs))
        ReplayExecutor(mk(), kernel_mode="interpret").run(dict(bufs))
        assert intern_stats()["entries"] == 2  # substrate is part of the key


class TestRegionFusionIntegration:
    def test_region_replay_fused_matches_record(self):
        @taskgraph
        def region(g, **kw):
            for t in range(6):
                g.task(_mm, inouts=[f"x{t}"], name=f"a{t}")
            for t in range(6):
                g.task(_mm, inouts=[f"x{t}"], name=f"b{t}")

        rng = np.random.default_rng(3)
        bufs = {f"x{t}": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
                for t in range(6)}
        rec = region(**bufs)
        rep = region(**bufs)
        assert region.records == 1 and region.replays == 1
        for k in rec:
            np.testing.assert_allclose(np.asarray(rec[k]), np.asarray(rep[k]),
                                       rtol=2e-5, atol=2e-5)
        assert region.schedule_summary()["fusion"]["fused_tasks"] == 12

    def test_fuse_false_region_still_works(self):
        @taskgraph(fuse=False)
        def region(g, x):
            g.task(lambda x: x + 1.0, inouts=["x"])
            g.task(lambda x: x * 2.0, inouts=["x"])

        o1 = region(x=jnp.arange(4.0))
        o2 = region(x=jnp.arange(4.0))
        np.testing.assert_allclose(o1["x"], o2["x"])


class TestListScheduleRegression:
    def test_no_dead_pending_path(self):
        # Before the fix, an (unreachable) branch popped from an
        # always-empty list; the scheduler now raises only on impossible
        # (cyclic) inputs and completes every DAG.
        from repro.core import list_schedule, validate_execution_order
        tdg, _ = _pipeline_grid_tdg(stages=3, micro=4)
        sched = list_schedule(tdg, 3)
        assert validate_execution_order(tdg, sched.order())
        assert len(sched.start_time) == tdg.num_tasks

    def test_forged_cycle_rejected_loudly(self):
        # a cyclic graph dies with a clear error (either topo_order's cycle
        # check or the scheduler's stall guard), never a silent IndexError
        from repro.core import list_schedule
        tdg, _ = _diamond_tdg()
        tdg.preds[0].add(3)     # forge a cycle bypassing add_task
        tdg.succs[3].add(0)
        with pytest.raises((ValueError, RuntimeError)):
            list_schedule(tdg, 2)
