"""Property-based tests (hypothesis) for system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (TDG, EagerExecutor, ReplayExecutor, list_schedule,
                        one_f_one_b_order, pipeline_tdg, round_robin_assign,
                        topo_order, topo_waves, validate_execution_order)
from repro.core.pipeline import pipeline_waves


def _noop(*xs):
    return xs[0] if len(xs) == 1 else xs


@st.composite
def random_tdg(draw):
    """Random dep-clause programs over a small slot namespace."""
    n_slots = draw(st.integers(2, 6))
    n_tasks = draw(st.integers(1, 24))
    tdg = TDG("random")
    for _ in range(n_tasks):
        ins = draw(st.sets(st.integers(0, n_slots - 1), max_size=3))
        outs = draw(st.sets(st.integers(0, n_slots - 1), min_size=1,
                            max_size=2))
        tdg.add_task(_noop,
                     ins=[f"s{i}" for i in sorted(ins - outs)],
                     outs=[f"s{o}" for o in sorted(outs)])
    return tdg


@given(random_tdg())
@settings(max_examples=60, deadline=None)
def test_tdg_always_acyclic_and_schedulable(tdg):
    tdg.validate()
    order = topo_order(tdg)
    assert validate_execution_order(tdg, order)
    waves = topo_waves(tdg)
    assert sum(len(w) for w in waves) == tdg.num_tasks
    # wave members are mutually independent
    for w in waves:
        ws = set(w)
        for t in w:
            assert not (tdg.preds[t] & ws)


@given(random_tdg(), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_list_schedule_valid_and_complete(tdg, workers):
    sched = list_schedule(tdg, workers)
    order = sched.order()
    assert validate_execution_order(tdg, order)
    assert sched.makespan <= tdg.num_tasks          # never worse than serial
    # respects the critical-path lower bound
    assert sched.makespan >= len(topo_waves(tdg)) - 1e-9


@given(st.integers(0, 200), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_round_robin_partition(n, w):
    qs = round_robin_assign(list(range(n)), w)
    assert sorted(sum(qs, [])) == list(range(n))
    sizes = [len(q) for q in qs]
    assert max(sizes) - min(sizes) <= 1


@given(st.integers(1, 6), st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_pipeline_tdg_depth(S, M):
    fwd = pipeline_tdg(S, M, include_backward=False)
    assert len(topo_waves(fwd)) == pipeline_waves(S, M)
    full = pipeline_tdg(S, M)
    assert full.num_tasks == 2 * S * M
    streams = one_f_one_b_order(S, M)
    for s, stream in enumerate(streams):
        assert len(stream) == 2 * M
        fs = [m for p, m in stream if p == "F"]
        bs = [m for p, m in stream if p == "B"]
        assert fs == sorted(fs) and bs == sorted(bs)   # in-order per stage
        # B_m only after F_m on the same stage
        for m in range(M):
            assert stream.index(("F", m)) < stream.index(("B", m))


@given(st.lists(st.floats(1e-3, 1e3), min_size=1, max_size=30),
       st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_eager_replay_equivalence_property(vals, workers):
    """For arbitrary per-slot chains, the dynamic scheduler and the fused
    replay produce identical buffers."""
    tdg = TDG("chains")

    def fn(x):
        return x * 1.5 + 0.25

    for i, _ in enumerate(vals):
        tdg.add_task(fn, inouts=[f"x{i % 3}"])
    bufs = {f"x{j}": jnp.float32(sum(vals) % 7.0) for j in range(3)}
    r1 = EagerExecutor(tdg, n_workers=workers).run(dict(bufs))
    r2 = ReplayExecutor(tdg).run(dict(bufs))
    for k in r2:
        np.testing.assert_allclose(r1[k], r2[k], rtol=1e-5)


@given(st.lists(st.integers(1, 4), min_size=1, max_size=5),
       st.integers(1, 4), st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_continuous_streams_match_serial_oracle(steps_list, max_batch, seed):
    """Random join/leave/finish interleavings — streams of arbitrary length
    admitted to a continuous server under an arbitrary batch width — must
    produce exactly what each tenant would get from a serial replay chain."""
    from repro.serving import RegionServer

    def body(x, w):
        return jnp.tanh(x @ w) * 0.5 + x

    def region(i):
        from repro.core import TDG
        tdg = TDG(f"prop[{i}]")
        for s in range(2):
            tdg.add_task(body, ins=[f"x{s}", "w"], outs=[f"x{s}"])
        return tdg

    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)
    server = RegionServer(max_batch=max_batch, continuous=True,
                          autostart=False)
    tenants = []
    for i, steps in enumerate(steps_list):
        tdg = region(i)
        server.register_tenant(f"t{i}", tdg)
        bufs = {f"x{s}": jnp.asarray(rng.standard_normal((4, 4)),
                                     jnp.float32) for s in range(2)}
        bufs["w"] = w
        tenants.append((tdg, bufs, steps))
    futs = [server.submit_stream(f"t{i}", b, steps=s)
            for i, (_, b, s) in enumerate(tenants)]
    server.start()
    outs = [f.result(120) for f in futs]
    server.close()
    for (tdg, start, steps), out in zip(tenants, outs):
        bufs = dict(start)
        want = {}
        ex = ReplayExecutor(tdg)
        for _ in range(steps):
            want = ex.run(dict(bufs))
            bufs.update({k: v for k, v in want.items() if k in bufs})
        assert set(out) == set(want)
        for k in want:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(want[k]),
                                       rtol=2e-4, atol=2e-4)
