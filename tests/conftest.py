import numpy as np
import pytest

# Tests must see the real single-device CPU (the 512-device override is
# dryrun-only). Nothing here sets XLA_FLAGS.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)


@pytest.fixture(autouse=True)
def _reset_taskgraph_registry():
    from repro.core import reset_registry
    reset_registry()
    yield
    reset_registry()
