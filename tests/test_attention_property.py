"""Property-based attention invariants (hypothesis over shapes/patterns)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ref
from repro.kernels import xla_attention as X


@st.composite
def attn_case(draw):
    B = draw(st.integers(1, 2))
    Sq = draw(st.integers(1, 96))
    Sk = draw(st.integers(1, 96))
    Hkv = draw(st.sampled_from([1, 2]))
    G = draw(st.sampled_from([1, 2, 4]))
    D = draw(st.sampled_from([8, 16]))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Sq, Hkv * G, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, Hkv, D)), jnp.float32)
    return q, k, v


@given(attn_case())
@settings(max_examples=25, deadline=None)
def test_cross_matches_oracle(case):
    q, k, v = case
    np.testing.assert_allclose(X.sdpa_cross(q, k, v),
                               ref.attention_ref(q, k, v, causal=False),
                               atol=3e-5, rtol=3e-5)


@given(attn_case(), st.sampled_from([16, 32, 48]))
@settings(max_examples=25, deadline=None)
def test_sliding_matches_oracle(case, window):
    q, k, v = case
    S = min(q.shape[1], k.shape[1])
    q, k, v = q[:, :S], k[:, :S], v[:, :S]
    np.testing.assert_allclose(
        X.sdpa_sliding(q, k, v, window=window),
        ref.attention_ref(q, k, v, causal=True, window=window),
        atol=3e-5, rtol=3e-5)


@given(attn_case(), st.sampled_from([8, 16, 64]))
@settings(max_examples=25, deadline=None)
def test_full_qchunk_invariance(case, chunk):
    q, k, v = case
    S = min(q.shape[1], k.shape[1])
    q, k, v = q[:, :S], k[:, :S], v[:, :S]
    a = X.sdpa_full(q, k, v, chunk=chunk)
    b = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


@given(attn_case())
@settings(max_examples=15, deadline=None)
def test_softmax_rows_convex(case):
    """Output rows lie in the convex hull of V rows (softmax property)."""
    q, k, v = case
    S = min(q.shape[1], k.shape[1])
    q, k, v = q[:, :S], k[:, :S], v[:, :S]
    out = np.asarray(X.sdpa_full(q, k, v))
    vmax = np.asarray(v).max(axis=1, keepdims=True)   # (B,1,Hkv,D)
    vmin = np.asarray(v).min(axis=1, keepdims=True)
    G = q.shape[2] // k.shape[2]
    vmax = np.repeat(vmax, G, axis=2)
    vmin = np.repeat(vmin, G, axis=2)
    assert (out <= vmax[:, :1] + 1e-4).all()
    assert (out >= vmin[:, :1] - 1e-4).all()
