"""Self-healing cluster tier: fault plans, load shedding, leases, respawn.

The unit suites exercise the deterministic :class:`FaultPlan` machinery and
the ``RegionServer`` shedding paths with no processes at all; the
process-spawning suites drive the real supervisor — a SIGSTOPped worker
(lease expiry without a socket error), injected frame drops (deadline
sweep), injected spawn failures (respawn backoff), and the shm-leak /
close-race regressions — against spawned jax workers, so they share
class-scoped frontends where they can and keep heartbeats fast.
"""
import json
import os
import signal
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReplayExecutor
from repro.serving import (ClusterFrontend, DeadlineExceeded, FaultPlan,
                           InjectedFault, QueueFull, RegionServer)
from repro.serving import faults
from repro.serving.demo import DEMO_REGISTRY, demo_region

REGISTRY_SPEC = "repro.serving.demo:DEMO_REGISTRY"
DIM = 6


def _bufs(seed, width=2):
    rng = np.random.default_rng(seed)
    b = {f"x{s}": jnp.asarray(rng.standard_normal((DIM, DIM)), jnp.float32)
         for s in range(width)}
    b["w"] = jnp.asarray(rng.standard_normal((DIM, DIM)), jnp.float32)
    return b


def _check(out, tdg, bufs):
    want = ReplayExecutor(tdg).run(dict(bufs))
    for k in want:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with fault injection disarmed."""
    faults.clear()
    yield
    faults.clear()


# ---------------------------------------------------------------------------
# FaultPlan unit behaviour (no processes)
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_validation_is_loud(self):
        with pytest.raises(ValueError, match="point"):
            FaultPlan([{"point": "teleport", "action": "drop"}])
        with pytest.raises(ValueError, match="action"):
            FaultPlan([{"point": "send", "action": "explode"}])
        with pytest.raises(ValueError, match="role"):
            FaultPlan([{"point": "send", "action": "drop", "role": "gpu"}])

    def test_after_and_count_budgets(self):
        plan = FaultPlan([{"point": "send", "op": "submit_batch",
                           "after": 2, "count": 2, "action": "drop"}])
        hits = [plan.consult("frontend", "send", "submit_batch") is not None
                for _ in range(6)]
        # events 1,2 skipped (after=2), events 3,4 fire (count=2), then spent
        assert hits == [False, False, True, True, False, False]
        assert plan.exhausted()

    def test_op_none_counts_any_frame(self):
        plan = FaultPlan([{"point": "recv", "after": 1, "count": 1,
                           "action": "drop"}])
        assert plan.consult("worker", "recv", "submit_batch") is None
        assert plan.consult("worker", "recv", "result_batch") is not None

    def test_role_filtering(self):
        plan = FaultPlan([{"point": "send", "role": "worker",
                           "action": "drop", "count": -1}])
        assert plan.consult("frontend", "send", None) is None
        assert plan.consult("worker", "send", None) is not None

    def test_determinism_same_plan_same_schedule(self):
        spec = [{"point": "send", "op": "submit_batch", "after": 1,
                 "count": 2, "action": "drop"}]
        fired = []
        for _ in range(2):
            plan = FaultPlan(spec, seed=7)
            for _ in range(5):
                plan.consult("frontend", "send", "submit_batch")
            fired.append([(f["event"], f["action"]) for f in plan.fired()])
        assert fired[0] == fired[1] == [(2, "drop"), (3, "drop")]

    def test_corrupt_bytes_is_seeded(self):
        data = bytes(range(256)) * 4
        a = FaultPlan(seed=3).corrupt_bytes(data)
        b = FaultPlan(seed=3).corrupt_bytes(data)
        c = FaultPlan(seed=4).corrupt_bytes(data)
        assert a == b and a != data and a != c

    def test_json_roundtrip(self):
        plan = FaultPlan([{"point": "spawn", "action": "fail", "count": 3}],
                         seed=11)
        again = FaultPlan.from_json(plan.to_json())
        assert again.seed == 11
        assert again.rules[0]["point"] == "spawn"
        assert again.rules[0]["count"] == 3
        with pytest.raises(ValueError, match="not valid JSON"):
            FaultPlan.from_json("{nope")
        with pytest.raises(ValueError, match="rules"):
            FaultPlan.from_json('{"rules": "not-a-list"}')

    def test_install_flips_the_guard(self):
        assert faults.ENABLED is False
        faults.install(FaultPlan(), role="frontend")
        assert faults.ENABLED is True
        assert faults.active() is not None
        faults.clear()
        assert faults.ENABLED is False
        assert faults.on_point("send") is None     # disarmed: no-op

    def test_explicit_install_wins_over_env(self, monkeypatch):
        mine = FaultPlan(seed=42)
        faults.install(mine, role="frontend")
        monkeypatch.setenv(faults.FAULT_PLAN_ENV,
                           FaultPlan(seed=1).to_json())
        faults.init_from_env("frontend")
        assert faults.active() is mine

    def test_env_arms_when_nothing_installed(self, monkeypatch):
        monkeypatch.setenv(faults.FAULT_PLAN_ENV,
                           FaultPlan(seed=9).to_json())
        faults.init_from_env("worker")
        assert faults.ENABLED and faults.active().seed == 9

    def test_fail_action_raises_injected_fault(self):
        faults.install(FaultPlan([{"point": "spawn", "action": "fail"}]),
                       role="frontend")
        with pytest.raises(InjectedFault, match="spawn"):
            faults.on_point("spawn")


# ---------------------------------------------------------------------------
# Load shedding + deadlines on the bare RegionServer (no processes)
# ---------------------------------------------------------------------------

class TestLoadShedding:
    def test_submit_queue_bound_sheds_with_queue_full(self):
        tdg = demo_region("qb[0]")
        with RegionServer(max_batch=1, autostart=False,
                          queue_bound=2) as server:
            server.register_tenant("t", tdg)
            b = _bufs(1)
            server.submit("t", b)
            server.submit("t", b)
            with pytest.raises(QueueFull, match="bound"):
                server.submit("t", b)
            assert server.metrics.snapshot()["shed"] == 1
            assert server.stats()["queue_bound"] == 2

    def test_queue_bound_env_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_QUEUE_BOUND", "5")
        with RegionServer(autostart=False) as server:
            assert server.queue_bound == 5
        with RegionServer(autostart=False, queue_bound=0) as server:
            assert server.queue_bound == 0      # explicit beats env

    def test_submit_many_overflow_prefails_tail(self):
        tdg = demo_region("qm[0]")
        with RegionServer(max_batch=1, autostart=False,
                          queue_bound=2) as server:
            server.register_tenant("t", tdg)
            b = _bufs(2)
            futs = server.submit_many([("t", b)] * 4)
            done = [f for f in futs if f.done()]
            assert len(done) == 2               # the overflow pair
            for f in done:
                with pytest.raises(QueueFull):
                    f.result(0)
            assert server.metrics.snapshot()["shed"] == 2

    def test_expired_deadline_shed_at_admission(self):
        tdg = demo_region("dl[0]")
        with RegionServer(max_batch=1, autostart=False) as server:
            server.register_tenant("t", tdg)
            b = _bufs(3)
            past = time.monotonic() - 1.0
            futs = server.submit_many([("t", b, past), ("t", b, None)])
            assert futs[0].done()
            with pytest.raises(DeadlineExceeded, match="before admission"):
                futs[0].result(0)
            assert not futs[1].done()
            assert server.metrics.snapshot()["deadline_sheds"] == 1

    def test_expired_deadline_shed_at_dispatch(self):
        # Queue the request with a deadline that passes while the
        # dispatcher is stopped: starting the server must shed it without
        # spending a replay, and serve the live companion normally.
        tdg = demo_region("dd[0]")
        with RegionServer(max_batch=1, autostart=False) as server:
            server.register_tenant("t", tdg)
            b = _bufs(4)
            dl = time.monotonic() + 0.05
            doomed = server.submit("t", b, deadline=dl)
            alive = server.submit("t", b)
            # poll past the deadline instant (a fixed sleep flakes when the
            # submits themselves eat into the margin)
            while time.monotonic() <= dl:
                time.sleep(0.005)
            server.start()
            with pytest.raises(DeadlineExceeded, match="while queued"):
                doomed.result(60)
            _check(alive.result(60), tdg, b)
            snap = server.metrics.snapshot()
            assert snap["deadline_sheds"] == 1
            assert snap["failed"] == 1


# ---------------------------------------------------------------------------
# The live supervisor: leases, respawn, warm recovery (spawns workers)
# ---------------------------------------------------------------------------

def _wait_for(pred, timeout=90.0, tick=0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(tick)
    return False


class TestSupervisorSelfHealing:
    @pytest.fixture(scope="class")
    def frontend(self):
        fe = ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             heartbeat_secs=0.3, lease_misses=3,
                             respawn_max=5, name="test-heal")
        yield fe
        fe.close()

    def test_sigstop_lease_expiry_distinguishes_wedged_from_dead(
            self, frontend):
        # A SIGSTOPped worker produces NO socket error — the connection is
        # healthy, the process is wedged. Only the heartbeat lease can
        # notice; the supervisor must declare it dead and respawn it.
        tdg = demo_region("heal[0]")
        frontend.register_tenant("heal", tdg)
        bufs = _bufs(10)
        _check(frontend.serve("heal", bufs, timeout=120), tdg, bufs)
        old_pid = frontend._handles[0].process.pid
        deaths_before = frontend.worker_deaths
        respawns_before = frontend.respawns
        os.kill(old_pid, signal.SIGSTOP)
        try:
            assert _wait_for(lambda: frontend.worker_deaths > deaths_before)
        finally:
            # The spawner's terminate/kill escalation reaps a stopped
            # process, but never leave it wedged if the assert fails.
            try:
                os.kill(old_pid, signal.SIGCONT)
            except ProcessLookupError:
                pass
        assert frontend.heartbeat_misses >= 3   # the lease did the work
        assert _wait_for(lambda: frontend.respawns > respawns_before
                         and frontend._handles[0].alive)
        new_pid = frontend._handles[0].process.pid
        assert new_pid != old_pid
        _check(frontend.serve("heal", bufs, timeout=120), tdg, bufs)
        sup = frontend.stats()["frontend"]["supervisor"]
        assert sup["enabled"] and sup["lease_misses"] == 3

    def test_respawned_worker_is_reregistered_and_serves(self, frontend):
        # After the respawn above, the same tenant keeps serving from the
        # SAME slot (1-worker fleet: there is no sibling to hide behind).
        tdg = demo_region("heal[0]")
        bufs = _bufs(11)
        _check(frontend.serve("heal", bufs, timeout=120), tdg, bufs)
        assert frontend.tenant("heal").worker == 0


class TestInjectedFaults:
    def test_dropped_result_frame_becomes_deadline_exceeded(self):
        # Drop the first result_batch the FRONTEND receives: the worker
        # computed and answered, the reply evaporated. Without the
        # supervisor's deadline sweep this hangs forever; with it the
        # caller gets a typed DeadlineExceeded, the window slot frees, and
        # the next request flows normally.
        faults.install(FaultPlan([{"role": "frontend", "point": "recv",
                                   "op": "result_batch", "count": 1,
                                   "action": "drop"}]), role="frontend")
        with ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             heartbeat_secs=0.3, lease_misses=3,
                             retry_budget=0, name="test-drop") as fe:
            tdg = demo_region("drop[0]")
            fe.register_tenant("d", tdg)
            bufs = _bufs(20)
            with pytest.raises(DeadlineExceeded):
                fe.serve("d", bufs, timeout=3.0)
            assert faults.active().exhausted()
            assert fe.deadline_failures >= 1
            # the sweep released the frame slot: the connection still flows
            _check(fe.serve("d", bufs, timeout=120), tdg, bufs)

    def test_spawn_fault_burns_a_respawn_attempt_then_recovers(self):
        # Kill the worker, and make the FIRST respawn attempt fail at
        # launch (a host that momentarily cannot start processes). The
        # supervisor must count the failure, back off, and succeed on the
        # next attempt.
        faults.install(FaultPlan([{"role": "frontend", "point": "spawn",
                                   "after": 1, "count": 1,
                                   "action": "fail"}]), role="frontend")
        with ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             heartbeat_secs=0.3, lease_misses=3,
                             respawn_max=5, name="test-spawnfault") as fe:
            tdg = demo_region("sf[0]")
            fe.register_tenant("s", tdg)
            bufs = _bufs(21)
            _check(fe.serve("s", bufs, timeout=120), tdg, bufs)
            fe._handles[0].process.kill()
            assert _wait_for(lambda: fe.respawn_failures >= 1)
            assert _wait_for(lambda: fe.respawns >= 1
                             and fe._handles[0].alive)
            _check(fe.serve("s", bufs, timeout=120), tdg, bufs)
            assert fe.stats()["frontend"]["respawn_failures"] >= 1


class TestDeathCleanupRegressions:
    """The two satellite bugfixes: shm-segment leaks on worker death, and
    the close()-vs-dispatcher race."""

    def test_worker_death_unlinks_shm_rings_and_falls_back_to_tcp(self):
        with ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             transport="shm", heartbeat_secs=0.3,
                             lease_misses=3, respawn_max=5,
                             name="test-shmleak") as fe:
            h = fe._handles[0]
            assert h.transport == "shm"
            ring_names = [r.name for r in (h.conn._send_ring,
                                           h.conn._recv_ring)]
            for nm in ring_names:
                assert os.path.exists(f"/dev/shm/{nm}")
            tdg = demo_region("leak[0]")
            fe.register_tenant("l", tdg)
            bufs = _bufs(30)
            _check(fe.serve("l", bufs, timeout=120), tdg, bufs)
            fallbacks_before = fe.stats()["frontend"]["shm_fallbacks"]
            h.process.kill()
            assert _wait_for(lambda: not h.alive)
            # the death path (not frontend teardown) unlinked both rings
            assert _wait_for(lambda: not any(
                os.path.exists(f"/dev/shm/{nm}") for nm in ring_names),
                timeout=30)
            assert _wait_for(lambda: fe.respawns >= 1
                             and fe._handles[0].alive)
            # replacement's first connection is deliberately TCP, counted
            assert fe._handles[0].transport == "tcp"
            assert fe.stats()["frontend"]["shm_fallbacks"] > fallbacks_before
            _check(fe.serve("l", bufs, timeout=120), tdg, bufs)

    def test_close_with_inflight_window_never_hangs_or_drops_futures(self):
        # Stall the worker (SIGSTOP) with a window's worth of submissions
        # in flight, then close() the frontend: close must return promptly
        # and every outstanding future must resolve to a typed error —
        # never hang, never silently stay pending.
        fe = ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             heartbeat_secs=0, shutdown_grace=5.0,
                             name="test-closerace")
        closed = False
        try:
            tdg = demo_region("cr[0]")
            fe.register_tenant("c", tdg)
            bufs = _bufs(31)
            _check(fe.serve("c", bufs, timeout=120), tdg, bufs)
            os.kill(fe._handles[0].process.pid, signal.SIGSTOP)
            futs = [fe.submit("c", bufs) for _ in range(24)]
            t0 = time.monotonic()
            closer = threading.Thread(target=fe.close, daemon=True)
            closer.start()
            closer.join(timeout=60)
            assert not closer.is_alive(), "close() hung on inflight window"
            closed = True
            assert time.monotonic() - t0 < 60
            for f in futs:
                assert f.done(), "close() dropped a future silently"
                with pytest.raises(Exception):
                    f.result(0)
        finally:
            try:
                os.kill(fe._handles[0].process.pid, signal.SIGCONT)
            except (ProcessLookupError, OSError):
                pass
            if not closed:
                fe.close()
