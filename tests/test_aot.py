"""AOT compile path: warmup, cost capture, executable serialization.

The paper's compiler emits a TDG artifact the runtime just *loads*; the
JAX analogue is ``lower.aot_compile_tdg`` (+ ``serialize.save_executable``)
— trace and XLA-compile ahead of time, replay anywhere without retracing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TDG, ReplayExecutor, aot_compile_tdg,
                        executable_serialization_available, load_warm,
                        taskgraph, warmup_and_save)
from repro.core.serialize import TaskFnRegistry, load_executable

REG = TaskFnRegistry()


@REG.register()
def _aot_scale(x):
    return x * 2.0 + 1.0


def _graph(n=6):
    tdg = TDG("aot")
    for t in range(n):
        tdg.add_task(_aot_scale, inouts=[f"x{t}"])
    return tdg, {f"x{t}": jnp.arange(4.0) + t for t in range(n)}


class TestAotCompile:
    def test_matches_lazy_replay(self):
        tdg, bufs = _graph()
        aot = aot_compile_tdg(tdg, bufs)
        lazy = ReplayExecutor(tdg).run(dict(bufs))
        got = aot(bufs)
        for k in lazy:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(lazy[k]), rtol=1e-6)

    def test_accepts_abstract_specs(self):
        tdg, bufs = _graph()
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in bufs.items()}
        aot = aot_compile_tdg(tdg, specs)     # no data touched
        got = aot(bufs)
        np.testing.assert_allclose(got["x0"], bufs["x0"] * 2.0 + 1.0)

    def test_cost_analysis_and_timings_captured(self):
        tdg, bufs = _graph()
        aot = aot_compile_tdg(tdg, bufs)
        assert aot.trace_seconds > 0 and aot.compile_seconds > 0
        if aot.cost_analysis is not None:     # backend-dependent
            assert aot.flops is not None and aot.flops > 0

    def test_donation_preserved_in_aot_path(self):
        # regression: aot_compile dropped donate_slots, silently changing
        # memory semantics vs the lazy jit path
        tdg = TDG("don")
        tdg.add_task(_aot_scale, inouts=["state"])
        aot = aot_compile_tdg(tdg, {"state": jnp.ones((4,))},
                              donate_slots=("state",))
        assert aot.donate_slots == ("state",)
        np.testing.assert_allclose(aot({"state": jnp.ones((4,))})["state"],
                                   3.0)

        ex = ReplayExecutor(TDG("don2"), donate_slots=("state",))
        ex.tdg.add_task(_aot_scale, inouts=["state"])
        aot2 = ex.aot_compile({"state": jnp.ones((4,))})
        assert aot2.donate_slots == ("state",)
        np.testing.assert_allclose(ex.run({"state": jnp.ones((4,))})["state"],
                                   3.0)

    def test_extra_buffer_keys_dropped(self):
        tdg, bufs = _graph()
        aot = aot_compile_tdg(tdg, bufs)
        got = aot({**bufs, "unrelated": jnp.zeros(9)})
        np.testing.assert_allclose(got["x1"], bufs["x1"] * 2.0 + 1.0)


class TestExecutorWarmup:
    def test_replay_executor_aot_populates_cache(self):
        tdg, bufs = _graph()
        ex = ReplayExecutor(tdg)
        aot = ex.aot_compile(bufs)
        assert len(ex._cache) == 1
        out = ex.run(dict(bufs))
        assert ex._cache[(list(ex._cache)[0])] is aot
        np.testing.assert_allclose(out["x0"], bufs["x0"] * 2.0 + 1.0)

    def test_region_warmup_skips_retrace(self):
        traces = []

        def payload(x):
            traces.append(1)        # runs once per *trace*, not per call
            return x + 1.0

        @taskgraph
        def region(g, a, b):
            g.task(payload, inouts=["a"])
            g.task(payload, inouts=["b"])

        specs = dict(a=jax.ShapeDtypeStruct((3,), jnp.float32),
                     b=jax.ShapeDtypeStruct((3,), jnp.float32))
        region.build_static(**specs)
        region.warmup(**specs)
        n_after_warmup = len(traces)
        assert n_after_warmup >= 1
        out = region(a=jnp.zeros(3), b=jnp.ones(3))
        out2 = region(a=jnp.ones(3), b=jnp.zeros(3))
        assert len(traces) == n_after_warmup   # zero retraces at call time
        assert region.replays == 2
        np.testing.assert_allclose(out["a"], 1.0)
        np.testing.assert_allclose(out2["b"], 1.0)

    def test_warmup_requires_tdg(self):
        @taskgraph
        def region(g, x):
            g.task(lambda x: x, inouts=["x"])

        with pytest.raises(RuntimeError, match="no TDG yet"):
            region.warmup(x=jnp.zeros(2))


@pytest.mark.skipif(not executable_serialization_available(),
                    reason="jax build lacks serialize_executable")
class TestExecutableSerialization:
    def test_warmup_and_save_round_trip(self, tmp_path):
        tdg, bufs = _graph()
        path = tmp_path / "region.tdg.json"
        info = warmup_and_save(tdg, bufs, path, REG)
        assert info["aot_path"].endswith(".aot")
        assert info["trace_seconds"] > 0

        tdg2, aot = load_warm(path, REG)
        assert aot is not None
        assert tdg2.num_tasks == tdg.num_tasks
        want = ReplayExecutor(tdg).run(dict(bufs))
        got = aot(bufs)                        # deserialized binary: no trace
        for k in want:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]), rtol=1e-6)

    def test_load_executable_direct(self, tmp_path):
        tdg, bufs = _graph(3)
        aot = aot_compile_tdg(tdg, bufs)
        p = tmp_path / "exec.aot"
        from repro.core import save_executable
        save_executable(aot, p)
        aot2 = load_executable(p)
        assert aot2.fused == aot.fused
        got = aot2(bufs)
        np.testing.assert_allclose(got["x2"], bufs["x2"] * 2.0 + 1.0)

    def test_load_warm_without_sidecar(self, tmp_path):
        tdg, bufs = _graph(2)
        path = tmp_path / "plain.tdg.json"
        from repro.core import save_tdg
        save_tdg(tdg, path, REG)
        tdg2, aot = load_warm(path, REG)
        assert aot is None and tdg2.num_tasks == 2

    def test_load_warm_corrupt_sidecar_falls_back(self, tmp_path):
        # A damaged .aot sidecar must degrade to (tdg, None) — the caller
        # retraces — never crash the load.
        tdg, bufs = _graph(2)
        path = tmp_path / "corrupt.tdg.json"
        warmup_and_save(tdg, bufs, path, REG)
        with open(str(path) + ".aot", "wb") as f:
            f.write(b"\x00this is not a pickled executable\xff")
        tdg2, aot = load_warm(path, REG)
        assert aot is None and tdg2.num_tasks == tdg.num_tasks
        got = ReplayExecutor(tdg2).run(dict(bufs))   # retrace path still works
        np.testing.assert_allclose(got["x0"], bufs["x0"] * 2.0 + 1.0)

    def test_load_warm_truncated_sidecar_falls_back(self, tmp_path):
        tdg, bufs = _graph(2)
        path = tmp_path / "trunc.tdg.json"
        warmup_and_save(tdg, bufs, path, REG)
        aot_path = str(path) + ".aot"
        blob = open(aot_path, "rb").read()
        with open(aot_path, "wb") as f:
            f.write(blob[: max(1, len(blob) // 3)])
        tdg2, aot = load_warm(path, REG)
        assert aot is None and tdg2.num_tasks == tdg.num_tasks

    def test_load_warm_unknown_version_sidecar_falls_back(self, tmp_path):
        import pickle
        tdg, bufs = _graph(2)
        path = tmp_path / "vers.tdg.json"
        warmup_and_save(tdg, bufs, path, REG)
        aot_path = str(path) + ".aot"
        with open(aot_path, "rb") as f:
            blob = pickle.load(f)
        blob["version"] = 99
        with open(aot_path, "wb") as f:
            pickle.dump(blob, f)
        with pytest.raises(ValueError, match="version"):
            load_executable(aot_path)                # direct load: loud
        tdg2, aot = load_warm(path, REG)             # warm load: soft-fail
        assert aot is None and tdg2.num_tasks == tdg.num_tasks

    def test_load_warm_corrupt_graph_is_loud(self, tmp_path):
        # The graph JSON is authoritative — unlike the sidecar, damage
        # there must NOT be silently absorbed.
        tdg, bufs = _graph(2)
        path = tmp_path / "badgraph.tdg.json"
        warmup_and_save(tdg, bufs, path, REG)
        with open(path, "w") as f:
            f.write("{not json")
        with pytest.raises(Exception):
            load_warm(path, REG)
