"""Differential harness for multi-device sharded fused replay.

The tentpole claim: constraining a fused class's stacked batch axis onto a
mesh (``sharding.replay.shard_leading``) changes WHERE each lane computes,
never WHAT it computes — every lane is independent, so sharded replay must
be *bit-exact* against the single-device fused form (``assert_array_equal``,
not allclose), and match the unrolled/eager forms to float tolerance.

Tier-1 (1 CPU device) runs the mesh-resolution / fingerprint / padding unit
tests; the multi-device differentials skip themselves. ``scripts/ci.sh``
runs this module a second time under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` where everything is
live. Tests gate on ``jax.device_count()`` at runtime, so they also work at
2 or 4 faked devices.
"""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EagerExecutor, ReplayExecutor, TDG, TopologyMismatch,
                        clear_intern_cache, executable_from_bytes,
                        executable_serialization_available,
                        executable_to_bytes, fused_tdg_as_function,
                        intern_stats, lower_tdg, taskgraph,
                        topology_fingerprint)
from repro.core.lower import aot_compile_tdg
from repro.core.serialize import TaskFnRegistry, load_warm, warmup_and_save
from repro.launch.mesh import make_replay_mesh
from repro.serving.server import RegionServer
from repro.sharding import partition as _partition
from repro.sharding import replay as shreplay

DEVICES = jax.device_count()

MESH_LEG_HINT = ("run via scripts/ci.sh mesh leg "
                 "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def needs(n):
    return pytest.mark.skipif(DEVICES < n,
                              reason=f"needs {n} devices; {MESH_LEG_HINT}")


def _largest_mesh(cap=8):
    """Biggest power-of-two device count available, capped."""
    n = 1
    while n * 2 <= min(DEVICES, cap):
        n *= 2
    return n


# ---------------------------------------------------------------------------
# graph builders (mirroring tests/test_fusion.py idiom)
# ---------------------------------------------------------------------------

def _mm(x):
    return jnp.tanh(x @ x.T) @ x * 0.5 + x


def _gelu_mix(x):
    return jax.nn.gelu(x) @ x + x.sum(axis=-1, keepdims=True)


def _shared_proj(x, w):
    return jnp.tanh(x @ w) @ w.T + x


def _grid_tdg(occupancy, n_waves=2, name="mesh_grid"):
    """``occupancy`` independent chains of ``n_waves`` identical tasks: each
    wave is one fusion class of exactly ``occupancy`` members."""
    tdg = TDG(region=f"{name}_{occupancy}x{n_waves}")
    for c in range(occupancy):
        src = f"x{c}"
        for w in range(n_waves):
            dst = f"h{c}_{w}"
            tdg.add_task(_mm, ins=[src], outs=[dst], name=f"t{c}_{w}")
            src = dst
    return tdg


def _grid_inputs(occupancy, dim=4, seed=0):
    rng = np.random.default_rng(seed)
    return {f"x{c}": jnp.asarray(rng.standard_normal((dim, dim)),
                                 jnp.float32)
            for c in range(occupancy)}


def _shared_w_tdg(occupancy):
    """Every class member shares the constant-signature slot ``w`` (the MoE
    router-weight shape from test_fusion): only ``x`` stacks and shards."""
    tdg = TDG(region=f"mesh_shared_{occupancy}")
    for c in range(occupancy):
        tdg.add_task(_shared_proj, ins=[f"x{c}", "w"], outs=[f"y{c}"],
                     name=f"proj{c}")
    return tdg


_SWEEP_PAYLOADS = (_mm, _gelu_mix)


def _random_wave_tdg(seed, occupancy, n_waves):
    """Seeded wave-structured TDG: each wave picks one payload for all its
    tasks (so it fuses into a single class) and random fan-in from the
    previous wave — the property-test structure space."""
    rng = np.random.default_rng(seed)
    tdg = TDG(region=f"mesh_rand_{seed}_{occupancy}x{n_waves}")
    prev = [f"x{c}" for c in range(occupancy)]
    for w in range(n_waves):
        fn = _SWEEP_PAYLOADS[int(rng.integers(len(_SWEEP_PAYLOADS)))]
        width = max(1, int(rng.integers(1, occupancy + 1)))
        cur = []
        for c in range(width):
            src = prev[int(rng.integers(len(prev)))]
            dst = f"h{w}_{c}"
            tdg.add_task(fn, ins=[src], outs=[dst], name=f"t{w}_{c}")
            cur.append(dst)
        prev = cur
    return tdg


def _assert_tree_equal(a, b):
    ka, kb = sorted(a), sorted(b)
    assert ka == kb
    for k in ka:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"slot {k!r}")


def _assert_tree_close(a, b, tol=2e-5):
    for k in sorted(a):
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=tol, atol=tol, err_msg=f"slot {k!r}")


# ---------------------------------------------------------------------------
# mesh resolution / fingerprint / padding (1-device safe)
# ---------------------------------------------------------------------------

class TestResolveMesh:
    def test_none_stays_none(self):
        assert shreplay.resolve_mesh(None) is None
        assert shreplay.mesh_fingerprint(None) is None

    def test_auto_without_env_or_scope_is_none(self, monkeypatch):
        monkeypatch.delenv(shreplay.MESH_ENV, raising=False)
        assert shreplay.resolve_mesh("auto") is None

    @pytest.mark.parametrize("raw", ["", "0", "off", "false", "no", "none",
                                     "OFF", "False"])
    def test_env_off_values(self, monkeypatch, raw):
        monkeypatch.setenv(shreplay.MESH_ENV, raw)
        assert shreplay.resolve_mesh("auto") is None

    def test_env_junk_raises(self, monkeypatch):
        monkeypatch.setenv(shreplay.MESH_ENV, "banana")
        with pytest.raises(ValueError, match=shreplay.MESH_ENV):
            shreplay.resolve_mesh("auto")

    def test_env_one_device_normalizes_to_none(self, monkeypatch):
        # A 1-way batch axis shards nothing: resolve to the single-device
        # path instead of paying GSPMD constraint overhead for free.
        monkeypatch.setenv(shreplay.MESH_ENV, "1")
        assert shreplay.resolve_mesh("auto") is None

    def test_non_auto_string_rejected(self):
        with pytest.raises(ValueError):
            shreplay.resolve_mesh("data=8")

    def test_one_device_mesh_normalizes_to_none(self):
        assert shreplay.resolve_mesh(make_replay_mesh(1)) is None

    def test_make_replay_mesh_bad_count(self):
        with pytest.raises(ValueError):
            make_replay_mesh(0)

    def test_make_replay_mesh_too_many_mentions_flag(self):
        with pytest.raises(RuntimeError,
                           match="xla_force_host_platform_device_count"):
            make_replay_mesh(DEVICES + 1)

    def test_pad_group_no_mesh_is_identity(self):
        members = [jnp.zeros(3), jnp.ones(3)]
        assert shreplay.pad_group(members, None) == 0
        assert len(members) == 2

    @needs(2)
    def test_fingerprint_is_stable_string(self):
        mesh = make_replay_mesh(2)
        fp = shreplay.mesh_fingerprint(mesh)
        assert fp == "data=2"
        # the fingerprint crosses the cluster's JSON wire — must round-trip
        assert json.loads(json.dumps(fp)) == fp

    @needs(2)
    def test_pad_group_rounds_up_repeating_last(self):
        mesh = make_replay_mesh(2)
        a, b, c = jnp.zeros(3), jnp.ones(3), jnp.full(3, 2.0)
        members = [a, b, c]
        assert shreplay.pad_group(members, mesh) == 1
        assert len(members) == 4 and members[3] is c

    @needs(2)
    def test_env_count_resolves(self, monkeypatch):
        monkeypatch.setenv(shreplay.MESH_ENV, "2")
        assert shreplay.mesh_fingerprint(shreplay.resolve_mesh("auto")) == \
            "data=2"
        monkeypatch.setenv(shreplay.MESH_ENV, "all")
        assert shreplay.mesh_fingerprint(shreplay.resolve_mesh("auto")) == \
            f"data={DEVICES}"

    @needs(4)
    def test_scope_beats_env(self, monkeypatch):
        monkeypatch.setenv(shreplay.MESH_ENV, "2")
        with _partition.use_mesh(make_replay_mesh(4)):
            fp = shreplay.mesh_fingerprint(shreplay.resolve_mesh("auto"))
        assert fp == "data=4"
        # scope restored: env wins again outside
        assert shreplay.mesh_fingerprint(shreplay.resolve_mesh("auto")) == \
            "data=2"

    @needs(4)
    def test_explicit_mesh_beats_scope(self):
        with _partition.use_mesh(make_replay_mesh(2)):
            fp = shreplay.mesh_fingerprint(
                shreplay.resolve_mesh(make_replay_mesh(4)))
        assert fp == "data=4"

    @needs(2)
    def test_batch_axis_size(self):
        assert shreplay.batch_axis_size(None) == 1
        assert shreplay.batch_axis_size(make_replay_mesh(2)) == 2


# ---------------------------------------------------------------------------
# the differential: sharded == unsharded exactly, == unrolled/eager closely
# ---------------------------------------------------------------------------

def _differential(tdg, buffers, mesh):
    """Run the three forms and cross-check: this is THE harness invariant."""
    sharded = lower_tdg(tdg, mesh=mesh)(buffers)
    plain = lower_tdg(tdg, mesh=None)(buffers)
    unrolled = lower_tdg(tdg, fuse=False, mesh=None)(buffers)
    _assert_tree_equal(sharded, plain)
    _assert_tree_close(sharded, unrolled)
    return sharded


class TestDifferential:
    @pytest.mark.parametrize("n_dev", [2, 4, 8])
    @pytest.mark.parametrize("occupancy", [1, 3, 8])
    def test_grid_parity(self, n_dev, occupancy):
        if DEVICES < n_dev:
            pytest.skip(f"needs {n_dev} devices; {MESH_LEG_HINT}")
        tdg = _grid_tdg(occupancy, name=f"grid{n_dev}")
        _differential(tdg, _grid_inputs(occupancy, seed=occupancy),
                      make_replay_mesh(n_dev))

    @needs(2)
    @pytest.mark.parametrize("occupancy", [3, 5, 7])
    def test_non_divisible_occupancy_pads_exactly(self, occupancy):
        """Odd class sizes on every available mesh width: the pad lanes are
        computed but never read back, so results stay bit-exact."""
        n_dev = _largest_mesh()
        tdg = _grid_tdg(occupancy, name=f"pad{occupancy}")
        _differential(tdg, _grid_inputs(occupancy, seed=occupancy + 100),
                      make_replay_mesh(n_dev))

    @needs(2)
    def test_shared_constant_arg_not_sharded(self):
        """The shared slot ``w`` has constant signature: only the varying
        ``x`` members stack/shard, ``w`` broadcasts — still bit-exact."""
        occupancy = 5
        tdg = _shared_w_tdg(occupancy)
        rng = np.random.default_rng(7)
        buffers = _grid_inputs(occupancy, seed=7)
        buffers["w"] = jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)
        _differential(tdg, buffers, make_replay_mesh(_largest_mesh()))

    @needs(2)
    def test_seeded_random_sweep(self):
        """Always-on miniature of the hypothesis property test: random
        wave-structured TDGs x occupancy x every available device count."""
        for seed in range(6):
            rng = np.random.default_rng(1000 + seed)
            occupancy = int(rng.integers(1, 11))
            n_waves = int(rng.integers(1, 4))
            tdg = _random_wave_tdg(seed, occupancy, n_waves)
            buffers = _grid_inputs(occupancy, seed=seed)
            for n_dev in (2, 4, 8):
                if n_dev > DEVICES:
                    continue
                sharded = lower_tdg(tdg, mesh=make_replay_mesh(n_dev))(buffers)
                plain = lower_tdg(tdg, mesh=None)(buffers)
                _assert_tree_equal(sharded, plain)
            eager = EagerExecutor(tdg).run(dict(buffers))
            for k in plain:
                np.testing.assert_allclose(np.asarray(plain[k]),
                                           np.asarray(eager[k]),
                                           rtol=2e-5, atol=2e-5)

    @needs(2)
    def test_unbatchable_class_falls_back_single_device(self):
        """A payload with no usable vmap path degrades its class to the
        unrolled (single-device) form under a mesh — the per-class fallback
        — while other classes in the same TDG still fuse and shard."""
        from jax.interpreters.batching import BatchTracer

        def stubborn(x):
            if isinstance(x, BatchTracer):
                raise TypeError("no batching rule for this payload")
            return x * 2.0 + 1.0

        occupancy = 4
        tdg = TDG(region="mesh_fallback")
        for c in range(occupancy):
            tdg.add_task(stubborn, ins=[f"x{c}"], outs=[f"s{c}"],
                         name=f"stub{c}")
        for c in range(occupancy):
            tdg.add_task(_mm, ins=[f"s{c}"], outs=[f"y{c}"], name=f"mm{c}")
        buffers = _grid_inputs(occupancy, seed=42)
        mesh = make_replay_mesh(_largest_mesh())

        fn = fused_tdg_as_function(tdg, mesh=mesh)
        out = fn(buffers)
        fused_flags = {cls.fused for cls in fn.last_plan.classes}
        assert fused_flags == {True, False}  # mm wave fused, stubborn not

        expected = EagerExecutor(tdg).run(dict(buffers))
        for k in out:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(expected[k]),
                                       rtol=2e-5, atol=2e-5)
        # and through the full jitted lowering path
        _assert_tree_equal(lower_tdg(tdg, mesh=mesh)(buffers),
                           lower_tdg(tdg, mesh=None)(buffers))

    @needs(2)
    def test_map_batcher_ignores_mesh(self):
        """lax.map serializes class members on purpose — it must stay
        single-device (mesh silently dropped), and still agree."""
        tdg = _grid_tdg(4, name="mapb")
        buffers = _grid_inputs(4, seed=9)
        out = lower_tdg(tdg, batcher="map",
                        mesh=make_replay_mesh(_largest_mesh()))(buffers)
        _assert_tree_equal(out, lower_tdg(tdg, mesh=None)(buffers))


# optional deep property test (hypothesis is not a tier-1 dependency)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - env-dependent
    HAVE_HYP = False

if HAVE_HYP:
    @needs(2)
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16),
           occupancy=st.integers(1, 64),
           n_waves=st.integers(1, 4),
           n_dev=st.sampled_from([1, 2, 4, 8]))
    def test_property_sharded_replay_bit_exact(seed, occupancy, n_waves,
                                               n_dev):
        if n_dev > DEVICES:
            n_dev = DEVICES
        tdg = _random_wave_tdg(seed, occupancy, n_waves)
        buffers = _grid_inputs(occupancy, dim=2, seed=seed)
        mesh = make_replay_mesh(n_dev) if n_dev > 1 else None
        sharded = lower_tdg(tdg, mesh=mesh)(buffers)
        plain = lower_tdg(tdg, mesh=None)(buffers)
        _assert_tree_equal(sharded, plain)


# ---------------------------------------------------------------------------
# executor / region / env plumbing
# ---------------------------------------------------------------------------

_REGION_IDS = itertools.count()


class TestExecutorAndRegion:
    @needs(2)
    def test_replay_executor_mesh_parity_and_keys(self):
        tdg = _grid_tdg(4, name="exec")
        buffers = _grid_inputs(4, seed=3)
        mesh = make_replay_mesh(2)
        ex_m = ReplayExecutor(tdg, mesh=mesh)
        ex_p = ReplayExecutor(tdg, mesh=None)
        assert ex_m.mesh_fp == "data=2" and ex_p.mesh_fp is None
        _assert_tree_equal(ex_m.run(dict(buffers)), ex_p.run(dict(buffers)))

    @needs(2)
    def test_region_mesh_resolves_per_replay(self, monkeypatch):
        """A region built with the default mesh="auto" picks up REPRO_MESH
        at replay time, keys its cache by fingerprint, and flipping the env
        re-lowers instead of serving a stale single-device executable."""
        monkeypatch.delenv(shreplay.MESH_ENV, raising=False)

        @taskgraph(name=f"mesh_region_{next(_REGION_IDS)}")
        def region(g, x):
            g.task(_mm, ins=["x"], outs=["h"], name="a")
            g.task(_mm, ins=["h"], outs=["y"], name="b")

        x = jnp.asarray(np.random.default_rng(5).standard_normal((4, 4)),
                        jnp.float32)
        o_plain = region(x=x)           # record
        o_plain = region(x=x)           # replay, single-device
        monkeypatch.setenv(shreplay.MESH_ENV, "2")
        o_mesh = region(x=x)            # replay, sharded
        _assert_tree_equal(o_mesh, o_plain)
        fps = {key[2] for key in region._replay_cache}
        assert fps == {None, "data=2"}

    @needs(2)
    def test_env_and_explicit_mesh_intern_to_same_executable(self):
        """REPRO_MESH=2 and an explicit 2-device mesh produce the same
        fingerprint, so the global intern cache serves one executable."""
        tdg = _grid_tdg(3, name="internhit")
        buffers = _grid_inputs(3, seed=11)
        clear_intern_cache()
        out1 = lower_tdg(tdg, mesh=make_replay_mesh(2))(buffers)
        with _partition.use_mesh(make_replay_mesh(2)):
            out2 = lower_tdg(tdg)(buffers)  # mesh="auto" -> ambient scope
        stats = intern_stats()
        assert stats["entries"] == 1 and stats["hits"] >= 1
        _assert_tree_equal(out1, out2)

    @needs(2)
    def test_mesh_and_no_mesh_never_collide_in_intern_cache(self):
        tdg = _grid_tdg(3, name="internmiss")
        buffers = _grid_inputs(3, seed=12)
        clear_intern_cache()
        out_m = lower_tdg(tdg, mesh=make_replay_mesh(2))(buffers)
        out_p = lower_tdg(tdg, mesh=None)(buffers)
        stats = intern_stats()
        assert stats["entries"] == 2 and stats["misses"] == 2
        _assert_tree_equal(out_m, out_p)


# ---------------------------------------------------------------------------
# serving: batched dispatch under a mesh, pool keys, eviction
# ---------------------------------------------------------------------------

def _serve_rounds(server, rounds):
    """Submit each round as one frame; returns [round][request] outputs."""
    results = []
    for reqs in rounds:
        futures = server.submit_many(reqs)
        results.append([f.result(timeout=60) for f in futures])
    return results


class TestServingUnderMesh:
    @needs(2)
    @pytest.mark.parametrize("occupancy", [4, 3])
    def test_batched_dispatch_parity(self, occupancy):
        """The same admission batch through a sharded and a plain server is
        bit-exact, including non-power-of-two (bucket-rounded) occupancy."""
        n_dev = _largest_mesh()
        rng = np.random.default_rng(occupancy)
        reqs = [("t0", {"x": jnp.asarray(rng.standard_normal((4, 4)),
                                         jnp.float32)})
                for _ in range(occupancy)]

        def one(mesh):
            srv = RegionServer(max_batch=8, max_wait_ms=30.0, mesh=mesh)
            try:
                @taskgraph(name=f"srv_region_{next(_REGION_IDS)}")
                def region(g, x):
                    g.task(_mm, ins=["x"], outs=["h"], name="a")
                    g.task(_mm, ins=["h"], outs=["y"], name="b")
                region(x=reqs[0][1]["x"])  # record
                srv.register_tenant("t0", region.tdg)
                return _serve_rounds(srv, [reqs])[0], srv.stats()
            finally:
                srv.close()

        out_m, stats_m = one(make_replay_mesh(n_dev))
        out_p, stats_p = one(None)
        assert stats_m["mesh"] == f"data={n_dev}" and stats_p["mesh"] is None
        for a, b in zip(out_m, out_p):
            _assert_tree_equal(a, b)

    @needs(2)
    def test_pool_keys_carry_mesh_fingerprint(self, tmp_path):
        """WarmPool AOT + batched keys end in the server's mesh fingerprint
        — a 1-device worker can never serve an N-device executable."""
        n_dev = _largest_mesh()
        srv = RegionServer(max_batch=4, max_wait_ms=20.0,
                           mesh=make_replay_mesh(n_dev))
        try:
            tdg = _grid_tdg(2, name="poolkeys")
            srv.register_tenant("pk", tdg)
            # per-request DISTINCT arrays: members sharing the very same
            # buffer objects collapse to the all-shared single-replay path
            # and never exercise the batched callable
            reqs = [("pk", _grid_inputs(2, seed=21 + i)) for i in range(2)]
            # dispatch BEFORE warming: a warm AOT executable would serve the
            # frame per-request and skip the batched-callable path entirely
            futures = srv.submit_many(reqs)
            for f in futures:
                f.result(timeout=60)
            srv.warmup("pk", _grid_inputs(2, seed=21))
            keys = list(srv.pool._entries)
            assert keys, "warmup + dispatch should have populated the pool"
            for key in keys:
                assert key[-1] == f"data={n_dev}", key
            assert {k[0] for k in keys} >= {"aot", "batched"}
        finally:
            srv.close()

    @needs(2)
    def test_pool_eviction_under_mesh_preserves_parity(self):
        """pool_capacity=1 with two alternating structures: every round
        evicts and recompiles, and sharded results stay exact throughout."""
        n_dev = _largest_mesh()

        def payload_b(x):
            return jax.nn.relu(x @ x.T) - x

        tdg_a = _grid_tdg(2, name="evict_a")
        tdg_b = TDG(region="evict_b")
        for c in range(2):
            tdg_b.add_task(payload_b, ins=[f"x{c}"], outs=[f"y{c}"],
                           name=f"b{c}")
        # distinct per-request data (identical objects would collapse to
        # the all-shared single-replay path and bypass the pool entirely)
        rounds = []
        for i, name in enumerate(["a", "b", "a", "b"]):
            rounds.append([(name, _grid_inputs(2, seed=31 + 10 * i + j))
                           for j in range(2)])

        def run(mesh):
            srv = RegionServer(max_batch=4, max_wait_ms=20.0,
                               pool_capacity=1, mesh=mesh)
            try:
                srv.register_tenant("a", tdg_a)
                srv.register_tenant("b", tdg_b)
                out = _serve_rounds(srv, rounds)
                return out, srv.pool.stats()
            finally:
                srv.close()

        out_m, pool_m = run(make_replay_mesh(n_dev))
        out_p, _ = run(None)
        assert pool_m["evictions"] > 0
        for rm, rp in zip(out_m, out_p):
            for a, b in zip(rm, rp):
                _assert_tree_equal(a, b)


# ---------------------------------------------------------------------------
# topology fingerprint / artifact hydration (satellite 3)
# ---------------------------------------------------------------------------

needs_serialization = pytest.mark.skipif(
    not executable_serialization_available(),
    reason="jax build lacks executable serialization")


class TestTopologyMesh:
    def test_fingerprint_has_mesh_and_is_json_stable(self):
        fp = topology_fingerprint(mesh=None)
        assert fp["mesh"] is None
        assert json.loads(json.dumps(fp)) == fp

    @needs(2)
    def test_fingerprint_mesh_field(self):
        fp = topology_fingerprint(mesh=make_replay_mesh(2))
        assert fp["mesh"] == "data=2"
        assert json.loads(json.dumps(fp)) == fp

    @needs(2)
    @needs_serialization
    def test_artifact_mesh_mismatch_raises(self):
        """An executable compiled under an N-device replay mesh must refuse
        to hydrate on a worker whose replay mesh differs — same device
        count, same platform: the MESH is the distinguishing factor. (A
        differing device_count already tripped the pre-existing fields;
        this is the gap satellite 3 closes.)"""
        n_dev = _largest_mesh()
        tdg = _grid_tdg(2, name="topo")
        buffers = _grid_inputs(2, seed=51)
        aot = aot_compile_tdg(tdg, buffers, mesh=make_replay_mesh(n_dev))
        assert aot.mesh_fp == f"data={n_dev}"
        blob = executable_to_bytes(aot)

        with pytest.raises(TopologyMismatch):
            executable_from_bytes(blob, mesh=None)

        back = executable_from_bytes(blob, mesh=f"data={n_dev}")
        assert back.mesh_fp == f"data={n_dev}"
        _assert_tree_equal(back(buffers), lower_tdg(tdg, mesh=None)(buffers))

    @needs(2)
    @needs_serialization
    def test_server_rejects_foreign_mesh_artifact_but_still_serves(
            self, tmp_path):
        """Full warm-path: artifact warmed under a mesh, hydrated by a
        server replaying WITHOUT one. The sidecar is rejected (loud in
        metrics, not silently wrong), and the tenant still serves correct
        results through the lazy path."""
        n_dev = _largest_mesh()
        reg = TaskFnRegistry()
        reg.register("mesh_mm")(_mm)
        tdg = TDG(region="warm_mesh")
        tdg.add_task(_mm, ins=["x"], outs=["y"], name="t")
        buffers = {"x": _grid_inputs(1, seed=61)["x0"]}
        path = str(tmp_path / "warm.json")
        warmup_and_save(tdg, buffers, path, reg,
                        mesh=make_replay_mesh(n_dev))

        # a consumer replaying under the SAME mesh hydrates fine
        _, aot_ok = load_warm(path, reg, mesh=f"data={n_dev}")
        assert aot_ok is not None

        srv = RegionServer(max_batch=1, max_wait_ms=1.0, mesh=None)
        try:
            srv.register_tenant("wm", warm_path=path, fn_registry=reg)
            assert srv.metrics.snapshot()["aot_hydrate_failures"] == 1
            out = srv.submit("wm", buffers).result(timeout=60)
            _assert_tree_equal(out, lower_tdg(tdg, mesh=None)(buffers))
        finally:
            srv.close()
