"""Per-architecture smoke tests (reduced same-family configs) + model
behavior invariants (scan==unrolled, prefill/decode==full forward)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, reduced, shape_applicable
from repro.models import (decode_step, forward, init_params, loss_fn,
                          param_count, prefill)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": jax.random.randint(KEY, (B, S), 2, cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            jax.random.fold_in(KEY, 7), (B, cfg.encoder_seq, cfg.d_model),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, KEY)
        batch = _batch(cfg)
        loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
        assert np.isfinite(float(loss)), arch
        logits, aux = forward(params, cfg, batch)
        assert logits.shape == (2, 32, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_grads_nonzero_and_finite(self, arch):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, KEY)
        g = jax.grad(lambda p: loss_fn(p, cfg, _batch(cfg))[0])(params)
        leaves = jax.tree_util.tree_leaves(g)
        assert all(np.isfinite(np.asarray(x)).all() for x in leaves), arch
        total = sum(float(jnp.abs(x).sum()) for x in leaves)
        assert total > 0, arch

    def test_decode_matches_forward(self, arch):
        """Prefill+decode logits == teacher-forced forward logits."""
        cfg = reduced(get_config(arch))
        params = init_params(cfg, KEY)
        B, S = 2, 16
        batch = _batch(cfg, B, S)
        full_logits, _ = forward(params, cfg, batch)

        pre = {**batch, "tokens": batch["tokens"][:, :S // 2]}
        logits, caches, pos = prefill(params, cfg, pre, max_len=S + 4)
        np.testing.assert_allclose(
            np.asarray(logits[:, -1], np.float32),
            np.asarray(full_logits[:, S // 2 - 1], np.float32),
            atol=5e-2, rtol=5e-2)
        # decode the next two tokens teacher-forced and compare
        for t in range(S // 2, S // 2 + 2):
            lg, caches = decode_step(params, cfg, batch["tokens"][:, t:t + 1],
                                     pos, caches)
            pos = pos + 1
            np.testing.assert_allclose(
                np.asarray(lg[:, 0], np.float32),
                np.asarray(full_logits[:, t], np.float32),
                atol=5e-2, rtol=5e-2)


def test_scan_equals_unrolled():
    cfg = reduced(get_config("qwen2.5-3b"), num_layers=4)
    cfg_scan = dataclasses.replace(cfg, scan_layers=True)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    l1, _ = loss_fn(params, cfg, batch)
    l2, _ = loss_fn(params, cfg_scan, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_scan_grouped_heterogeneous_llama4():
    cfg = reduced(get_config("llama4-scout-17b-a16e"), num_layers=4,
                  attn_chunk=16)
    cfg = dataclasses.replace(cfg, global_attn_every=4)
    cfg_scan = dataclasses.replace(cfg, scan_layers=True)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    l1, _ = loss_fn(params, cfg, batch)
    l2, _ = loss_fn(params, cfg_scan, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_loss_chunking_invariant():
    cfg = reduced(get_config("glm4-9b"))
    cfg_chunked = dataclasses.replace(cfg, loss_chunk=8)
    params = init_params(cfg, KEY)
    batch = _batch(cfg, 2, 32)
    l1, _ = loss_fn(params, cfg, batch)
    l2, _ = loss_fn(params, cfg_chunked, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_remat_invariant():
    cfg = reduced(get_config("minitron-8b"))
    cfg_remat = dataclasses.replace(cfg, remat="full")
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    g1 = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(p, cfg_remat, batch)[0])(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4),
        g1, g2)


def test_param_count_estimate_close():
    """configs.param_count() (analytic) vs actual initialized params."""
    for arch in ("qwen2.5-3b", "mamba2-370m", "qwen3-moe-30b-a3b"):
        cfg = reduced(get_config(arch))
        actual = param_count(init_params(cfg, KEY))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.25, (arch, actual, est)


def test_shape_applicability_matrix():
    cells = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            if s.name == "long_500k":
                assert ok == (arch in ("mamba2-370m", "hymba-1.5b")), arch
            else:
                assert ok
            cells += ok
    assert cells == 32   # 10 archs x 4 shapes - 8 inapplicable long_500k


def test_sliding_window_cache_ring_buffer():
    """Hymba ring cache: decode with cache == full forward at long pos."""
    cfg = reduced(get_config("hymba-1.5b"), window=8)
    params = init_params(cfg, KEY)
    B, S = 1, 24
    batch = _batch(cfg, B, S)
    full_logits, _ = forward(params, cfg, batch)
    pre = {**batch, "tokens": batch["tokens"][:, :S - 2]}
    logits, caches, pos = prefill(params, cfg, pre, max_len=S + 2)
    for t in range(S - 2, S):
        lg, caches = decode_step(params, cfg, batch["tokens"][:, t:t + 1],
                                 pos, caches)
        pos = pos + 1
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full_logits[:, t], np.float32),
                                   atol=5e-2, rtol=5e-2)
