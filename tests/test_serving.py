"""RegionServer: parity, coalescing, isolation, warm-pool, concurrency.

The serving tier must never trade correctness for batching: every test
checks outputs against the plain ``ReplayExecutor`` ground truth, and the
structural-sharing tests assert the economics (one executable for N
structurally identical tenants) that make multi-tenant replay serving
worthwhile in the first place.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TDG, ReplayExecutor, clear_intern_cache,
                        executable_serialization_available, intern_stats,
                        warmup_and_save)
from repro.core.serialize import TaskFnRegistry
from repro.serving import (QueueFull, RateLimited, RegionServer, SmoothWRR,
                           TokenBucket, WarmPool, tier_weight, validate_trace)
from repro.serving import rpc

REG = TaskFnRegistry()


@REG.register()
def _srv_body(x, w):
    return jnp.tanh(x @ w) * 0.5 + x


def _other_body(x, w):
    return x @ w + 1.0


def _region(i, body=_srv_body, waves=2, width=2):
    tdg = TDG(f"srv[{i}]")
    for wv in range(waves):
        for s in range(width):
            tdg.add_task(body, ins=[f"x{s}", "w"], outs=[f"x{s}"],
                         name=f"t{wv}.{s}")
    return tdg


def _bufs(seed, dim=6, width=2, shared_w=None):
    rng = np.random.default_rng(seed)
    b = {f"x{s}": jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
         for s in range(width)}
    b["w"] = (shared_w if shared_w is not None
              else jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32))
    return b


def _check(out, tdg, bufs):
    want = ReplayExecutor(tdg).run(dict(bufs))
    assert set(out) == set(want)
    for k in want:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5)


class TestParity:
    def test_single_tenant_single_request(self):
        tdg = _region(0)
        bufs = _bufs(0)
        with RegionServer(max_batch=1) as server:
            server.register_tenant("a", tdg)
            out = server.serve("a", bufs)
        _check(out, tdg, bufs)

    def test_sequential_requests_reuse_executable(self):
        tdg = _region(0)
        with RegionServer(max_batch=1) as server:
            server.register_tenant("a", tdg)
            b1, b2 = _bufs(1), _bufs(2)
            o1, o2 = server.serve("a", b1), server.serve("a", b2)
        _check(o1, tdg, b1)
        _check(o2, tdg, b2)

    def test_missing_input_slot_rejected_at_submit(self):
        with RegionServer() as server:
            server.register_tenant("a", _region(0))
            bad = _bufs(0)
            del bad["w"]
            with pytest.raises(KeyError, match="missing"):
                server.submit("a", bad)

    def test_unknown_tenant(self):
        with RegionServer() as server:
            with pytest.raises(KeyError, match="unknown tenant"):
                server.serve("ghost", {})


class TestCoalescing:
    def test_identical_structure_batches_and_matches_replay(self):
        n = 4
        w = jnp.asarray(np.random.default_rng(9).standard_normal((6, 6)),
                        jnp.float32)
        server = RegionServer(max_batch=n, max_wait_ms=500, autostart=False)
        tenants = []
        for i in range(n):
            tdg = _region(i)
            server.register_tenant(f"t{i}", tdg)
            tenants.append((tdg, _bufs(10 + i, shared_w=w)))
        futs = [server.submit(f"t{i}", b) for i, (_, b) in enumerate(tenants)]
        server.start()          # deterministic: all n queued before dispatch
        outs = [f.result(120) for f in futs]
        server.close()
        for (tdg, b), out in zip(tenants, outs):
            _check(out, tdg, b)
        m = server.metrics.snapshot()
        assert m["batches"] == 1
        assert m["batch_occupancy_max"] == n
        assert m["coalesced_requests"] == n

    def test_structural_sharing_serial_path(self):
        # N structurally identical tenants, batching off: tenant 2..N must
        # be served from tenant 1's interned executable (>= N-1 hits).
        clear_intern_cache()
        n = 4
        base = intern_stats()
        server = RegionServer(max_batch=1, autostart=True)
        tenants = []
        for i in range(n):
            tdg = _region(i)
            server.register_tenant(f"t{i}", tdg)
            tenants.append((tdg, _bufs(20 + i)))
        for i, (tdg, b) in enumerate(tenants):
            _check(server.serve(f"t{i}", b), tdg, b)
        server.close()
        stats = intern_stats()
        assert stats["hits"] - base["hits"] >= n - 1
        assert stats["misses"] - base["misses"] == 1

    def test_batched_entry_shared_across_batches(self):
        n = 2
        server = RegionServer(max_batch=n, max_wait_ms=500, autostart=False)
        for i in range(n):
            server.register_tenant(f"t{i}", _region(i))
        w = jnp.eye(6, dtype=jnp.float32)
        for round_ in range(3):
            futs = [server.submit(f"t{i}", _bufs(30 + i, shared_w=w))
                    for i in range(n)]
            if round_ == 0:
                server.start()
            for f in futs:
                f.result(120)
        server.close()
        pool = server.pool.stats()
        assert pool["misses"] == 1          # one batched executable built
        assert pool["hits"] >= 2            # ... reused by later batches
        assert server.metrics.snapshot()["batches"] == 3

    def test_shared_buffer_broadcast_not_stacked(self):
        # All members pass the SAME w object: results must still be exact
        # per-tenant (their private x slots differ).
        n = 3
        w = jnp.asarray(np.random.default_rng(1).standard_normal((6, 6)),
                        jnp.float32)
        server = RegionServer(max_batch=n, max_wait_ms=500, autostart=False)
        tenants = []
        for i in range(n):
            tdg = _region(i)
            server.register_tenant(f"t{i}", tdg)
            tenants.append((tdg, _bufs(40 + i, shared_w=w)))
        futs = [server.submit(f"t{i}", b) for i, (_, b) in enumerate(tenants)]
        server.start()
        outs = [f.result(120) for f in futs]
        server.close()
        for (tdg, b), out in zip(tenants, outs):
            _check(out, tdg, b)

    def test_fully_shared_buffers_one_evaluation(self):
        # Every slot is the same object across members: served by one
        # single-request replay, identical outputs for all.
        n = 3
        shared = _bufs(50)
        server = RegionServer(max_batch=n, max_wait_ms=500, autostart=False)
        tenants = [server.register_tenant(f"t{i}", _region(i))
                   for i in range(n)]
        futs = [server.submit(f"t{i}", shared) for i in range(n)]
        server.start()
        outs = [f.result(120) for f in futs]
        server.close()
        for t, out in zip(tenants, outs):
            _check(out, t.tdg, shared)


class TestIsolation:
    def test_different_payloads_never_coalesce(self):
        server = RegionServer(max_batch=4, max_wait_ms=100, autostart=False)
        t_a = _region("a")
        t_b = _region("b", body=_other_body)
        server.register_tenant("a", t_a)
        server.register_tenant("b", t_b)
        ba, bb = _bufs(60), _bufs(61)
        fa, fb = server.submit("a", ba), server.submit("b", bb)
        server.start()
        oa, ob = fa.result(120), fb.result(120)
        server.close()
        _check(oa, t_a, ba)
        _check(ob, t_b, bb)
        assert server.metrics.snapshot()["batch_occupancy_max"] <= 1

    def test_different_kernel_modes_never_coalesce(self):
        server = RegionServer(max_batch=4, max_wait_ms=100, autostart=False)
        t_a, t_b = _region("a"), _region("b")
        server.register_tenant("a", t_a, kernel_mode="ref")
        server.register_tenant("b", t_b, kernel_mode="interpret")
        assert server.tenant("a").kernel_mode == "ref"
        assert server.tenant("b").kernel_mode == "interpret"
        ba, bb = _bufs(62), _bufs(63)
        fa, fb = server.submit("a", ba), server.submit("b", bb)
        server.start()
        oa, ob = fa.result(120), fb.result(120)
        server.close()
        _check(oa, t_a, ba)
        _check(ob, t_b, bb)
        assert server.metrics.snapshot()["batch_occupancy_max"] <= 1

    def test_different_shapes_never_coalesce(self):
        server = RegionServer(max_batch=4, max_wait_ms=100, autostart=False)
        t_a, t_b = _region("a"), _region("b")
        server.register_tenant("a", t_a)
        server.register_tenant("b", t_b)
        ba, bb = _bufs(64, dim=6), _bufs(65, dim=8)
        fa, fb = server.submit("a", ba), server.submit("b", bb)
        server.start()
        oa, ob = fa.result(120), fb.result(120)
        server.close()
        _check(oa, t_a, ba)
        _check(ob, t_b, bb)
        assert server.metrics.snapshot()["batch_occupancy_max"] <= 1


class TestFallbackAndErrors:
    def test_batched_failure_falls_back_to_serial(self, monkeypatch):
        n = 3
        server = RegionServer(max_batch=n, max_wait_ms=500, autostart=False)
        tenants = []
        for i in range(n):
            tdg = _region(i)
            server.register_tenant(f"t{i}", tdg)
            tenants.append((tdg, _bufs(70 + i)))
        monkeypatch.setattr(
            server, "_build_batched",
            lambda tenant: (_ for _ in ()).throw(RuntimeError("no vmap rule")))
        futs = [server.submit(f"t{i}", b) for i, (_, b) in enumerate(tenants)]
        server.start()
        outs = [f.result(120) for f in futs]
        server.close()
        for (tdg, b), out in zip(tenants, outs):
            _check(out, tdg, b)
        m = server.metrics.snapshot()
        assert m["batch_fallbacks"] == 1
        assert m["completed"] == n

    def test_fallback_failure_isolated_per_request(self, monkeypatch):
        # Regression: when a coalesced batch falls back to serial replay
        # and ONE member fails, its siblings must still get their results
        # — not the failing member's exception.
        t0, t1 = _region(0), _region(1)
        server = RegionServer(max_batch=2, max_wait_ms=500, autostart=False)
        server.register_tenant("ok", t0)
        server.register_tenant("doomed", t1)
        monkeypatch.setattr(
            server, "_build_batched",
            lambda tenant: (_ for _ in ()).throw(RuntimeError("no vmap")))
        real_single = server._run_single

        def poisoned_single(req):
            if req.tenant.name == "doomed":
                raise ValueError("poison")
            return real_single(req)

        monkeypatch.setattr(server, "_run_single", poisoned_single)
        good = _bufs(75)
        f_ok = server.submit("ok", good)
        f_bad = server.submit("doomed", _bufs(76, shared_w=good["w"]))
        server.start()
        _check(f_ok.result(120), t0, good)
        with pytest.raises(ValueError, match="poison"):
            f_bad.result(120)
        server.close()
        m = server.metrics.snapshot()
        assert m["batch_fallbacks"] == 1
        assert m["completed"] == 1 and m["failed"] == 1

    def test_payload_error_propagates_to_future(self):
        def bad(x, w):
            raise ValueError("broken payload")

        tdg = TDG("bad")
        tdg.add_task(bad, ins=["x0", "w"], outs=["x0"])
        with RegionServer(max_batch=1) as server:
            server.register_tenant("a", tdg)
            fut = server.submit("a", _bufs(80, width=1))
            with pytest.raises(ValueError, match="broken payload"):
                fut.result(120)
        m = server.metrics.snapshot()
        assert m["failed"] == 1 and m["completed"] == 0

    def test_fallback_groups_not_counted_as_coalesced(self, monkeypatch):
        n = 3
        server = RegionServer(max_batch=n, max_wait_ms=500, autostart=False)
        for i in range(n):
            server.register_tenant(f"t{i}", _region(i))
        monkeypatch.setattr(
            server, "_build_batched",
            lambda tenant: (_ for _ in ()).throw(RuntimeError("no vmap")))
        w = jnp.eye(6, dtype=jnp.float32)
        futs = [server.submit(f"t{i}", _bufs(77 + i, shared_w=w))
                for i in range(n)]
        server.start()
        for f in futs:
            f.result(120)
        server.close()
        m = server.metrics.snapshot()
        assert m["batch_fallbacks"] == 1
        assert m["batch_occupancy_max"] == n      # admission group size...
        assert m["coalesced_requests"] == 0       # ...but nothing was fused

    def test_close_before_start_drains_queued_requests(self):
        # Regression: close() on a never-started server must not abandon
        # queued futures.
        server = RegionServer(max_batch=2, max_wait_ms=50, autostart=False)
        server.register_tenant("a", _region(0))
        bufs = _bufs(78)
        futs = [server.submit("a", bufs) for _ in range(3)]
        server.close()                             # never start()ed
        for f in futs:
            assert f.done()
            _check(f.result(0), server.tenant("a").tdg, bufs)

    def test_submit_after_close_rejected(self):
        server = RegionServer()
        server.register_tenant("a", _region(0))
        server.close()
        with pytest.raises(RuntimeError, match="closed"):
            server.submit("a", _bufs(0))

    def test_close_drains_pending(self):
        server = RegionServer(max_batch=2, max_wait_ms=50, autostart=False)
        server.register_tenant("a", _region(0))
        bufs = _bufs(81)
        futs = [server.submit("a", bufs) for _ in range(4)]
        server.start()
        server.close()                      # must drain, not drop
        for f in futs:
            assert f.done()
            _check(f.result(0), server.tenant("a").tdg, bufs)

    def test_duplicate_tenant_rejected(self):
        with RegionServer() as server:
            server.register_tenant("a", _region(0))
            with pytest.raises(ValueError, match="already registered"):
                server.register_tenant("a", _region(1))

    def test_tdg_xor_warm_path_required(self):
        with RegionServer() as server:
            with pytest.raises(ValueError, match="exactly one"):
                server.register_tenant("a")
            with pytest.raises(ValueError, match="exactly one"):
                server.register_tenant("a", _region(0), warm_path="x.json")


class TestWarmPoolAndAot:
    def test_warm_pool_lru_eviction(self):
        pool = WarmPool(capacity=2)
        from repro.serving import PoolEntry
        pool.put(("k1",), PoolEntry("single", lambda: 1))
        pool.put(("k2",), PoolEntry("single", lambda: 2))
        assert pool.get(("k1",)) is not None      # refresh k1
        pool.put(("k3",), PoolEntry("single", lambda: 3))
        assert pool.get(("k2",)) is None          # evicted (LRU)
        assert pool.get(("k3",)) is not None
        s = pool.stats()
        assert s["evictions"] == 1 and s["entries"] == 2

    def test_server_warmup_installs_aot(self):
        tdg = _region(0)
        bufs = _bufs(90)
        with RegionServer(max_batch=1) as server:
            server.register_tenant("a", tdg)
            info = server.warmup("a", bufs)
            assert info["trace_seconds"] > 0
            out = server.serve("a", bufs)
            _check(out, tdg, bufs)
            assert server.metrics.snapshot()["aot_served"] == 1

    def test_warmup_wrong_shapes_falls_back(self):
        tdg = _region(0)
        with RegionServer(max_batch=1) as server:
            server.register_tenant("a", tdg)
            server.warmup("a", _bufs(91, dim=6))
            other = _bufs(92, dim=8)          # different shapes: no AOT
            _check(server.serve("a", other), tdg, other)
            assert server.metrics.snapshot()["aot_served"] == 0

    @pytest.mark.skipif(not executable_serialization_available(),
                        reason="jax build lacks serialize_executable")
    def test_cold_tenant_hydrates_from_sidecar(self, tmp_path):
        tdg = _region(0)
        bufs = _bufs(93)
        path = tmp_path / "tenant.tdg.json"
        warmup_and_save(tdg, bufs, path, REG)
        with RegionServer(max_batch=1) as server:
            tenant = server.register_tenant("cold", warm_path=str(path),
                                            fn_registry=REG)
            assert tenant.aot_key is not None
            out = server.serve("cold", bufs)
            _check(out, tdg, bufs)
            m = server.metrics.snapshot()
            assert m["aot_served"] == 1
            assert server.pool.stats()["hydrations"] == 1

    def test_cold_tenant_missing_sidecar_falls_back(self, tmp_path):
        from repro.core import save_tdg
        tdg = _region(0)
        bufs = _bufs(94)
        path = tmp_path / "plain.tdg.json"
        save_tdg(tdg, path, REG)              # graph only, no .aot sidecar
        with RegionServer(max_batch=1) as server:
            tenant = server.register_tenant("cold", warm_path=str(path),
                                            fn_registry=REG)
            assert tenant.aot_key is None     # nothing hydrated
            out = server.serve("cold", bufs)  # interned lazy path
            _check(out, tdg, bufs)
            assert server.metrics.snapshot()["aot_served"] == 0


class TestMetrics:
    def test_percentile_nearest_rank(self):
        from repro.serving import percentile
        vals = [float(i) for i in range(1, 11)]      # 1..10
        assert percentile(vals, 50) == 5.0           # ceil(0.5*10)=5th value
        assert percentile(vals, 99) == 10.0
        assert percentile(vals, 0) == 1.0
        assert percentile(vals, 100) == 10.0
        assert percentile([], 50) == 0.0
        cent = [float(i) for i in range(1, 101)]
        assert percentile(cent, 50) == 50.0
        assert percentile(cent, 99) == 99.0

    def test_latency_reservoir_bounded(self):
        from repro.serving import LatencyReservoir
        r = LatencyReservoir(capacity=8)
        for i in range(100):
            r.record(float(i))
        s = r.summary()
        assert s["count"] == 100
        assert s["max_s"] == 99.0                    # recent window survives


class TestConcurrency:
    def test_many_tenants_many_rounds_threaded(self):
        n, rounds = 4, 3
        w = jnp.asarray(np.random.default_rng(5).standard_normal((6, 6)),
                        jnp.float32)
        server = RegionServer(max_batch=n, max_wait_ms=20)
        tenants = []
        for i in range(n):
            tdg = _region(i)
            server.register_tenant(f"t{i}", tdg)
            tenants.append((tdg, _bufs(100 + i, shared_w=w)))
        finals = [None] * n
        errors = []

        def loop(i):
            try:
                tdg, start = tenants[i]
                bufs = dict(start)
                for _ in range(rounds):
                    out = server.serve(f"t{i}", bufs, timeout=300)
                    bufs.update(out)
                    bufs["w"] = w
                finals[i] = bufs
            except BaseException as e:
                errors.append(e)

        threads = [threading.Thread(target=loop, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        server.close()
        assert not errors
        # ground truth: replay each tenant's chain serially
        for i, (tdg, start) in enumerate(tenants):
            ex = ReplayExecutor(tdg)
            bufs = dict(start)
            for _ in range(rounds):
                out = ex.run(dict(bufs))
                bufs.update(out)
                bufs["w"] = w
            for k in ("x0", "x1"):
                np.testing.assert_allclose(
                    np.asarray(finals[i][k]), np.asarray(bufs[k]),
                    rtol=2e-4, atol=2e-4)
        m = server.metrics.snapshot()
        assert m["completed"] == n * rounds
        assert m["failed"] == 0


def _chain_oracle(tdg, start, steps, rtol=2e-4):
    """Serial ground truth for a stream: replay ``steps`` times, carrying
    outputs into the same-named input slots between iterations."""
    ex = ReplayExecutor(tdg)
    bufs = dict(start)
    out = {}
    for _ in range(steps):
        out = ex.run(dict(bufs))
        bufs.update({k: v for k, v in out.items() if k in bufs})
    return out


def _assert_stream(out, tdg, start, steps):
    want = _chain_oracle(tdg, start, steps)
    assert set(out) == set(want)
    for k in want:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-4, atol=2e-4)


class TestContinuous:
    """Iteration-level batching: resident per-class batches with tenants
    joining/leaving between fused steps (the tentpole of the serving tier's
    continuous mode)."""

    def test_stream_parity_vs_replay_chain(self):
        w = jnp.asarray(np.random.default_rng(7).standard_normal((6, 6)),
                        jnp.float32)
        server = RegionServer(max_batch=4, continuous=True, autostart=False)
        tenants = []
        for i in range(3):
            tdg = _region(i)
            server.register_tenant(f"t{i}", tdg)
            tenants.append((tdg, _bufs(200 + i, shared_w=w)))
        futs = [server.submit_stream(f"t{i}", b, steps=5)
                for i, (_, b) in enumerate(tenants)]
        server.start()
        outs = [f.result(120) for f in futs]
        server.close()
        for (tdg, b), out in zip(tenants, outs):
            _assert_stream(out, tdg, b, steps=5)

    def test_join_leave_mid_stream_no_retrace(self):
        # Two long streams and two short ones share one resident batch; the
        # short pair retires after step 2 WITHOUT draining the batch, and
        # the shrink must re-slice pooled executables, never retrace.
        w = jnp.asarray(np.random.default_rng(8).standard_normal((6, 6)),
                        jnp.float32)
        server = RegionServer(max_batch=4, continuous=True, autostart=False)
        plans = [4, 4, 2, 2]          # steps per tenant
        tenants = []
        for i, steps in enumerate(plans):
            tdg = _region(i)
            server.register_tenant(f"t{i}", tdg)
            tenants.append((tdg, _bufs(210 + i, shared_w=w), steps))
        futs = [server.submit_stream(f"t{i}", b, steps=s)
                for i, (_, b, s) in enumerate(tenants)]
        server.start()
        outs = [f.result(120) for f in futs]
        server.close()
        for (tdg, b, s), out in zip(tenants, outs):
            _assert_stream(out, tdg, b, steps=s)
        # Execution pattern: 2 full steps at occupancy 4, then 2 at 2.
        trace = server.metrics.trace.snapshot()
        assert [r["occupancy"] for r in trace] == [4, 4, 2, 2]
        assert trace[1]["leaves"] == 2      # short pair retires in place
        assert trace[3]["leaves"] == 2
        m = server.metrics.snapshot()
        assert m["joins"] == 4 and m["leaves"] == 4
        assert m["batches"] == 4
        # ONE batched executable serves every step — churn re-sliced it
        # (misses stay at 1, every later step is a pool hit on the same
        # entry), it did not rebuild.
        pool = server.pool.stats()
        assert pool["misses"] == 1
        assert pool["hits"] == 3
        assert pool["hot"] == [{"kind": "batched", "hits": 3}]

    def test_mid_stream_join_and_early_leave_parity(self):
        # A 3-step stream and a 1-step request admitted at the same
        # boundary: the single rides step 1 of the resident batch and
        # leaves; the stream continues alone. Both match serial oracles.
        w = jnp.eye(6, dtype=jnp.float32)
        server = RegionServer(max_batch=2, continuous=True, autostart=False)
        tdg_a, tdg_b = _region("a"), _region("b")
        server.register_tenant("a", tdg_a)
        server.register_tenant("b", tdg_b)
        ba, bb = _bufs(220, shared_w=w), _bufs(221, shared_w=w)
        fa = server.submit_stream("a", ba, steps=3)
        fb = server.submit("b", bb)
        server.start()
        out_a, out_b = fa.result(120), fb.result(120)
        server.close()
        _assert_stream(out_a, tdg_a, ba, steps=3)
        _check(out_b, tdg_b, bb)
        trace = server.metrics.trace.snapshot()
        assert [r["occupancy"] for r in trace] == [2, 1, 1]
        assert trace[0]["joins"] == 2 and trace[0]["leaves"] == 1

    def test_deterministic_step_boundary_admission(self):
        # All requests queued before start: admission order is a pure
        # function of (FIFO within tier) x (smooth weighted round-robin
        # across tiers), so the trace tier tallies are reproducible.
        w = jnp.eye(6, dtype=jnp.float32)
        server = RegionServer(max_batch=2, continuous=True, autostart=False)
        for i in range(8):
            server.register_tenant(f"t{i}", _region(i), tier=i % 2)
        futs = [server.submit(f"t{i % 8}", _bufs(230 + i, shared_w=w))
                for i in range(24)]
        server.start()
        for f in futs:
            f.result(120)
        server.close()
        trace = server.metrics.trace.snapshot()
        assert len(trace) == 12
        assert all(r["occupancy"] == 2 for r in trace)
        tiers = [r["tiers"] for r in trace]
        # tier-1 holds a 2x admission weight: it is never behind tier-0
        # cumulatively, and drains first, leaving an all-tier-0 tail.
        cum = {"0": 0, "1": 0}
        for t in tiers:
            for k, n in t.items():
                cum[k] += n
            assert cum["1"] >= cum["0"] or cum["1"] == 12
        assert cum == {"0": 12, "1": 12}
        assert tiers[0] == {"0": 1, "1": 1}
        assert tiers[-3:] == [{"0": 2}] * 3     # tier-1 exhausted first

    def test_submit_stream_requires_continuous(self):
        with RegionServer(continuous=False) as server:
            server.register_tenant("a", _region(0))
            with pytest.raises(RuntimeError, match="continuous"):
                server.submit_stream("a", _bufs(0), steps=2)
        with RegionServer(continuous=True) as server:
            server.register_tenant("a", _region(0))
            with pytest.raises(ValueError, match="steps"):
                server.submit_stream("a", _bufs(0), steps=0)

    def test_continuous_stats_flag_and_trace_dump(self, tmp_path):
        with RegionServer(continuous=True) as server:
            server.register_tenant("a", _region(0))
            _check(server.serve("a", _bufs(240)), _region(0), _bufs(240))
            assert server.stats()["continuous"] is True
            path = tmp_path / "trace.json"
            dumped = server.dump_trace(str(path))
        assert path.exists()
        assert dumped["summary"]["steps"] >= 1


class TestQoS:
    """Per-tenant admission shaping: token buckets, priority tiers, and
    tier-aware shedding (compose with the queue bound + deadlines)."""

    def test_token_bucket_accounting_under_burst(self):
        b = TokenBucket(rate=2.0, burst=2.0, now=0.0)
        assert b.take(now=0.0) and b.take(now=0.0)      # burst drains
        assert not b.take(now=0.0)                      # empty
        assert not b.take(now=0.4)                      # 0.8 tokens: < 1
        assert b.take(now=0.5)                          # refilled exactly 1
        assert not b.take(now=0.5)
        assert b.available(now=100.0) == pytest.approx(2.0)   # capped
        assert b.take(n=2, now=100.0)
        with pytest.raises(ValueError):
            TokenBucket(rate=0.0)

    def test_smooth_wrr_is_proportional_and_interleaved(self):
        wrr = SmoothWRR()
        weights = {1: 2, 0: 1}
        picks = [wrr.pick(weights) for _ in range(6)]
        assert picks == [1, 0, 1, 1, 0, 1]
        assert tier_weight(1) == 2 * tier_weight(0)

    def test_rate_limited_is_typed_and_counted(self):
        server = RegionServer(continuous=True, autostart=False)
        server.register_tenant("a", _region(0), rate=1.0)   # burst of 1
        fut = server.submit("a", _bufs(0))
        with pytest.raises(RateLimited, match="rate limit"):
            server.submit("a", _bufs(1))
        server.start()
        fut.result(120)
        server.close()
        m = server.metrics.snapshot()
        assert m["rate_limited"] == 1
        assert m["completed"] == 1

    def test_low_tier_shed_first_at_queue_bound(self):
        # Queue at its bound, all waiters tier-0: a tier-1 arrival evicts
        # the NEWEST low-tier waiter instead of being refused; a further
        # tier-0 arrival (nothing lower to evict) is refused outright.
        w = jnp.eye(6, dtype=jnp.float32)
        server = RegionServer(max_batch=8, continuous=True, autostart=False,
                              queue_bound=4)
        server.register_tenant("low", _region("lo"), tier=0)
        server.register_tenant("high", _region("hi"), tier=1)
        low_futs = [server.submit("low", _bufs(300 + i, shared_w=w))
                    for i in range(4)]
        high_fut = server.submit("high", _bufs(310, shared_w=w))
        with pytest.raises(QueueFull, match="tier-1"):
            low_futs[-1].result(1)          # newest low waiter was shed
        with pytest.raises(QueueFull):
            server.submit("low", _bufs(311, shared_w=w))
        server.start()
        for f in low_futs[:-1] + [high_fut]:
            f.result(120)
        server.close()
        m = server.metrics.snapshot()
        assert m["shed"] == 2               # the victim + the refusal
        assert m["completed"] == 4

    def test_qos_env_defaults(self, monkeypatch):
        monkeypatch.setenv("REPRO_TENANT_TIER", "a=2,*=0")
        monkeypatch.setenv("REPRO_TENANT_RATE", "a=5,*=0")
        with RegionServer(autostart=False) as server:
            ta = server.register_tenant("a", _region(0))
            tb = server.register_tenant("b", _region(1))
        assert ta.tier == 2 and ta.rate == 5.0 and ta.bucket is not None
        assert tb.tier == 0 and tb.rate == 0.0 and tb.bucket is None

    def test_typed_errors_cross_the_wire_by_name(self):
        from repro.serving.server import DeadlineExceeded
        assert rpc.wire_error_class("RateLimited: tenant 'a' ...") \
            is RateLimited
        assert rpc.wire_error_class("QueueFull: bound") is QueueFull
        assert rpc.wire_error_class("DeadlineExceeded: late") \
            is DeadlineExceeded
        assert rpc.wire_error_class("ValueError: nope") is None
        assert rpc.wire_error_class("no colon here") is None
