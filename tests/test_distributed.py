"""Multi-device tests (subprocess-isolated: device count is process-global,
and the main pytest process must stay single-device)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 4, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_pipeline_parallel_forward_backward():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ("stage",), devices=jax.devices()[:4])
S, M, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (S, d, d)) * 0.3
xs = jax.random.normal(jax.random.fold_in(key, 2), (M, mb, d))
stage_fn = lambda W, x: jnp.tanh(x @ W)
out = pipeline_apply(stage_fn, Ws, xs, mesh)
ref = xs
for s in range(S): ref = jnp.tanh(ref @ Ws[s])
np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
g = jax.grad(lambda W: (pipeline_apply(stage_fn, W, xs, mesh) ** 2).sum())(Ws)
def lref(W):
    r = xs
    for s in range(S): r = jnp.tanh(r @ W[s])
    return (r ** 2).sum()
np.testing.assert_allclose(g, jax.grad(lref)(Ws), atol=1e-4, rtol=1e-4)
print("OK")
""")


def test_moe_shard_map_equals_gspmd():
    _run("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models import moe as MoE
from repro.sharding import partition as P_
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
cfg = reduced(get_config("qwen3-moe-30b-a3b"))
key = jax.random.PRNGKey(0)
p = MoE.moe_init(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, cfg.d_model), jnp.float32)
out_ref, _ = MoE.moe_apply_gspmd(p, cfg, x)
cfg_sm = dataclasses.replace(cfg, moe_impl="shard_map")
with P_.use_mesh(mesh):
    p_d = jax.device_put(p, P_.param_shardings(p, mesh))
    x_d = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    out_sm, _ = jax.jit(lambda p_, x_: MoE.moe_apply(p_, cfg_sm, x_))(p_d, x_d)
np.testing.assert_allclose(np.asarray(out_sm), np.asarray(out_ref),
                           atol=2e-4, rtol=2e-3)
print("OK")
""")


def test_train_step_on_2x2_mesh():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.optim import adamw
from repro.sharding import partition as P_
from repro.training import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
cfg = reduced(get_config("glm4-9b"), d_model=64, num_heads=4, head_dim=16)
opt = adamw(1e-3)
with P_.use_mesh(mesh):
    params = init_params(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params, P_.param_shardings(params, mesh))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    toks = jax.device_put(jnp.full((4, 16), 3, jnp.int32),
                          NamedSharding(mesh, P("data", None)))
    p2, s2, m = step(params, state, {"tokens": toks})
    assert np.isfinite(float(m["loss"]))
print("OK")
""")


@pytest.mark.slow
def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery end-to-end on a tiny in-process mesh.

    Heaviest single test in the suite (~35s: two full model lowerings in a
    subprocess) — behind the ``slow`` marker; run with ``-m slow``."""
    _run("""
import jax
from repro.launch.dryrun import lower_cell
mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
r = lower_cell("qwen2.5-3b", "train_4k", mesh=mesh, save=False)
assert r["roofline"]["hlo_flops_per_device"] > 0
assert r["cost_mode"] == "extrapolated_exact"
r2 = lower_cell("mamba2-370m", "long_500k", mesh=mesh, save=False)
assert r2["kind"] == "decode"
r3 = lower_cell("qwen2.5-3b", "long_500k", mesh=mesh, save=False)
assert "skipped" in r3
print("OK")
""", timeout=420)
