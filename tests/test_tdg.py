"""Core TDG structure: dependency semantics, graph invariants."""
import jax.numpy as jnp
import pytest

from repro.core import (TDG, DependencyTable, EdgeKind, critical_path,
                        parallelism, topo_order, topo_waves,
                        round_robin_assign, validate_execution_order)


def _noop(*xs):
    return xs[0] if len(xs) == 1 else xs


class TestDependencySemantics:
    def test_raw(self):
        tdg = TDG()
        a = tdg.add_task(_noop, outs=["x"])
        b = tdg.add_task(_noop, ins=["x"], outs=["y"])
        assert tdg.preds[b.tid] == {a.tid}
        assert tdg.edges[0].kind == EdgeKind.RAW

    def test_war(self):
        tdg = TDG()
        r = tdg.add_task(_noop, ins=["x"], outs=["y"])
        w = tdg.add_task(_noop, outs=["x"])             # pure anti-dep on x
        kinds = {(e.src, e.dst): e.kind for e in tdg.edges}
        assert kinds[(r.tid, w.tid)] == EdgeKind.WAR

    def test_edge_dedup_one_edge_per_pair(self):
        tdg = TDG()
        a = tdg.add_task(_noop, outs=["x", "y"])
        b = tdg.add_task(_noop, ins=["x", "y"], outs=["x"])  # RAW+RAW+WAW
        assert len([e for e in tdg.edges
                    if (e.src, e.dst) == (a.tid, b.tid)]) == 1

    def test_waw(self):
        tdg = TDG()
        a = tdg.add_task(_noop, outs=["x"])
        b = tdg.add_task(_noop, outs=["x"])
        kinds = {(e.src, e.dst): e.kind for e in tdg.edges}
        assert kinds[(a.tid, b.tid)] == EdgeKind.WAW

    def test_inout_chains(self):
        tdg = TDG()
        for i in range(5):
            tdg.add_task(_noop, inouts=["x"])
        order = topo_order(tdg)
        assert order == list(range(5))
        assert len(topo_waves(tdg)) == 5

    def test_independent_tasks_one_wave(self):
        tdg = TDG()
        for i in range(8):
            tdg.add_task(_noop, inouts=[f"x{i}"])
        waves = topo_waves(tdg)
        assert len(waves) == 1 and len(waves[0]) == 8
        assert tdg.roots() == list(range(8))

    def test_dep_table_never_freed(self):
        # paper 4.3.2: edges to long-finished tasks still resolve
        t = DependencyTable()
        t.resolve(0, [], ["x"])
        for i in range(1, 100):
            t.resolve(i, [], [f"y{i}"])
        edges = t.resolve(100, ["x"], [])
        assert edges and edges[0].src == 0

    def test_region_io_slots(self):
        tdg = TDG()
        tdg.add_task(_noop, ins=["a"], outs=["b"])
        tdg.add_task(_noop, ins=["b", "c"], outs=["d"])
        assert tdg.input_slots == ["a", "c"]
        assert set(tdg.output_slots) == {"b", "d"}


class TestSchedules:
    def _diamond(self):
        tdg = TDG()
        tdg.add_task(_noop, outs=["a"])                    # 0
        tdg.add_task(_noop, ins=["a"], outs=["b"])         # 1
        tdg.add_task(_noop, ins=["a"], outs=["c"])         # 2
        tdg.add_task(_noop, ins=["b", "c"], outs=["d"])    # 3
        return tdg

    def test_diamond_waves(self):
        waves = topo_waves(self._diamond())
        assert waves == [[0], [1, 2], [3]]

    def test_critical_path_and_parallelism(self):
        tdg = self._diamond()
        assert critical_path(tdg) == 3.0
        assert parallelism(tdg) == pytest.approx(4 / 3)

    def test_round_robin(self):
        q = round_robin_assign(list(range(10)), 4)
        assert [len(x) for x in q] == [3, 3, 2, 2]
        assert sorted(sum(q, [])) == list(range(10))

    def test_order_validation(self):
        tdg = self._diamond()
        assert validate_execution_order(tdg, [0, 1, 2, 3])
        assert validate_execution_order(tdg, [0, 2, 1, 3])
        assert not validate_execution_order(tdg, [1, 0, 2, 3])
        assert not validate_execution_order(tdg, [0, 1, 2])

    def test_cycle_rejected(self):
        tdg = self._diamond()
        from repro.core.tdg import Edge
        tdg.edges.append(Edge(3, 0, EdgeKind.RAW, "d"))
        tdg.preds[0].add(3)
        tdg.succs[3].add(0)
        with pytest.raises(ValueError):
            topo_order(tdg)
