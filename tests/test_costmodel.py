"""Cost-model-driven adaptive fusion: probing, decisions, caches, buckets.

The invariants that make adaptivity safe to ship:

* every ``cost_analysis`` shape jax has ever returned (and every failure)
  degrades to None / UNMEASURED — never an exception, never a lie;
* the decision matrix is exactly the documented policy, and an unmeasured
  payload always falls back to the static vmap plan;
* different batcher *plans* never share an interned executable, while the
  ``REPRO_ADAPTIVE=0`` kill switch makes "auto" share the static entry;
* adaptive replay is bit-exact against static replay (the model picks
  where a class computes, never what);
* bucket fitting is the exact pad-minimizing DP, and the tuner respects
  its retrace budget and the kill switch.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TDG, ReplayExecutor, clear_intern_cache, fusion_plan,
                        intern_stats, lower_tdg)
from repro.core import costmodel as cm
from repro.core import lower as lower_mod
from repro.serving import RegionServer, WarmPool
from repro.serving.pool import PoolEntry

f32 = jnp.float32


# ------------------------------------------------- capture_cost_analysis

class _Compiled:
    """Fake jax.stages.Compiled returning a canned cost_analysis."""

    def __init__(self, result=None, raises=False):
        self._result, self._raises = result, raises

    def cost_analysis(self):
        if self._raises:
            raise RuntimeError("no analysis on this backend")
        return self._result


class TestCaptureCostAnalysis:
    def test_reexported_into_lower(self):
        # tests/serialize reach it as lower._capture_cost_analysis; the
        # canonical def moved to costmodel but the old name must keep working.
        assert lower_mod._capture_cost_analysis is cm.capture_cost_analysis

    def test_raising_backend_degrades_to_none(self):
        assert cm.capture_cost_analysis(_Compiled(raises=True)) is None

    def test_none_and_empty_shapes_degrade_to_none(self):
        assert cm.capture_cost_analysis(_Compiled(None)) is None
        assert cm.capture_cost_analysis(_Compiled([])) is None
        assert cm.capture_cost_analysis(_Compiled(())) is None
        assert cm.capture_cost_analysis(_Compiled({})) is None

    def test_list_of_dict_unwraps(self):
        got = cm.capture_cost_analysis(_Compiled([{"flops": 8.0}]))
        assert got == {"flops": 8.0}

    def test_plain_dict_passes_through(self):
        got = cm.capture_cost_analysis(_Compiled({"bytes accessed": 64.0}))
        assert got == {"bytes accessed": 64.0}

    def test_dict_like_converts(self):
        ca = collections.OrderedDict(flops=2.0)
        assert cm.capture_cost_analysis(_Compiled(ca)) == {"flops": 2.0}

    def test_unconvertible_degrades_to_none(self):
        assert cm.capture_cost_analysis(_Compiled(object())) is None


# --------------------------------------------------------- decision matrix

def _cost(flops, nbytes):
    return cm.ClassCost(flops=flops, bytes_accessed=nbytes)


class TestDecide:
    def setup_method(self):
        self.m = cm.CostModel()   # default thresholds

    def test_unmeasured_falls_back_to_vmap(self):
        d = self.m.decide(cm.UNMEASURED, size=8)
        assert d.batcher == "vmap" and "unmeasured" in d.reason

    def test_below_breakeven_unrolls(self):
        # 8 members x 4 flops = 32 << 256
        d = self.m.decide(_cost(4.0, 16.0), size=8)
        assert d.batcher == "unrolled" and "break-even" in d.reason

    def test_memory_bound_cache_resident_member_maps(self):
        # intensity 0.25, member 256KB <= 512KB, batch 2MB >= 128KB
        d = self.m.decide(_cost(64e3, 256 * 1024), size=8)
        assert d.batcher == "map"

    def test_memory_bound_huge_member_stays_vmap(self):
        # intensity low but member 2MB can never be cache-resident
        d = self.m.decide(_cost(256e3, 2 * 1024 * 1024), size=8)
        assert d.batcher == "vmap" and "too large" in d.reason

    def test_memory_bound_tiny_batch_stays_vmap(self):
        # whole batch (8 x 4KB = 32KB) fits in cache: fused vmap wins
        d = self.m.decide(_cost(1e3, 4 * 1024), size=8)
        assert d.batcher == "vmap" and "cache-resident" in d.reason

    def test_compute_bound_vmaps(self):
        d = self.m.decide(_cost(1e6, 1e4), size=8)   # 100 flops/B
        assert d.batcher == "vmap" and "compute-bound" in d.reason

    def test_describe_carries_the_numbers(self):
        rec = self.m.decide(_cost(64e3, 256 * 1024), size=8).describe()
        assert rec["flops"] == 64e3 and rec["bytes"] == 256 * 1024
        assert rec["intensity"] == pytest.approx(0.2441, abs=1e-3)


class TestProbe:
    def test_real_matmul_measures_positive_cost(self):
        m = cm.CostModel()
        spec = jax.ShapeDtypeStruct((32, 32), f32)
        cost = m.measure(lambda a, b: a @ b, [spec, spec])
        assert cost.source == "measured"
        assert cost.flops and cost.flops > 0
        assert cost.bytes_accessed and cost.bytes_accessed > 0
        assert cost.intensity and cost.intensity > 0

    def test_probe_cached_per_payload_and_signature(self):
        m = cm.CostModel()
        fn = lambda x: x * 2.0                                    # noqa: E731
        spec = jax.ShapeDtypeStruct((8,), f32)
        m.measure(fn, [spec])
        m.measure(fn, [spec])
        assert m.probes == 1
        m.measure(fn, [jax.ShapeDtypeStruct((16,), f32)])
        assert m.probes == 2

    def test_probe_failure_degrades_to_unmeasured(self):
        m = cm.CostModel()

        def boom(x):
            raise ValueError("untraceable")

        cost = m.measure(boom, [jax.ShapeDtypeStruct((4,), f32)])
        assert cost is cm.UNMEASURED
        assert m.probe_failures == 1

    def test_negative_flops_sentinel_normalized_to_unmeasured(self):
        # CPU triangular solve is the real-world producer of XLA's -1
        # "unknown flops" sentinel; the probe must not treat it as "free".
        m = cm.CostModel()
        a = jax.ShapeDtypeStruct((8, 8), f32)
        b = jax.ShapeDtypeStruct((8, 8), f32)

        def trsm(l, x):
            return jax.scipy.linalg.solve_triangular(l, x, lower=True)

        cost = m.measure(trsm, [a, b])
        assert cost.flops is None       # never negative, never a lie
        # whatever bytes say, an unknown-flops payload must not unroll
        assert m.decide(cost, size=8).batcher == "vmap"


# ------------------------------------------------- plan keys + kill switch

class TestPlanKey:
    def test_static_plans_pass_through(self):
        assert cm.plan_key("vmap") == "vmap"
        assert cm.plan_key("map") == "map"

    def test_adaptive_plan_carries_threshold_fingerprint(self):
        key = cm.plan_key("auto")
        assert key == f"auto/{cm.default_model().fingerprint()}"

    def test_kill_switch_collapses_auto_to_vmap(self, monkeypatch):
        monkeypatch.setenv(cm.ADAPTIVE_ENV, "0")
        assert cm.resolve_batcher("auto") == "vmap"
        assert cm.plan_key("auto") == "vmap"
        monkeypatch.setenv(cm.ADAPTIVE_ENV, "1")
        assert cm.resolve_batcher("auto") == "auto"

    def test_invalid_args_are_loud(self):
        with pytest.raises(ValueError, match="batcher"):
            cm.resolve_batcher("scan")
        with pytest.raises(ValueError, match="adaptive"):
            cm.adaptive_enabled("maybe")


def _grid_tdg(n_tasks=6, dim=16):
    tdg = TDG("cmgrid")

    def body(x):
        return jnp.tanh(x @ x.T) + x

    for t in range(n_tasks):
        tdg.add_task(body, inouts=[f"x{t}"], name=f"t{t}")
    rng = np.random.default_rng(7)
    bufs = {f"x{t}": jnp.asarray(rng.standard_normal((dim, dim)), f32)
            for t in range(n_tasks)}
    return tdg, bufs


class TestInternIsolation:
    def test_each_plan_gets_its_own_entry(self):
        tdg, bufs = _grid_tdg()
        clear_intern_cache()
        outs = {}
        for b in ("vmap", "map", "auto"):
            outs[b] = lower_tdg(tdg, batcher=b)(bufs)
        stats = intern_stats()
        assert stats["misses"] == 3 and stats["entries"] == 3
        # same structure re-lowered under each plan hits its own entry
        for b in ("vmap", "map", "auto"):
            lower_tdg(tdg, batcher=b)
        assert intern_stats()["hits"] == 3
        for b in ("map", "auto"):   # and the plans agree bit-exactly
            for k in outs["vmap"]:
                np.testing.assert_array_equal(np.asarray(outs["vmap"][k]),
                                              np.asarray(outs[b][k]))

    def test_kill_switch_shares_the_static_entry(self, monkeypatch):
        tdg, _ = _grid_tdg()
        clear_intern_cache()
        monkeypatch.setenv(cm.ADAPTIVE_ENV, "0")
        lower_tdg(tdg, batcher="vmap")
        lower_tdg(tdg, batcher="auto")     # resolves to the SAME plan
        stats = intern_stats()
        assert stats["misses"] == 1 and stats["hits"] == 1
        assert stats["entries"] == 1


# ----------------------------------------------- adaptive plan end to end

def _mixed_tdg():
    """One region spanning all three batcher outcomes in a single wave."""
    tdg = TDG("mixed")

    def mm(a, w):
        return a @ w

    def relax(x):
        return 0.25 * (jnp.roll(x, 1, 0) + jnp.roll(x, -1, 0)
                       + jnp.roll(x, 1, 1) + jnp.roll(x, -1, 1))

    def nudge(x):
        return x + 0.5

    for i in range(4):
        tdg.add_task(mm, ins=[f"a{i}", "w"], outs=[f"y{i}"])
        tdg.add_task(relax, ins=[f"h{i}"], outs=[f"g{i}"])
        tdg.add_task(nudge, ins=[f"s{i}"], outs=[f"t{i}"])
    rng = np.random.default_rng(3)
    bufs = {}
    for i in range(4):
        bufs[f"a{i}"] = jnp.asarray(rng.standard_normal((64, 64)), f32)
        bufs[f"h{i}"] = jnp.asarray(rng.standard_normal((128, 128)), f32)
        bufs[f"s{i}"] = jnp.asarray(rng.standard_normal((2,)), f32)
    bufs["w"] = jnp.asarray(rng.standard_normal((64, 64)), f32)
    return tdg, bufs


class TestAdaptivePlan:
    def test_mixed_region_decisions_and_summary(self):
        tdg, bufs = _mixed_tdg()
        plan = fusion_plan(tdg, buffers=bufs, batcher="auto")
        by_batcher = {d["batcher"]: d for d in plan.summary()["decisions"]}
        assert set(by_batcher) == {"vmap", "map", "unrolled"}
        mm_d = by_batcher["vmap"]
        assert mm_d["flops"] > 0 and mm_d["intensity"] >= cm.DEFAULT_RIDGE
        st_d = by_batcher["map"]
        assert 0 < st_d["intensity"] < cm.DEFAULT_RIDGE
        assert st_d["bytes"] <= cm.DEFAULT_MAP_MEMBER_BYTES
        summary = plan.summary()
        assert summary["batchers"] == {"vmap": 1, "map": 1}
        assert "padded_lanes" in summary and "pad_fraction" in summary

    def test_adaptive_replay_bit_exact_vs_static(self):
        tdg, bufs = _mixed_tdg()
        out_static = ReplayExecutor(tdg, batcher="vmap").run(dict(bufs))
        out_auto = ReplayExecutor(tdg, batcher="auto").run(dict(bufs))
        assert set(out_static) == set(out_auto)
        for k in out_static:
            np.testing.assert_array_equal(np.asarray(out_static[k]),
                                          np.asarray(out_auto[k]))

    def test_executor_plan_key_is_pinned_at_construction(self, monkeypatch):
        tdg, _ = _mixed_tdg()
        ex = ReplayExecutor(tdg, batcher="auto")
        assert ex.plan_key.startswith("auto/")
        monkeypatch.setenv(cm.ADAPTIVE_ENV, "0")
        assert ReplayExecutor(tdg, batcher="auto").plan_key == "vmap"


# ------------------------------------------------------- bucket boundaries

class TestFitBoundaries:
    def test_exact_fit_on_skewed_modes(self):
        hist = {5: 40, 12: 30, 3: 10, 16: 5}
        bounds = cm.fit_boundaries(hist, max_buckets=8)
        assert bounds == [3, 5, 12, 16]     # zero pad lanes is achievable

    def test_max_included_and_budget_respected(self):
        hist = {3: 1, 5: 1, 7: 1, 9: 1, 11: 1}
        bounds = cm.fit_boundaries(hist, max_buckets=2)
        assert len(bounds) <= 2 and bounds[-1] == 11

    def test_single_bucket_is_the_max(self):
        assert cm.fit_boundaries({4: 10, 7: 1}, max_buckets=1) == [7]

    def test_sub_floor_occupancies_ignored(self):
        assert cm.fit_boundaries({1: 100, 4: 1}, max_buckets=8) == [4]
        assert cm.fit_boundaries({1: 100}, max_buckets=8) == []
        assert cm.fit_boundaries({}, max_buckets=8) == []

    def test_never_beaten_by_pow2(self):
        # the DP is exact: pad under fitted <= pad under pow-2, always
        rng = np.random.default_rng(11)
        for _ in range(10):
            hist = {int(v): int(c) for v, c in zip(
                rng.integers(2, 17, size=5), rng.integers(1, 20, size=5))}

            def bill(bounds):
                total = 0
                for occ, cnt in hist.items():
                    b = next(x for x in sorted(bounds) + [32] if x >= occ)
                    total += cnt * (b - occ)
                return total

            fitted = cm.fit_boundaries(hist, max_buckets=8)
            assert bill(fitted) <= bill(cm.pow2_boundaries(16))


class TestBucketTuner:
    def test_static_tuner_keeps_pow2(self):
        t = cm.BucketTuner(16, adaptive=False, window=4)
        for _ in range(32):
            assert t.observe(5) is False
        assert t.boundaries == cm.pow2_boundaries(16)
        assert t.bucket_for(5) == 8 and t.retunes == 0

    def test_adaptive_tuner_refits_on_window(self):
        t = cm.BucketTuner(16, adaptive=True, window=4)
        changed = [t.observe(5) for _ in range(4)]
        assert changed == [False, False, False, True]
        assert t.boundaries == [5]
        assert t.bucket_for(5) == 5     # pad lanes gone
        assert t.bucket_for(9) == 10    # past the ladder: pow-2 extension
        assert t.retunes == 1 and t.new_buckets_spent == 1

    def test_retrace_budget_freezes_boundaries(self):
        t = cm.BucketTuner(16, adaptive=True, window=4, max_new_buckets=1)
        for _ in range(4):
            t.observe(5)
        assert t.boundaries == [5] and t.new_buckets_spent == 1
        for _ in range(8):              # budget spent: no further retunes
            assert t.observe(3) is False
        assert t.boundaries == [5] and t.retunes == 1

    def test_groups_of_one_never_observed(self):
        t = cm.BucketTuner(16, adaptive=True, window=2)
        assert t.observe(1) is False and t.observations == 0
        assert t.bucket_for(1) == 1

    def test_summary_names_the_numbers(self):
        t = cm.BucketTuner(8, adaptive=True, window=64)
        for _ in range(3):
            t.observe(3)
        s = t.summary()
        assert s["observations"] == 3 and s["histogram"] == {"3": 3}
        assert s["pad_lanes"] == 3      # 3 pads up to pow-2 bucket 4
        assert 0 < s["pad_fraction"] < 1


# ---------------------------------------------------- serving-tier wiring

class TestPoolInvalidate:
    def test_invalidate_counts_and_filters_by_kind(self):
        pool = WarmPool(capacity=8)
        pool.put(("a",), PoolEntry(kind="single", fn=lambda: None))
        pool.put(("b",), PoolEntry(kind="batched", fn=lambda: None))
        pool.put(("c",), PoolEntry(kind="batched", fn=lambda: None))
        n = pool.invalidate(lambda k, e: e.kind == "batched")
        assert n == 2
        stats = pool.stats()
        assert stats["invalidations"] == 2 and stats["entries"] == 1
        assert pool.get(("a",)) is not None


class TestServerAdaptiveBuckets:
    def test_bucket_retune_invalidates_and_stops_padding(self):
        n = 3
        server = RegionServer(max_batch=8, max_wait_ms=500, autostart=False,
                              adaptive=True)
        # Small window so the refit fires within the test instead of at 64.
        server.buckets = cm.BucketTuner(server.max_batch, adaptive=True,
                                        window=3)
        w = jnp.eye(6, dtype=f32)

        def body(x, w):
            return jnp.tanh(x @ w) * 0.5 + x

        def region(i):
            # ONE shared payload across tenants: identical structure is what
            # makes the requests coalesce into occupancy-n batched groups.
            tdg = TDG(f"ab[{i}]")
            for s in range(2):
                tdg.add_task(body, ins=[f"x{s}", "w"], outs=[f"x{s}"])
            return tdg

        tdgs = [region(i) for i in range(n)]
        for i, tdg in enumerate(tdgs):
            server.register_tenant(f"t{i}", tdg)

        def round_(seed):
            rng = np.random.default_rng(seed)
            bufs = [{**{f"x{s}": jnp.asarray(
                rng.standard_normal((6, 6)), f32) for s in range(2)},
                "w": w} for _ in range(n)]
            futs = [server.submit(f"t{i}", b) for i, b in enumerate(bufs)]
            if seed == 0:
                server.start()
            outs = [f.result(120) for f in futs]
            for tdg, b, out in zip(tdgs, bufs, outs):
                want = ReplayExecutor(tdg).run(dict(b))
                for k in want:
                    np.testing.assert_allclose(
                        np.asarray(out[k]), np.asarray(want[k]),
                        rtol=2e-5, atol=2e-5)

        for seed in range(5):
            round_(seed)
        stats = server.stats()
        server.close()
        assert stats["adaptive"] is True
        buckets = stats["buckets"]
        # occupancy-3 groups padded to pow-2 bucket 4 until the window-3
        # refit landed a boundary at 3; after that, zero pad.
        assert buckets["retunes"] >= 1 and 3 in buckets["boundaries"]
        assert buckets["observations"] == 5
        m = stats["metrics"]
        assert m["pad_lanes"] >= 1 and m["bucket_retunes"] >= 1
        assert 0 <= m["pad_fraction"] < 1
        assert stats["pool"]["invalidations"] >= 1

    def test_adaptive_false_pins_pow2(self):
        with RegionServer(adaptive=False, autostart=False) as server:
            assert server.adaptive is False
            assert server.buckets.adaptive is False
            assert server.buckets.boundaries == cm.pow2_boundaries(
                server.max_batch)
