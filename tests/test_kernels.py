"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes.

The broad shape/dtype sweeps carry the ``slow`` marker (deselected from the
default tier-1 run; opt in with ``-m slow``) — fast single-case coverage of
every kernel stays here and in tests/test_registry.py::TestParityFast."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention, grouped_matmul, ref, rmsnorm,
                           ssd)
from repro.kernels import xla_attention as X

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _arr(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


class TestFlashAttention:
    @pytest.mark.slow
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("seq,hq,hkv,d", [
        (128, 4, 4, 64),       # MHA
        (256, 8, 2, 64),       # GQA
        (256, 4, 1, 128),      # MQA
        (100, 4, 2, 64),       # ragged tail
    ])
    def test_causal(self, rng, seq, hq, hkv, d, dtype):
        q = _arr(rng, 2, seq, hq, d, dtype=dtype)
        k = _arr(rng, 2, seq, hkv, d, dtype=dtype)
        v = _arr(rng, 2, seq, hkv, d, dtype=dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=ATOL[dtype], rtol=ATOL[dtype])

    @pytest.mark.slow
    @pytest.mark.parametrize("window", [32, 64, 100])
    def test_sliding_window(self, rng, window):
        q = _arr(rng, 1, 256, 4, 64)
        k = _arr(rng, 1, 256, 2, 64)
        v = _arr(rng, 1, 256, 2, 64)
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.slow
    @pytest.mark.parametrize("chunk", [64, 128])
    def test_chunked_local(self, rng, chunk):
        q = _arr(rng, 1, 256, 4, 64)
        k = _arr(rng, 1, 256, 2, 64)
        v = _arr(rng, 1, 256, 2, 64)
        out = flash_attention(q, k, v, causal=True, chunk=chunk,
                              interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, chunk=chunk)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_cross_attention(self, rng):
        q = _arr(rng, 2, 64, 4, 64)
        k = _arr(rng, 2, 200, 2, 64)
        v = _arr(rng, 2, 200, 2, 64)
        out = flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_decode_offset(self, rng):
        S = 128
        q = _arr(rng, 2, 1, 4, 64)
        k = _arr(rng, 2, S, 2, 64)
        v = _arr(rng, 2, S, 2, 64)
        out = flash_attention(q, k, v, causal=True, q_offset=S - 1,
                              interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, q_offset=S - 1)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.slow
    def test_block_skip_equals_masked(self, rng):
        """Block-skipping (pl.when) must not change results vs full mask."""
        q = _arr(rng, 1, 512, 2, 64)
        k = _arr(rng, 1, 512, 2, 64)
        v = _arr(rng, 1, 512, 2, 64)
        a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
        b = flash_attention(q, k, v, causal=True, block_q=256, block_k=256,
                            interpret=True)
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


class TestXLAAttention:
    @pytest.mark.parametrize("fn,kw", [
        (X.sdpa_full, {}),
        (X.sdpa_sliding, {"window": 64}),
        (X.sdpa_chunked, {"chunk": 64}),
    ])
    def test_matches_oracle(self, rng, fn, kw):
        q = _arr(rng, 2, 256, 4, 32)
        k = _arr(rng, 2, 256, 2, 32)
        v = _arr(rng, 2, 256, 2, 32)
        out = fn(q, k, v, **kw)
        want = ref.attention_ref(q, k, v, causal=True, **kw)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_qchunk_invariance(self, rng):
        q = _arr(rng, 1, 256, 2, 32)
        k = _arr(rng, 1, 256, 1, 32)
        v = _arr(rng, 1, 256, 1, 32)
        a = X.sdpa_full(q, k, v, chunk=32)
        b = X.sdpa_full(q, k, v, chunk=256)
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


class TestSSD:
    @pytest.mark.slow
    @pytest.mark.parametrize("S,H,P,G,N,chunk", [
        (128, 2, 32, 1, 16, 32),
        (256, 4, 64, 2, 32, 64),
        (64, 2, 16, 1, 64, 64),    # single chunk
    ])
    def test_chunked_matches_sequential(self, rng, S, H, P, G, N, chunk):
        x = _arr(rng, 2, S, H, P)
        dt = jnp.abs(_arr(rng, 2, S, H)) * 0.1 + 0.01
        A = -jnp.abs(_arr(rng, H)) - 0.1
        Bm = _arr(rng, 2, S, G, N, scale=0.5)
        Cm = _arr(rng, 2, S, G, N, scale=0.5)
        D = _arr(rng, H)
        y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D=D)
        y_c, h_c = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D=D, chunk=chunk)
        np.testing.assert_allclose(y_c, y_ref, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(h_c, h_ref, atol=1e-3, rtol=1e-3)
        y_p, h_p = ssd(x, dt, A, Bm, Cm, D=D, chunk=chunk, interpret=True)
        np.testing.assert_allclose(y_p, y_ref, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(h_p, h_ref, atol=1e-3, rtol=1e-3)

    def test_state_chaining_matches_decode(self, rng):
        """Chunked prefill state -> sequential decode == one long pass."""
        S, H, P, G, N = 96, 2, 16, 1, 8
        x = _arr(rng, 1, S, H, P)
        dt = jnp.abs(_arr(rng, 1, S, H)) * 0.1 + 0.01
        A = -jnp.abs(_arr(rng, H)) - 0.1
        Bm = _arr(rng, 1, S, G, N, scale=0.5)
        Cm = _arr(rng, 1, S, G, N, scale=0.5)
        y_all, h_all = ref.ssd_ref(x, dt, A, Bm, Cm)
        cut = 64
        _, h1 = ssd(x[:, :cut], dt[:, :cut], A, Bm[:, :cut], Cm[:, :cut],
                    chunk=32, interpret=True)
        ys = []
        h = h1
        for t in range(cut, S):
            y_t, h = ref.ssd_ref(x[:, t:t + 1], dt[:, t:t + 1], A,
                                 Bm[:, t:t + 1], Cm[:, t:t + 1],
                                 init_state=h)
            ys.append(y_t)
        np.testing.assert_allclose(jnp.concatenate(ys, 1), y_all[:, cut:],
                                   atol=1e-3, rtol=1e-3)


class TestGroupedMatmul:
    @pytest.mark.slow
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("E,C,d,f", [
        (4, 64, 128, 128), (2, 100, 256, 128), (8, 32, 128, 256),
    ])
    def test_matches_einsum(self, rng, E, C, d, f, dtype):
        x = _arr(rng, E, C, d, dtype=dtype, scale=0.3)
        w = _arr(rng, E, d, f, dtype=dtype, scale=0.3)
        out = grouped_matmul(x, w, interpret=True)
        want = ref.grouped_matmul_ref(x, w)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=ATOL[dtype] * d, rtol=ATOL[dtype])


class TestRMSNorm:
    @pytest.mark.slow
    @pytest.mark.parametrize("shape", [(4, 17, 64), (1, 8, 512), (128, 256)])
    @pytest.mark.parametrize("residual", [False, True])
    def test_matches_oracle(self, rng, shape, residual):
        x = _arr(rng, *shape)
        w = _arr(rng, shape[-1])
        r = _arr(rng, *shape) if residual else None
        out = rmsnorm(x, w, residual=r, interpret=True)
        want = ref.rmsnorm_ref(x, w, residual=r)
        np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)
