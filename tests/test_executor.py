"""Eager (dynamic scheduler) vs replay (fused) equivalence + scheduler
policy behavior — the heart of the paper's claim: same results, no
per-task orchestration on replay."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TDG, EagerExecutor, ReplayExecutor, list_schedule,
                        lower_tdg, topo_waves)


def _listing1(series: int, tasks: int) -> TDG:
    """Paper Listing 1: `series` waves of `tasks` independent chains."""
    tdg = TDG("listing1")

    def fn(x):
        return x * 1.0001 + 1.0

    for s in range(series):
        for t in range(tasks):
            tdg.add_task(fn, inouts=[f"x{t}"], name=f"t{s}.{t}")
    return tdg


def _bufs(tasks: int):
    return {f"x{t}": jnp.float32(t) for t in range(tasks)}


class TestEquivalence:
    @pytest.mark.parametrize("central", [False, True])
    @pytest.mark.parametrize("workers", [1, 3, 8])
    def test_eager_matches_replay(self, central, workers):
        tdg = _listing1(3, 5)
        r1 = EagerExecutor(tdg, n_workers=workers,
                           central_queue=central).run(_bufs(5))
        r2 = ReplayExecutor(tdg).run(_bufs(5))
        for k in r2:
            np.testing.assert_allclose(r1[k], r2[k], rtol=1e-6)

    def test_matmul_dag(self, rng):
        tdg = TDG("mm")
        tdg.add_task(lambda a, b: a @ b, ins=["a", "b"], outs=["ab"])
        tdg.add_task(lambda a: a.T, ins=["a"], outs=["at"])
        tdg.add_task(lambda ab, at: ab + at, ins=["ab", "at"], outs=["out"])
        bufs = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
                "b": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
        r1 = EagerExecutor(tdg, 2).run(dict(bufs))
        r2 = ReplayExecutor(tdg).run(dict(bufs))
        np.testing.assert_allclose(r1["out"], r2["out"], rtol=1e-6)

    def test_grad_through_lowered(self):
        tdg = TDG("g")
        tdg.add_task(lambda x: x * 2.0, ins=["x"], outs=["y"])
        tdg.add_task(lambda y: (y ** 2).sum(), ins=["y"], outs=["l"])
        f = lower_tdg(tdg, jit=False)
        g = jax.grad(lambda x: f({"x": x})["l"])(jnp.arange(3.0))
        np.testing.assert_allclose(g, 8.0 * jnp.arange(3.0))


class TestSchedulerPolicies:
    def test_root_distribution_spreads_load(self):
        tdg = _listing1(1, 16)
        ex = EagerExecutor(tdg, n_workers=4, round_robin_roots=True)
        ex.run(_bufs(16))
        assert ex.stats.steals == 0      # everyone starts with own queue

    def test_vanilla_single_owner_steals(self):
        # all roots on worker 0's queue (vanilla spawn) -> others must steal
        tdg = _listing1(1, 16)
        ex = EagerExecutor(tdg, n_workers=4, round_robin_roots=False)
        ex.run(_bufs(16))
        assert ex.stats.tasks_executed == 16

    def test_dep_resolution_counts(self):
        tdg = _listing1(4, 6)
        ex = EagerExecutor(tdg, n_workers=2)
        ex.run(_bufs(6))
        # one join-counter decrement per edge — the work replay eliminates
        assert ex.stats.dep_resolutions == tdg.num_edges

    def test_replay_cache_hit(self):
        tdg = _listing1(2, 3)
        rep = ReplayExecutor(tdg)
        rep.run(_bufs(3))
        rep.run(_bufs(3))
        assert rep.replays == 2
        assert len(rep._cache) == 1      # one signature -> one executable

    def test_list_schedule_load_balance(self):
        tdg = _listing1(1, 32)
        sched = list_schedule(tdg, 4)
        sizes = [len(w) for w in sched.worker_tasks]
        assert max(sizes) - min(sizes) <= 1
        assert sched.makespan == pytest.approx(8.0)

    def test_donation_slots(self):
        tdg = TDG("d")
        tdg.add_task(lambda s, g: s + g, ins=["state", "g"], outs=["state"])
        fn = lower_tdg(tdg, donate_slots=("state",))
        out = fn({"state": jnp.ones((4,)), "g": jnp.ones((4,))})
        np.testing.assert_allclose(out["state"], 2.0)
