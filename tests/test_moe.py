"""MoE layer: routing/dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import moe as MoE
from repro.models import layers as L

KEY = jax.random.PRNGKey(3)


def _cfg(**kw):
    base = reduced(get_config("qwen3-moe-30b-a3b"))
    return dataclasses.replace(base, **kw)


def test_capacity_formula():
    cfg = _cfg(num_experts=4, top_k=2, capacity_factor=1.0)
    c = MoE.capacity(cfg, 64)
    assert c == 32 and c % 8 == 0


def test_output_shape_and_finite():
    cfg = _cfg()
    p = MoE.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = MoE.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0


def test_huge_capacity_recovers_all_tokens():
    """With capacity >> tokens, dispatch+combine must not drop anything:
    the combined output equals the dense mixture-of-experts computation."""
    cfg = _cfg(num_experts=4, top_k=2, capacity_factor=8.0)
    p = MoE.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 8, cfg.d_model),
                          jnp.float32)
    out, _ = MoE.moe_apply(p, cfg, x)

    # dense reference: every token through its top-k experts
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(xt))
    eup = np.asarray(p["experts"]["up"]["w"])
    egate = np.asarray(p["experts"]["gate"]["w"])
    edown = np.asarray(p["experts"]["down"]["w"])
    for t in range(xt.shape[0]):
        for j in range(cfg.top_k):
            e = int(ei[t, j])
            h = np.asarray(xt[t]) @ eup[e]
            g = np.asarray(xt[t]) @ egate[e]
            act = g / (1 + np.exp(-g)) * h
            ref[t] += float(gv[t, j]) * (act @ edown[e])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), ref,
                               atol=2e-3, rtol=2e-3)


def test_capacity_one_drops_tokens_gracefully():
    cfg = _cfg(num_experts=2, top_k=1, capacity_factor=0.05)
    p = MoE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model), jnp.float32)
    out, _ = MoE.moe_apply(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()


def test_aux_loss_balanced_vs_collapsed():
    cfg = _cfg(num_experts=4, top_k=1, router_aux_weight=1.0)
    T, E = 256, 4
    # balanced: uniform probabilities
    probs = jnp.full((T, E), 0.25)
    me, ce = probs.mean(0), jnp.full((E,), 0.25)
    balanced = E * jnp.sum(me * ce)
    # collapsed: all mass on expert 0
    probs_c = jnp.eye(E)[jnp.zeros(T, int)]
    collapsed = E * jnp.sum(probs_c.mean(0) * jnp.eye(E)[0])
    assert float(collapsed) > float(balanced)


def test_grad_flows_to_router_and_experts():
    cfg = _cfg()
    p = MoE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)

    def loss(p):
        out, aux = MoE.moe_apply(p, cfg, x)
        return (out ** 2).sum() + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
    assert float(jnp.abs(g["experts"]["up"]["w"]).sum()) > 0
