"""Unit coverage for the dormant sharding substrate (sharding/partition.py).

PR 9 promoted this module from "used by the training demos" to a
correctness dependency of the replay path (``sharding.replay`` resolves
specs through it), so its contracts get direct tests: ``sanitize_spec``
shrink-to-fit, ``param_pspecs``/``batch_pspec`` against real repo model
configs, and ``use_mesh`` scope nesting/restore. Everything here runs on
one CPU device; cases needing real axis sizes > 1 gate on device count and
go live in the scripts/ci.sh mesh leg.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.launch.mesh import make_replay_mesh
from repro.models.model import init_params
from repro.sharding import partition as P_

DEVICES = jax.device_count()

needs2 = pytest.mark.skipif(
    DEVICES < 2, reason="needs 2 devices; run via scripts/ci.sh mesh leg")
needs4 = pytest.mark.skipif(
    DEVICES < 4, reason="needs 4 devices; run via scripts/ci.sh mesh leg")


def _mesh2():
    return jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])


def _mesh22():
    return jax.make_mesh((2, 2), ("data", "model"),
                         devices=jax.devices()[:4])


# ---------------------------------------------------------------------------
# sanitize_spec: shrink-to-fit against real axis sizes
# ---------------------------------------------------------------------------

class TestSanitizeSpec:
    @needs2
    def test_drops_axis_on_non_divisible_dim(self):
        mesh = _mesh2()
        assert P_.sanitize_spec((7, 64), P("data", None), mesh) == \
            P(None, None)
        assert P_.sanitize_spec((8, 64), P("data", None), mesh) == \
            P("data", None)

    @needs2
    def test_per_dim_independent(self):
        # one bad dim must not strip the spec from the good dims
        mesh = _mesh2()
        assert P_.sanitize_spec((7, 8), P(None, "data"), mesh) == \
            P(None, "data")

    @needs4
    def test_tuple_entry_uses_product_of_axis_sizes(self):
        # ("data", "model") on a 2x2 mesh splits 4 ways: 6 doesn't divide,
        # 8 does
        mesh = _mesh22()
        assert P_.sanitize_spec((6,), P(("data", "model")), mesh) == P(None)
        assert P_.sanitize_spec((8,), P(("data", "model")), mesh) == \
            P(("data", "model"))

    @needs2
    def test_short_spec_extends_with_replicated_dims(self):
        mesh = _mesh2()
        assert P_.sanitize_spec((8, 3, 5), P("data"), mesh) == \
            P("data", None, None)

    def test_size_one_axes_always_fit(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        assert P_.sanitize_spec((7, 13), P("data", "model"), mesh) == \
            P("data", "model")


# ---------------------------------------------------------------------------
# param_pspecs / batch_pspec on repo model configs
# ---------------------------------------------------------------------------

def _tiny_params(arch):
    cfg = reduced(get_config(arch))
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


class TestParamSpecsOnRepoConfigs:
    @pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen3-moe-30b-a3b",
                                      "mamba2-370m"])
    def test_specs_well_formed_for_family(self, arch):
        """Dense / MoE / SSM param trees: every spec fits its leaf's rank,
        names only real mesh axes, and never reuses one mesh axis twice
        (GSPMD rejects duplicate axes within one spec)."""
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        cfg, params = _tiny_params(arch)
        specs = P_.param_pspecs(params, mesh)

        def check(path, x, spec):
            assert len(spec) <= x.ndim, (path, spec, x.shape)
            flat = [a for e in spec if e is not None
                    for a in (e if isinstance(e, tuple) else (e,))]
            assert set(flat) <= set(mesh.axis_names), (path, spec)
            assert len(flat) == len(set(flat)), (path, spec)

        jax.tree_util.tree_map_with_path(
            lambda p, x, s: check(p, x, s), params, specs)

    def test_dense_spot_checks(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        _, params = _tiny_params("qwen2.5-3b")
        specs = P_.param_pspecs(params, mesh)
        # vocab table: TP over vocab, FSDP over embed
        assert specs["embed"]["table"] == P("model", "data")
        # norms replicate
        chex = jax.tree_util.tree_leaves(specs["final_norm"])
        assert all(e is None for s in chex for e in s)

    @needs2
    def test_param_shardings_are_placeable(self):
        """param_shardings must yield shardings jax.device_put accepts for
        EVERY leaf of a real model — i.e. sanitize_spec already removed
        anything the leaf shapes can't honour."""
        mesh = _mesh2()
        _, params = _tiny_params("qwen2.5-3b")
        shardings = P_.param_shardings(params, mesh)
        placed = jax.device_put(params, shardings)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(placed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batch_pspec_shapes(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        assert P_.batch_pspec(mesh) == P("data", None)
        assert P_.batch_pspec(mesh, extra=3) == P("data", None, None, None)
        # no mesh: fully replicated (resolution needs a mesh)
        assert P_.batch_pspec(None) == P(None, None)

    @needs2
    def test_batch_pspec_on_replay_mesh(self):
        assert P_.batch_pspec(make_replay_mesh(2), extra=0) == P("data")

    def test_batch_pspec_custom_rules(self):
        mesh = jax.make_mesh((1, 1), ("pod", "data"),
                             devices=jax.devices()[:1])
        # DEFAULT_RULES "batch" uses every present candidate, in order
        assert P_.batch_pspec(mesh) == P(("pod", "data"), None)


# ---------------------------------------------------------------------------
# use_mesh scope: nesting, restore, exception safety
# ---------------------------------------------------------------------------

class TestUseMeshScope:
    def test_nesting_restores_previous(self):
        m1 = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
        m2 = jax.make_mesh((1, 1), ("data", "model"),
                           devices=jax.devices()[:1])
        assert P_.active_mesh() is None
        with P_.use_mesh(m1):
            assert P_.active_mesh() is m1
            with P_.use_mesh(m2):
                assert P_.active_mesh() is m2
            assert P_.active_mesh() is m1
        assert P_.active_mesh() is None

    def test_restores_on_exception(self):
        m1 = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
        with pytest.raises(RuntimeError):
            with P_.use_mesh(m1):
                raise RuntimeError("boom")
        assert P_.active_mesh() is None

    def test_scope_rules_drive_resolution(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        with P_.use_mesh(mesh, rules={"batch": ("model",)}):
            assert P_.resolve_axis("batch") == "model"
        with P_.use_mesh(mesh):
            assert P_.resolve_axis("batch") == "data"
            # unknown logical axes and empty candidate lists resolve to None
            assert P_.resolve_axis("no_such_axis") is None
            assert P_.resolve_axis("seq") is None

    def test_nested_scope_rules_restore(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        with P_.use_mesh(mesh, rules={"batch": ("model",)}):
            with P_.use_mesh(mesh):  # default rules inside
                assert P_.resolve_axis("batch") == "data"
            assert P_.resolve_axis("batch") == "model"

    @needs2
    def test_constrain_applies_active_mesh(self):
        mesh = _mesh2()
        x = jnp.ones((4, 3))
        with P_.use_mesh(mesh):
            out = jax.jit(lambda v: P_.constrain(v, ("batch", None)))(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
        assert isinstance(out.sharding, NamedSharding)
        # jax may normalize trailing replicated dims away: check dim 0 only
        assert out.sharding.spec[0] == "data"
