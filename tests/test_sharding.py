"""Distribution: partition rules, small-mesh pjit/shard_map, pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import partition as P_

pytestmark = pytest.mark.skipif(
    jax.device_count() < 1, reason="needs devices")


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


class TestPartitionRules:
    def test_param_specs_by_path(self):
        mesh = _mesh11()
        params = {
            "embed": {"table": jnp.zeros((256, 64))},
            "layers": {"attn": {"wq": {"w": jnp.zeros((2, 64, 64))},
                                "wo": {"w": jnp.zeros((2, 64, 64))}},
                       "mlp": {"up": {"w": jnp.zeros((2, 64, 128))},
                               "down": {"w": jnp.zeros((2, 128, 64))}},
                       "norm1": {"scale": jnp.zeros((2, 64))}},
        }
        specs = P_.param_pspecs(params, mesh)
        assert specs["embed"]["table"] == P("model", "data")
        assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
        assert specs["layers"]["attn"]["wo"]["w"] == P(None, "model", "data")
        assert specs["layers"]["mlp"]["down"]["w"] == P(None, "model", "data")
        assert specs["layers"]["norm1"]["scale"] == P(None, None)

    def test_expert_specs_no_axis_reuse(self):
        mesh = _mesh11()
        params = {"moe": {"experts": {"up": {"w": jnp.zeros((2, 4, 8, 16))}}}}
        spec = P_.param_pspecs(params, mesh)["moe"]["experts"]["up"]["w"]
        flat = [a for e in spec if e for a in
                (e if isinstance(e, tuple) else (e,))]
        assert len(flat) == len(set(flat))   # each mesh axis used once

    def test_sanitize_drops_nondivisible(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        spec = P_.sanitize_spec((7, 64), P("model", "data"), mesh)
        assert spec == P("model", "data")   # axis size 1 divides everything

    def test_constrain_noop_without_mesh(self):
        x = jnp.ones((4, 4))
        out = P_.constrain(x, ("batch", None))
        np.testing.assert_array_equal(out, x)


class TestSmallMeshLowering:
    """End-to-end pjit of the real train/serve steps on a 1x1 CPU mesh —
    the same code path the 512-device dry-run exercises."""

    def test_train_step_lowers_and_runs(self):
        import dataclasses
        from repro.configs import get_config, reduced
        from repro.launch import specs as SP
        from repro.models import init_params
        from repro.optim import adamw
        from repro.training import make_train_step

        mesh = _mesh11()
        cfg = reduced(get_config("qwen2.5-3b"))
        opt = adamw(1e-3)
        with P_.use_mesh(mesh):
            params = init_params(cfg, jax.random.PRNGKey(0))
            sh = P_.param_shardings(params, mesh)
            params = jax.device_put(params, sh)
            state = opt.init(params)
            step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
            tokens = jnp.zeros((2, 16), jnp.int32) + 3
            p2, s2, m = step(params, state, {"tokens": tokens})
            assert np.isfinite(float(m["loss"]))

    def test_input_specs_cover_all_kinds(self):
        from repro.configs import SHAPES, get_config
        from repro.launch import specs as SP
        from repro.optim import adamw
        mesh = _mesh11()
        cfg = get_config("qwen2.5-3b")
        for name in ("train_4k", "prefill_32k", "decode_32k"):
            out = SP.input_specs(cfg, SHAPES[name], mesh,
                                 adamw(1e-4) if name == "train_4k" else None)
            assert "params" in out
            leaves = jax.tree_util.tree_leaves(out["params"])
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)

    def test_cache_specs_sharded_sanely(self):
        from repro.configs import SHAPES, get_config
        from repro.launch import specs as SP
        mesh = _mesh11()
        caches = SP.cache_specs(get_config("hymba-1.5b"),
                                SHAPES["decode_32k"], mesh)
        k = caches[0]["attn"]["k"]
        assert k.shape[1] == 1024      # ring buffer == window, not 32768
