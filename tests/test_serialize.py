"""TDG serialization: the compiler->runtime handoff round-trip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReplayExecutor, TDG, topo_waves
from repro.core.serialize import (TaskFnRegistry, load_tdg, save_tdg,
                                  tdg_from_dict, tdg_to_dict)

REG = TaskFnRegistry()


@REG.register()
def scale2(x):
    return x * 2.0


@REG.register()
def addone(x):
    return x + 1.0


@REG.register("dotsum")
def dot(x, y):
    return (x * y).sum()


def _graph():
    tdg = TDG("ser")
    tdg.add_task(scale2, ins=["a"], outs=["b"], name="s")
    tdg.add_task(addone, ins=["b"], outs=["c"], name="p", cost_hint=2.0,
                 stage=1)
    tdg.add_task(dot, ins=["b", "c"], outs=["d"], name="d")
    return tdg


def test_roundtrip_structure_and_replay(tmp_path):
    tdg = _graph()
    f = tmp_path / "region.tdg.json"
    save_tdg(tdg, f, REG)
    tdg2 = load_tdg(f, REG)
    assert tdg2.num_tasks == tdg.num_tasks
    assert tdg2.num_edges == tdg.num_edges
    assert topo_waves(tdg2) == topo_waves(tdg)
    assert tdg2.tasks[1].metadata == {"stage": 1}
    assert tdg2.tasks[1].cost_hint == 2.0
    bufs = {"a": jnp.arange(4.0)}
    r1 = ReplayExecutor(tdg).run(dict(bufs))
    r2 = ReplayExecutor(tdg2).run(dict(bufs))
    for k in r1:
        np.testing.assert_allclose(r1[k], r2[k], rtol=1e-6)


def test_loaded_tdg_supports_add_task(tmp_path):
    """Regression: the rebuilt TDG's dependency table was left empty, so
    add_task after a load silently resolved no edges at all."""
    tdg = _graph()
    f = tmp_path / "grow.tdg.json"
    save_tdg(tdg, f, REG)
    tdg2 = load_tdg(f, REG)

    before = tdg2.num_edges
    t = tdg2.add_task(addone, ins=["d"], outs=["e"], name="post-load")
    # 'd' was written by task 2: the new task must pick up that RAW edge
    assert tdg2.preds[t.tid] == {2}
    assert tdg2.num_edges == before + 1
    # and execution semantics match building the same graph from scratch
    fresh = _graph()
    fresh.add_task(addone, ins=["d"], outs=["e"], name="post-load")
    bufs = {"a": jnp.arange(4.0)}
    r1 = ReplayExecutor(fresh).run(dict(bufs))
    r2 = ReplayExecutor(tdg2).run(dict(bufs))
    np.testing.assert_allclose(r1["e"], r2["e"], rtol=1e-6)


def test_loaded_tdg_war_edges_still_resolve(tmp_path):
    """The rebuilt readers table must also produce WAR (anti) deps."""
    tdg = _graph()
    f = tmp_path / "war.tdg.json"
    save_tdg(tdg, f, REG)
    tdg2 = load_tdg(f, REG)
    # task 2 reads 'b' and 'c'; writing 'b' now must order after that read
    t = tdg2.add_task(scale2, ins=["a"], outs=["b"], name="rewrite-b")
    kinds = {(e.src, e.kind.value) for e in tdg2.edges if e.dst == t.tid}
    assert (2, "war") in kinds     # anti dep on the reader of 'b'
    assert (0, "waw") in kinds     # output dep on the old writer of 'b'


def test_unregistered_payload_rejected():
    tdg = TDG("bad")
    tdg.add_task(lambda x: x, ins=["a"], outs=["b"])
    with pytest.raises(ValueError, match="not registered"):
        tdg_to_dict(tdg, REG)


def test_unknown_symbol_rejected():
    data = tdg_to_dict(_graph(), REG)
    data["tasks"][0]["fn"] = "nonexistent"
    with pytest.raises(KeyError):
        tdg_from_dict(data, REG)


def test_version_gate():
    data = tdg_to_dict(_graph(), REG)
    data["version"] = 99
    with pytest.raises(ValueError, match="version"):
        tdg_from_dict(data, REG)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        REG.register("scale2")(lambda x: x)
