"""TDG serialization: the compiler->runtime handoff round-trip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReplayExecutor, TDG, topo_waves
from repro.core.serialize import (TaskFnRegistry, load_tdg, save_tdg,
                                  tdg_from_dict, tdg_to_dict)

REG = TaskFnRegistry()


@REG.register()
def scale2(x):
    return x * 2.0


@REG.register()
def addone(x):
    return x + 1.0


@REG.register("dotsum")
def dot(x, y):
    return (x * y).sum()


def _graph():
    tdg = TDG("ser")
    tdg.add_task(scale2, ins=["a"], outs=["b"], name="s")
    tdg.add_task(addone, ins=["b"], outs=["c"], name="p", cost_hint=2.0,
                 stage=1)
    tdg.add_task(dot, ins=["b", "c"], outs=["d"], name="d")
    return tdg


def test_roundtrip_structure_and_replay(tmp_path):
    tdg = _graph()
    f = tmp_path / "region.tdg.json"
    save_tdg(tdg, f, REG)
    tdg2 = load_tdg(f, REG)
    assert tdg2.num_tasks == tdg.num_tasks
    assert tdg2.num_edges == tdg.num_edges
    assert topo_waves(tdg2) == topo_waves(tdg)
    assert tdg2.tasks[1].metadata == {"stage": 1}
    assert tdg2.tasks[1].cost_hint == 2.0
    bufs = {"a": jnp.arange(4.0)}
    r1 = ReplayExecutor(tdg).run(dict(bufs))
    r2 = ReplayExecutor(tdg2).run(dict(bufs))
    for k in r1:
        np.testing.assert_allclose(r1[k], r2[k], rtol=1e-6)


def test_unregistered_payload_rejected():
    tdg = TDG("bad")
    tdg.add_task(lambda x: x, ins=["a"], outs=["b"])
    with pytest.raises(ValueError, match="not registered"):
        tdg_to_dict(tdg, REG)


def test_unknown_symbol_rejected():
    data = tdg_to_dict(_graph(), REG)
    data["tasks"][0]["fn"] = "nonexistent"
    with pytest.raises(KeyError):
        tdg_from_dict(data, REG)


def test_version_gate():
    data = tdg_to_dict(_graph(), REG)
    data["version"] = 99
    with pytest.raises(ValueError, match="version"):
        tdg_from_dict(data, REG)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        REG.register("scale2")(lambda x: x)
