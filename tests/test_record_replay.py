"""Record-and-replay region semantics (paper §4.2/4.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TaskGraphRegion, registry, taskgraph


def _mk_region(nowait=False):
    @taskgraph(nowait=nowait)
    def region(g, x, a):
        g.task(lambda x, a: x * a, ins=["x", "a"], outs=["y"], name="scale")
        g.task(lambda y: y + 1.0, ins=["y"], outs=["z"], name="shift")
        g.task(lambda y, z: (y * z).sum(), ins=["y", "z"], outs=["w"], name="dot")
    return region


def test_first_call_records_then_replays():
    region = _mk_region()
    x = jnp.arange(4.0)
    o1 = region(x=x, a=jnp.float32(3.0))
    assert region.records == 1 and region.replays == 0
    o2 = region(x=x, a=jnp.float32(3.0))
    assert region.replays == 1
    for k in o1:
        np.testing.assert_allclose(o1[k], o2[k], rtol=1e-6)


def test_replay_new_data_changes_result():
    region = _mk_region()
    region(x=jnp.arange(4.0), a=jnp.float32(1.0))
    o = region(x=jnp.arange(4.0), a=jnp.float32(2.0))  # fill_data path
    np.testing.assert_allclose(o["y"], 2.0 * jnp.arange(4.0))


def test_replay_cache_per_signature():
    region = _mk_region()
    region(x=jnp.arange(4.0), a=jnp.float32(1.0))
    region(x=jnp.arange(4.0), a=jnp.float32(1.0))
    region(x=jnp.arange(8.0), a=jnp.float32(1.0))   # new shape -> new exec
    assert len(region._replay_cache) == 2


def test_replay_cache_keyed_by_kernel_mode():
    """Flipping the global kernel mode between replays must re-lower, not
    serve a stale-substrate executable (regression: cache was sig-only)."""
    from repro.kernels import registry as kreg

    region = _mk_region()
    region(x=jnp.arange(4.0), a=jnp.float32(1.0))      # record
    with kreg.kernel_mode_scope("ref"):
        region(x=jnp.arange(4.0), a=jnp.float32(1.0))
    with kreg.kernel_mode_scope("interpret"):
        region(x=jnp.arange(4.0), a=jnp.float32(1.0))
    assert len(region._replay_cache) == 2
    modes = {key[1] for key in region._replay_cache}
    assert modes == {"ref", "interpret"}


def test_static_build_matches_recorded_shape():
    rec = _mk_region()
    rec(x=jnp.arange(4.0), a=jnp.float32(1.0))

    @taskgraph(name="static_twin")
    def twin(g, x, a):
        g.task(lambda x, a: x * a, ins=["x", "a"], outs=["y"])
        g.task(lambda y: y + 1.0, ins=["y"], outs=["z"])
        g.task(lambda y, z: (y * z).sum(), ins=["y", "z"], outs=["w"])

    twin.build_static(x=jax.ShapeDtypeStruct((4,), jnp.float32),
                      a=jax.ShapeDtypeStruct((), jnp.float32))
    assert twin.static
    assert twin.tdg.num_tasks == rec.tdg.num_tasks
    assert twin.tdg.num_edges == rec.tdg.num_edges
    o = twin(x=jnp.arange(4.0), a=jnp.float32(1.0))  # replay w/o recording
    assert twin.records == 0 and twin.replays == 1
    np.testing.assert_allclose(o["w"],
                               (jnp.arange(4.0) * (jnp.arange(4.0) + 1)).sum())


def test_source_location_registry():
    region = _mk_region()
    assert region.source_location in registry()
    # same source location twice -> non-conforming (paper §4.1 rule 3)
    with pytest.raises(ValueError):
        TaskGraphRegion(region.build_fn, name=region.name)


def test_non_recurrent_runs_without_tdg():
    @taskgraph(recurrent=False)
    def once(g, x):
        g.task(lambda x: x + 1, ins=["x"], outs=["y"])
    o = once(x=jnp.zeros(()))
    assert once.tdg is None            # Algorithm 4.1 line 23 fallback
    np.testing.assert_allclose(o["y"], 1.0)


def test_outputs_restriction():
    @taskgraph(outputs=("z",))
    def region(g, x):
        g.task(lambda x: x * 2, ins=["x"], outs=["y"])
        g.task(lambda y: y + 1, ins=["y"], outs=["z"])
    o = region(x=jnp.ones(()))
    assert set(o) == {"z"}
    o = region(x=jnp.ones(()))
    assert set(o) == {"z"}


def test_schedule_summary():
    region = _mk_region()
    region(x=jnp.arange(4.0), a=jnp.float32(1.0))
    s = region.schedule_summary()
    assert s["tasks"] == 3 and s["waves"] == 3 and s["roots"] == 1
    assert s["dep_lookups_at_record"] > 0
