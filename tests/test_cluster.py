"""Cluster tier: RPC codec, sticky routing, artifact shipping, failover.

Process-spawning tests share module-scoped frontends (spawning a jax worker
costs seconds; the suites amortize it) and check every distributed answer
against the in-process ``ReplayExecutor``/``RegionServer`` ground truth —
the RPC front must never change WHAT is computed, only WHERE. The remote
bootstrap suite drives *subprocess* workers (``python -m
repro.serving.worker`` over localhost TCP — no ``multiprocessing`` handle),
which is exactly the multi-host attach path. Multi-worker soak lives behind
the ``slow`` marker.
"""
import itertools
import json
import os
import pickle
import shutil
import socket
import struct
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ReplayExecutor, TopologyMismatch,
                        executable_from_bytes,
                        executable_serialization_available,
                        topology_fingerprint, warmup_and_save)
from repro.serving import (ClusterError, ClusterFrontend, ClusterRemoteError,
                           RateLimited, RegionServer, ShmRing, StickyRouter,
                           rpc)
from repro.serving.metrics import validate_trace
from repro.serving.cluster import WorkerNode, _WorkerHandle, resolve_registry
from repro.serving.demo import DEMO_REGISTRY, demo_affine, demo_mix, demo_region
from repro.serving.spawner import SpawnedWorker, parse_worker_spec
from repro.serving.worker import spawn_worker_subprocess

REGISTRY_SPEC = "repro.serving.demo:DEMO_REGISTRY"
DIM = 6


def _bufs(seed, width=2, shared_w=None):
    rng = np.random.default_rng(seed)
    b = {f"x{s}": jnp.asarray(rng.standard_normal((DIM, DIM)), jnp.float32)
         for s in range(width)}
    b["w"] = (shared_w if shared_w is not None
              else jnp.asarray(rng.standard_normal((DIM, DIM)), jnp.float32))
    return b


def _check(out, tdg, bufs):
    want = ReplayExecutor(tdg).run(dict(bufs))
    assert set(out) == set(want)
    for k in want:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Wire codec (no processes)
# ---------------------------------------------------------------------------

class TestRpcCodec:
    def _roundtrip(self, obj):
        return rpc.decode(rpc.encode(obj))

    def test_scalars_and_containers(self):
        obj = {"op": "x", "id": 3, "none": None, "flag": True,
               "f": 2.5, "s": "text", "tup": (1, 2), "lst": [1, [2, 3]],
               ("k", 1): "tuple-key"}
        back = self._roundtrip(obj)
        assert back == obj
        assert isinstance(back["tup"], tuple)
        assert isinstance(back["lst"], list)

    def test_array_dtypes_and_zero_d(self):
        arrays = {
            "f32": jnp.asarray(np.arange(6, dtype=np.float32).reshape(2, 3)),
            "bf16": jnp.asarray([[1.5, -2.0]], jnp.bfloat16),
            "i32_0d": jnp.asarray(7, jnp.int32),
            "np_scalar": np.float32(1.25),
            "bool_arr": np.array([True, False]),
        }
        back = self._roundtrip(arrays)
        assert back["f32"].dtype == np.float32
        np.testing.assert_array_equal(back["f32"], np.asarray(arrays["f32"]))
        assert str(back["bf16"].dtype) == "bfloat16"
        np.testing.assert_array_equal(
            back["bf16"].astype(np.float32),
            np.asarray(arrays["bf16"]).astype(np.float32))
        assert back["i32_0d"].shape == () and int(back["i32_0d"]) == 7
        assert back["np_scalar"].dtype == np.float32
        assert float(back["np_scalar"]) == 1.25
        np.testing.assert_array_equal(back["bool_arr"],
                                      np.array([True, False]))

    def test_nested_pytree_and_bytes(self):
        obj = {"caches": [{"k": jnp.ones((2, 2)), "v": (jnp.zeros((1,)),)}],
               "artifact": b"\x00\x01binary\xff"}
        back = self._roundtrip(obj)
        assert back["artifact"] == b"\x00\x01binary\xff"
        np.testing.assert_array_equal(back["caches"][0]["k"], np.ones((2, 2)))
        assert isinstance(back["caches"][0]["v"], tuple)

    def test_decoded_arrays_are_writable(self):
        back = self._roundtrip({"x": np.zeros((2,), np.float32)})
        back["x"][0] = 1.0      # frombuffer views are read-only; copies aren't
        assert back["x"][0] == 1.0

    def test_unencodable_rejected(self):
        with pytest.raises(TypeError, match="cannot encode"):
            rpc.encode({"fn": lambda: None})

    def test_truncated_frame_rejected(self):
        data = rpc.encode({"a": jnp.ones((4,))})
        with pytest.raises(rpc.ProtocolError):
            rpc.decode(data[:8])


def _frame(header_obj, blobs=()):
    """Hand-roll a v2 JSON frame body (adversarial tests build invalid ones).

    Layout: ``[1B tag 'J'][u32 hlen][header][u32 nblobs]`` then per blob
    ``[1B placement=inline][u64 len][bytes]``.
    """
    header = json.dumps(header_obj).encode("utf-8")
    parts = [b"J", struct.pack(">I", len(header)), header,
             struct.pack(">I", len(blobs))]
    for b in blobs:
        parts.append(b"\x00")
        parts.append(struct.pack(">Q", len(b)))
        parts.append(b)
    return b"".join(parts)


class TestRpcFramingAdversarial:
    """Bytes a peer could actually send must fail as ProtocolError — never
    as a numpy/json traceback from half-parsed attacker-controlled data."""

    def test_truncated_header_length(self):
        with pytest.raises(rpc.ProtocolError, match="missing header"):
            rpc.decode(b"\x00\x01")

    def test_header_overruns_body(self):
        with pytest.raises(rpc.ProtocolError, match="header overruns"):
            rpc.decode(b"J" + struct.pack(">I", 100) + b"{}")

    def test_bad_magic_tag_rejected(self):
        with pytest.raises(rpc.ProtocolError, match="codec tag"):
            rpc.decode(b"\x00" + struct.pack(">I", 2) + b"{}"
                       + struct.pack(">I", 0))

    def test_truncated_blob_length(self):
        good = _frame({"t": "b", "i": 0}, [b"payload"])
        with pytest.raises(rpc.ProtocolError, match="blob length"):
            rpc.decode(good[:-len(b"payload") - 4])   # cut mid length prefix

    def test_blob_overruns_body(self):
        good = _frame({"t": "b", "i": 0}, [b"payload"])
        with pytest.raises(rpc.ProtocolError, match="blob overruns"):
            rpc.decode(good[:-3])

    def test_blob_index_out_of_range(self):
        with pytest.raises(rpc.ProtocolError, match="out of range"):
            rpc.decode(_frame({"t": "b", "i": 7}, [b"x"]))

    def test_array_blob_shape_mismatch(self):
        # 3 bytes of data for a float32[4]: without validation this escapes
        # as a numpy frombuffer/reshape error deep in the codec.
        bad = _frame({"t": "a", "i": 0, "d": "float32", "s": [4]}, [b"abc"])
        with pytest.raises(rpc.ProtocolError, match="disagrees"):
            rpc.decode(bad)

    def test_array_negative_dim(self):
        # float32[-1] with 4 bytes would pass a naive size check (numpy
        # infers -1) and reshape attacker-chosen geometry.
        bad = _frame({"t": "a", "i": 0, "d": "float32", "s": [-1]},
                     [b"\x00" * 4])
        with pytest.raises(rpc.ProtocolError, match="invalid shape"):
            rpc.decode(bad)

    def test_unknown_node_type(self):
        with pytest.raises(rpc.ProtocolError, match="unknown codec node"):
            rpc.decode(_frame({"t": "zz", "v": 1}))

    def test_non_list_shape_rejected(self):
        bad = _frame({"t": "a", "i": 0, "d": "float32", "s": 1},
                     [b"\x00" * 4])
        with pytest.raises(rpc.ProtocolError, match="invalid shape"):
            rpc.decode(bad)

    def test_missing_node_keys_are_protocol_errors(self):
        # A node without "t"/"d"/"i" must not escape as KeyError from deep
        # inside the codec — the reader loops only treat ProtocolError (and
        # socket errors) as fatal-but-handled.
        with pytest.raises(rpc.ProtocolError, match="malformed codec"):
            rpc.decode(_frame({"v": 1}))
        with pytest.raises(rpc.ProtocolError, match="malformed codec"):
            rpc.decode(_frame({"t": "a", "i": 0, "s": [1]}, [b"\x00" * 4]))

    def test_bogus_dtype_is_protocol_error(self):
        bad = _frame({"t": "a", "i": 0, "d": "no-such-dtype", "s": [1]},
                     [b"\x00" * 4])
        with pytest.raises(rpc.ProtocolError, match="malformed codec"):
            rpc.decode(bad)

    def test_non_json_header_is_protocol_error(self):
        body = (b"J" + struct.pack(">I", 4) + b"\xff\xfe{{"
                + struct.pack(">I", 0))
        with pytest.raises(rpc.ProtocolError, match="not valid JSON"):
            rpc.decode(body)

    def test_protocol_error_mid_stream_fails_pending_futures(self):
        # A desynced frame on a live frontend connection must mark the
        # worker dead (failing in-flight futures fast), not kill the
        # reader thread silently with futures hung.
        import itertools

        from repro.serving.cluster import _WorkerHandle
        from repro.serving.spawner import SpawnedWorker

        sa, sb = socket.socketpair()
        handle = _WorkerHandle(
            0, SpawnedWorker(idx=0, kind="remote", address=("x", 1),
                             conn=rpc.RpcConnection(sa)),
            itertools.count(1), lambda idx: None)
        fut = handle.request_async({"op": "stats"})
        rpc.recv_msg(sb)                          # consume the request
        sb.sendall(struct.pack(">Q", rpc.max_frame_bytes() + 1))
        with pytest.raises(Exception, match="died"):
            fut.result(timeout=10)
        assert not handle.alive
        sb.close()
        handle.close()

    def test_oversized_length_prefix_refused(self):
        a, b = socket.socketpair()
        try:
            a.sendall(struct.pack(">Q", rpc.max_frame_bytes() + 1))
            with pytest.raises(rpc.ProtocolError, match="exceeding"):
                rpc.recv_msg(b)
        finally:
            a.close()
            b.close()

    def test_max_frame_env_caps_both_directions(self, monkeypatch):
        monkeypatch.setenv("REPRO_RPC_MAX_FRAME", "64")
        assert rpc.max_frame_bytes() == 64
        a, b = socket.socketpair()
        try:
            with pytest.raises(rpc.ProtocolError, match="exceeds"):
                rpc.send_msg(a, {"x": np.zeros(100, np.float32)})
            a.sendall(struct.pack(">Q", 65))
            with pytest.raises(rpc.ProtocolError, match="exceeding"):
                rpc.recv_msg(b)
        finally:
            a.close()
            b.close()

    def test_max_frame_env_invalid_is_loud(self, monkeypatch):
        # ProtocolError, not bare ValueError: the cap is read on wire
        # paths, and reader loops only treat ProtocolError as a handled
        # fatal error (futures fail fast instead of threads dying silent).
        monkeypatch.setenv("REPRO_RPC_MAX_FRAME", "not-a-number")
        with pytest.raises(rpc.ProtocolError, match="REPRO_RPC_MAX_FRAME"):
            rpc.max_frame_bytes()
        monkeypatch.setenv("REPRO_RPC_MAX_FRAME", "-1")
        with pytest.raises(rpc.ProtocolError, match="positive"):
            rpc.max_frame_bytes()

    def test_hello_frame_capped_preauth(self):
        # An unauthenticated peer's first frame is bounded by
        # HELLO_MAX_BYTES regardless of the (multi-GiB) general cap.
        sa, sb = socket.socketpair()
        a, b = rpc.RpcConnection(sa), rpc.RpcConnection(sb)
        try:
            a.send({"op": "hello", "proto": rpc.PROTOCOL_VERSION,
                    "token": "x" * (rpc.HELLO_MAX_BYTES + 1)})
            with pytest.raises(rpc.ProtocolError, match="exceeding"):
                rpc.server_handshake(b, token="t")
        finally:
            a.close()
            b.close()

    def test_handshake_deadline_is_absolute(self):
        # A trickler that sends nothing must be cut off by the deadline.
        sa, sb = socket.socketpair()
        b = rpc.RpcConnection(sb)
        try:
            t0 = time.monotonic()
            with pytest.raises(rpc.ProtocolError, match="deadline"):
                rpc.server_handshake(b, token="t", timeout=0.3)
            assert time.monotonic() - t0 < 5.0
        finally:
            sa.close()
            b.close()


class TestRpcAccounting:
    """The satellite bugfix: recv() must account real wire bytes, not
    "1 per message", and both directions must be observable."""

    def test_bytes_received_matches_peer_bytes_sent(self):
        sa, sb = socket.socketpair()
        a, b = rpc.RpcConnection(sa), rpc.RpcConnection(sb)
        try:
            payload = {"op": "x", "arr": np.arange(32, dtype=np.float32),
                       "blob": b"\x00" * 100}
            a.send(payload)
            a.send({"op": "tiny"})
            got1, got2 = b.recv(), b.recv()
            assert got1["op"] == "x" and got2["op"] == "tiny"
            assert a.messages_sent == 2
            assert b.messages_received == 2
            # REAL byte symmetry: everything a put on the wire, b counted.
            assert a.bytes_sent == b.bytes_received
            assert b.bytes_received > 128 + 100     # not a message count
            ws = b.wire_stats()
            assert ws["bytes_sent"] == 0
            assert ws["bytes_received"] == b.bytes_received
            assert ws["messages_sent"] == 0
            assert ws["messages_received"] == 2
            assert ws["decode_seconds"] > 0.0
            assert ws["transport"] == "tcp"
            aw = a.wire_stats()
            assert aw["encode_seconds"] > 0.0
            assert aw["shm_bytes_sent"] == 0
        finally:
            a.close()
            b.close()


class TestRegistryResolution:
    def test_instance_passthrough(self):
        assert resolve_registry(DEMO_REGISTRY) is DEMO_REGISTRY

    def test_spec_string(self):
        assert resolve_registry(REGISTRY_SPEC) is DEMO_REGISTRY

    def test_bad_spec(self):
        with pytest.raises(ValueError, match="module:attr"):
            resolve_registry("not-a-spec")


# ---------------------------------------------------------------------------
# Routing (no processes)
# ---------------------------------------------------------------------------

class TestStickyRouter:
    def test_sticky_by_key(self):
        r = StickyRouter(4)
        alive = {0, 1, 2, 3}
        w = r.route("sigA", alive)
        for _ in range(5):
            assert r.route("sigA", alive) == w

    def test_distinct_structures_spread_least_loaded(self):
        r = StickyRouter(2)
        alive = {0, 1}
        workers = {r.route(f"sig{i}", alive) for i in range(2)}
        assert workers == {0, 1}

    def test_reroute_excludes_dead(self):
        r = StickyRouter(3)
        alive = {0, 1, 2}
        w = r.route("sig", alive)
        w2 = r.reroute("sig", alive - {w}, exclude={w})
        assert w2 != w
        assert r.route("sig", alive - {w}) == w2   # sticky on the new home

    def test_no_live_workers(self):
        r = StickyRouter(2)
        with pytest.raises(Exception, match="no live workers"):
            r.route("sig", set())


# ---------------------------------------------------------------------------
# Live cluster (module-scoped 2-worker frontend)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def frontend():
    fe = ClusterFrontend(workers=2, registry=REGISTRY_SPEC, max_wait_ms=5.0,
                         name="test-cluster")
    yield fe
    fe.close()


@pytest.fixture(scope="module")
def shared_w():
    return jnp.asarray(np.random.default_rng(99).standard_normal((DIM, DIM)),
                       jnp.float32)


class TestClusterServing:
    def test_parity_vs_inprocess_ground_truth(self, frontend, shared_w):
        tenants = []
        for i in range(4):
            tdg = demo_region(f"par[{i}]")
            frontend.register_tenant(f"par{i}", tdg, pinned={"w": shared_w})
            tenants.append((tdg, _bufs(20 + i, shared_w=shared_w)))
        futs = [frontend.submit(f"par{i}",
                                {k: v for k, v in b.items() if k != "w"})
                for i, (_, b) in enumerate(tenants)]
        outs = [f.result(120) for f in futs]
        for (tdg, b), out in zip(tenants, outs):
            _check(out, tdg, b)

    def test_sticky_routing_by_structure(self, frontend, shared_w):
        # 3 tenants of one structure + 2 of another: each structure must
        # land whole on exactly one worker (warm state never splits).
        for i in range(3):
            frontend.register_tenant(
                f"stA{i}", demo_region(f"stA[{i}]", waves=3),
                pinned={"w": shared_w})
        for i in range(2):
            frontend.register_tenant(
                f"stB{i}", demo_region(f"stB[{i}]", waves=3,
                                       body=demo_affine),
                pinned={"w": shared_w})
        a_workers = {frontend.tenant(f"stA{i}").worker for i in range(3)}
        b_workers = {frontend.tenant(f"stB{i}").worker for i in range(2)}
        assert len(a_workers) == 1
        assert len(b_workers) == 1
        # different payload symbol => different routing key; least-loaded
        # assignment puts it on the other worker of the pair
        assert a_workers != b_workers

    def test_cross_process_coalescing(self, frontend, shared_w):
        # Same-structure tenants routed to one worker still coalesce there:
        # the fleet's coalesced_requests must rise when we fire concurrently.
        before = frontend.stats()["aggregate"]["coalesced_requests"]
        for i in range(3):
            frontend.register_tenant(
                f"co{i}", demo_region(f"co[{i}]", waves=4),
                pinned={"w": shared_w})
        bufs = [_bufs(40 + i, shared_w=shared_w) for i in range(3)]
        for _ in range(3):      # several rounds: at least one coalesces
            futs = [frontend.submit(
                f"co{i}", {k: v for k, v in bufs[i].items() if k != "w"})
                for i in range(3)]
            [f.result(120) for f in futs]
        after = frontend.stats()["aggregate"]["coalesced_requests"]
        assert after > before

    def test_request_error_is_isolated(self, frontend):
        frontend.register_tenant("err", demo_region("err[0]"))
        with pytest.raises(ClusterRemoteError, match="missing"):
            frontend.serve("err", {"x0": jnp.ones((DIM, DIM))})  # no x1/w
        # the worker survived the bad request
        assert len(frontend._alive()) == 2
        good = _bufs(50)
        out = frontend.serve("err", good)
        _check(out, demo_region("err[0]"), good)

    def test_unknown_tenant(self, frontend):
        with pytest.raises(KeyError, match="unknown tenant"):
            frontend.serve("ghost", {})

    def test_duplicate_tenant_rejected(self, frontend):
        frontend.register_tenant("dup", demo_region("dup[0]"))
        with pytest.raises(ValueError, match="already registered"):
            frontend.register_tenant("dup", demo_region("dup[1]"))

    def test_aggregate_sums_worker_metrics(self, frontend):
        st = frontend.stats()
        live = [s for s in st["workers"].values() if s is not None]
        assert st["aggregate"]["admitted"] == sum(
            s["metrics"]["admitted"] for s in live)
        assert st["frontend"]["alive"] == 2
        assert set(st["aggregate"]) >= {
            "admitted", "completed", "failed", "coalesced_requests",
            "aot_served", "aot_hydrate_failures", "pool", "intern"}

    def test_pinned_group_ships_once_per_worker(self, frontend, shared_w):
        # Every pinned registration in this module passes the SAME shared_w
        # object, so there is exactly one pin group, shipped to at most one
        # worker per distinct placement — never once per tenant.
        st = frontend.stats()
        pinned_workers = {r["worker"] for r in st["tenants"].values()}
        assert 1 <= st["frontend"]["pin_groups_shipped"] <= len(pinned_workers)
        for s in st["workers"].values():
            if s is not None:
                assert s["worker"]["pin_groups"] <= 1

    def test_failed_registration_leaves_no_phantom(self, frontend,
                                                   monkeypatch):
        from repro.core import TDG

        def unregistered_payload(x, w):
            return x + w
        bad = TDG("phantom[0]")
        bad.add_task(unregistered_payload, ins=["x0", "w"], outs=["x0"])
        # frontend-side failure (payload has no symbol in DEMO_REGISTRY):
        # fails before any record exists
        with pytest.raises(ValueError, match="not registered"):
            frontend.register_tenant("phantom", bad)
        # worker-side failure (registration RPC errors after the record is
        # inserted): the record must be rolled back, not left as a phantom
        # that blocks the retry
        def boom(widx, record):
            raise ClusterRemoteError("worker rejected registration")
        monkeypatch.setattr(frontend, "_register_on", boom)
        with pytest.raises(ClusterRemoteError, match="rejected"):
            frontend.register_tenant("phantom", demo_region("phantom[0]"))
        monkeypatch.undo()
        frontend.register_tenant("phantom", demo_region("phantom[1]"))
        good = _bufs(55)
        _check(frontend.serve("phantom", good),
               demo_region("phantom[1]"), good)

    def test_health(self, frontend):
        rows = frontend.health()
        assert len(rows) == 2
        assert all(r["alive"] and r["process_alive"] for r in rows)
        assert all(isinstance(r["pid"], int) for r in rows)


# ---------------------------------------------------------------------------
# Warm-artifact shipping + poisoned artifacts (1-worker frontend)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not executable_serialization_available(),
                    reason="jax build cannot serialize executables")
class TestArtifactShipping:
    @pytest.fixture(scope="class")
    def cold_frontend(self):
        fe = ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             name="test-cold")
        yield fe
        fe.close()

    @pytest.fixture(scope="class")
    def warm_artifact(self, tmp_path_factory):
        tdg = demo_region("warm[0]")
        bufs = _bufs(60)
        path = str(tmp_path_factory.mktemp("warm") / "region.json")
        warmup_and_save(tdg, bufs, path, DEMO_REGISTRY)
        return path, tdg, bufs

    def test_cold_worker_hydrates_without_relowering(self, cold_frontend,
                                                     warm_artifact):
        path, tdg, bufs = warm_artifact
        rec = cold_frontend.register_tenant("warm", warm_path=path)
        assert rec.artifact is not None          # sidecar held for re-shipping
        out = cold_frontend.serve("warm", bufs)
        _check(out, tdg, bufs)
        st = cold_frontend.stats()
        wk = st["workers"][0]
        assert st["aggregate"]["hydrated_inband"] == 1
        assert st["aggregate"]["aot_served"] >= 1
        # THE cold-start claim: the worker served from the shipped binary
        # and never lowered anything itself.
        assert wk["intern"]["misses"] == 0
        assert st["aggregate"]["aot_hydrate_failures"] == 0

    def test_poisoned_artifact_is_loud_but_survivable(self, cold_frontend,
                                                      warm_artifact,
                                                      tmp_path):
        path, tdg, bufs = warm_artifact
        poisoned = str(tmp_path / "poisoned.json")
        with open(path) as f:
            graph = f.read()
        with open(poisoned, "w") as f:
            f.write(graph)
        with open(poisoned + ".aot", "wb") as f:
            f.write(b"not an executable")
        before = cold_frontend.stats()["aggregate"]["aot_hydrate_failures"]
        cold_frontend.register_tenant("poison", warm_path=poisoned)
        out = cold_frontend.serve("poison", bufs)   # lazy fallback still right
        _check(out, tdg, bufs)
        after = cold_frontend.stats()["aggregate"]["aot_hydrate_failures"]
        assert after == before + 1


class TestHydrateFailureMetricInProcess:
    """The satellite bugfix: RegionServer itself must count silent fallbacks."""

    def test_corrupt_sidecar_counts_hydrate_failure(self, tmp_path):
        tdg = demo_region("hf[0]")
        path = str(tmp_path / "hf.json")
        from repro.core.serialize import save_tdg
        save_tdg(tdg, path, DEMO_REGISTRY)
        with open(path + ".aot", "wb") as f:
            f.write(b"garbage bytes")
        with RegionServer(max_batch=1) as server:
            server.register_tenant("hf", warm_path=path,
                                   fn_registry=DEMO_REGISTRY)
            bufs = _bufs(70)
            out = server.serve("hf", bufs)
            _check(out, tdg, bufs)
            assert server.metrics.snapshot()["aot_hydrate_failures"] == 1

    def test_missing_sidecar_is_not_a_failure(self, tmp_path):
        tdg = demo_region("nf[0]")
        path = str(tmp_path / "nf.json")
        from repro.core.serialize import save_tdg
        save_tdg(tdg, path, DEMO_REGISTRY)   # graph only, no .aot at all
        with RegionServer(max_batch=1) as server:
            server.register_tenant("nf", warm_path=path,
                                   fn_registry=DEMO_REGISTRY)
            assert server.metrics.snapshot()["aot_hydrate_failures"] == 0


# ---------------------------------------------------------------------------
# Worker death -> requeue (own 2-worker frontend: it kills one)
# ---------------------------------------------------------------------------

class TestWorkerDeathRequeue:
    def test_kill_requeues_to_sibling_with_parity(self):
        # heartbeat_secs=0 pins the supervisor OFF: this test asserts the
        # bare death->requeue contract (victim stays dead, tenant moves to
        # the sibling for good); self-healing respawn has its own tests.
        with ClusterFrontend(workers=2, registry=REGISTRY_SPEC,
                             heartbeat_secs=0,
                             name="test-kill") as fe:
            shared = jnp.asarray(
                np.random.default_rng(7).standard_normal((DIM, DIM)),
                jnp.float32)
            tdg = demo_region("kill[0]")
            fe.register_tenant("k", tdg, pinned={"w": shared})
            bufs = {f"x{s}": jnp.asarray(
                np.random.default_rng(8 + s).standard_normal((DIM, DIM)),
                jnp.float32) for s in range(2)}
            out_before = fe.serve("k", bufs)
            _check(out_before, tdg, {**bufs, "w": shared})
            victim = fe.tenant("k").worker
            fe._handles[victim].process.terminate()
            fe._handles[victim].process.join(timeout=30)
            deadline = time.monotonic() + 30
            while fe._handles[victim].alive and time.monotonic() < deadline:
                time.sleep(0.05)     # reader notices EOF
            out_after = fe.serve("k", bufs)
            for key in out_before:
                np.testing.assert_allclose(np.asarray(out_after[key]),
                                           np.asarray(out_before[key]),
                                           rtol=2e-5, atol=2e-5)
            st = fe.stats()
            assert fe.tenant("k").worker != victim
            assert st["frontend"]["worker_deaths"] >= 1
            assert st["frontend"]["requeues"] >= 1
            assert st["frontend"]["alive"] == 1

    def test_kill_mid_window_all_futures_resolve(self, monkeypatch):
        # The hard case: the pipeline window holds SEVERAL inflight batch
        # frames (tiny _WIRE_BATCH forces multi-frame windows) on a
        # shm-transport worker when it is SIGKILLed mid-conversation.
        # Every outstanding future must resolve — retried to the sibling
        # with ground-truth parity, zero hangs — and the respawned
        # replacement comes back on TCP, leaving a mixed shm+tcp fleet
        # that still serves both tenants correctly.
        import repro.serving.cluster as cluster_mod
        monkeypatch.setattr(cluster_mod, "_WIRE_BATCH", 2)
        with ClusterFrontend(workers=2, registry=REGISTRY_SPEC,
                             transport="shm", window=4,
                             heartbeat_secs=0.3, lease_misses=3,
                             respawn_max=3, name="test-midwindow") as fe:
            assert all(h.transport == "shm" for h in fe._handles)
            shared = jnp.asarray(
                np.random.default_rng(17).standard_normal((DIM, DIM)),
                jnp.float32)
            tdg_a = demo_region("mwA[0]")
            tdg_b = demo_region("mwB[0]", body=demo_affine)
            fe.register_tenant("mwA", tdg_a, pinned={"w": shared})
            fe.register_tenant("mwB", tdg_b, pinned={"w": shared})
            bufs = {f"x{s}": jnp.asarray(
                np.random.default_rng(18 + s).standard_normal((DIM, DIM)),
                jnp.float32) for s in range(2)}
            send = {k: v for k, v in bufs.items() if k != "w"}
            ground_a = ReplayExecutor(tdg_a).run({**bufs, "w": shared})
            ground_b = ReplayExecutor(tdg_b).run({**bufs, "w": shared})
            # warm both workers so the kill round is pure replay traffic
            fe.serve("mwA", send, timeout=300)
            fe.serve("mwB", send, timeout=300)
            victim = fe.tenant("mwA").worker
            respawns_before = fe.respawns
            futs = [fe.submit("mwA", send) for _ in range(16)]
            fe._handles[victim].process.kill()      # SIGKILL mid-window
            for f in futs:
                out = f.result(timeout=120)          # zero hangs
                for key in ground_a:
                    np.testing.assert_allclose(
                        np.asarray(out[key]), np.asarray(ground_a[key]),
                        rtol=2e-5, atol=2e-5)
            st = fe.stats()["frontend"]
            assert st["worker_deaths"] >= 1
            assert st["requeues"] >= 1
            # the replacement connects TCP-first: genuinely mixed fleet
            deadline = time.monotonic() + 120
            while fe.respawns == respawns_before \
                    and time.monotonic() < deadline:
                time.sleep(0.1)
            assert fe.respawns > respawns_before
            assert {h.transport for h in fe._handles} == {"shm", "tcp"}
            out_a = fe.serve("mwA", send, timeout=120)
            out_b = fe.serve("mwB", send, timeout=120)
            for key in ground_a:
                np.testing.assert_allclose(np.asarray(out_a[key]),
                                           np.asarray(ground_a[key]),
                                           rtol=2e-5, atol=2e-5)
            for key in ground_b:
                np.testing.assert_allclose(np.asarray(out_b[key]),
                                           np.asarray(ground_b[key]),
                                           rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Continuous batching + QoS over the wire (own frontends)
# ---------------------------------------------------------------------------

class TestContinuousCluster:
    def test_kill_mid_resident_batch_supervised_all_resolve(self):
        # Workers run continuous RegionServers (the default): a burst of
        # concurrent submits forms a resident batch on the victim when it
        # is SIGKILLed. With the supervisor ON, every in-flight step must
        # resolve — requeued to the sibling with ground-truth parity or
        # failed with a typed error, zero hangs — and the respawned slot
        # must keep serving. The surviving fleet's execution-pattern trace
        # must be retrievable over the wire and schema-valid.
        with ClusterFrontend(workers=2, registry=REGISTRY_SPEC,
                             heartbeat_secs=0.3, lease_misses=3,
                             respawn_max=3, name="test-contkill") as fe:
            shared = jnp.asarray(
                np.random.default_rng(27).standard_normal((DIM, DIM)),
                jnp.float32)
            tdg = demo_region("ck[0]")
            fe.register_tenant("ck", tdg, pinned={"w": shared}, tier=1)
            bufs = {f"x{s}": jnp.asarray(
                np.random.default_rng(28 + s).standard_normal((DIM, DIM)),
                jnp.float32) for s in range(2)}
            ground = ReplayExecutor(tdg).run({**bufs, "w": shared})
            fe.serve("ck", bufs, timeout=300)       # warm the victim
            victim = fe.tenant("ck").worker
            respawns_before = fe.respawns
            futs = [fe.submit("ck", bufs) for _ in range(12)]
            fe._handles[victim].process.kill()      # SIGKILL mid-batch
            ok, typed = 0, 0
            for f in futs:
                try:
                    out = f.result(timeout=120)      # zero hangs
                except (ClusterError, ClusterRemoteError, RuntimeError):
                    typed += 1
                    continue
                for key in ground:
                    np.testing.assert_allclose(
                        np.asarray(out[key]), np.asarray(ground[key]),
                        rtol=2e-5, atol=2e-5)
                ok += 1
            assert ok + typed == 12 and ok >= 1
            st = fe.stats()["frontend"]
            assert st["worker_deaths"] >= 1
            deadline = time.monotonic() + 120
            while fe.respawns == respawns_before \
                    and time.monotonic() < deadline:
                time.sleep(0.1)
            assert fe.respawns > respawns_before    # supervised comeback
            out_after = fe.serve("ck", bufs, timeout=120)
            for key in ground:
                np.testing.assert_allclose(np.asarray(out_after[key]),
                                           np.asarray(ground[key]),
                                           rtol=2e-5, atol=2e-5)
            traces = [t for t in fe.trace().values() if t is not None]
            assert traces                            # fleet trace reachable
            for t in traces:
                validate_trace(t["records"])
            assert any(t["summary"]["steps"] >= 1 for t in traces)

    def test_rate_limited_crosses_the_wire_typed(self):
        # A tenant registered with rate=0.001 req/s has a one-token burst:
        # the first request spends it, the second must come back as the
        # TYPED RateLimited (matched by name through the rpc error
        # registry), not an opaque ClusterRemoteError — and must NOT be
        # retried onto another worker.
        with ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             heartbeat_secs=0,
                             name="test-ratewire") as fe:
            tdg = demo_region("rl[0]")
            fe.register_tenant("rl", tdg, tier=0, rate=0.001)
            bufs = _bufs(31)
            out = fe.serve("rl", bufs, timeout=300)  # spends the only token
            _check(out, tdg, bufs)
            with pytest.raises(RateLimited, match="rate limit"):
                fe.serve("rl", bufs, timeout=120)
            st = fe.stats()
            assert st["aggregate"]["rate_limited"] == 1


# ---------------------------------------------------------------------------
# Multi-worker soak (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestClusterSoak:
    def test_four_workers_dependent_chains(self):
        with ClusterFrontend(workers=4, registry=REGISTRY_SPEC,
                             max_wait_ms=10.0, name="test-soak") as fe:
            shared = jnp.asarray(
                np.random.default_rng(1).standard_normal((DIM, DIM)),
                jnp.float32)
            tenants = []
            for i in range(8):
                tdg = demo_region(f"soak[{i}]", waves=2 + (i % 4))
                fe.register_tenant(f"s{i}", tdg, pinned={"w": shared})
                tenants.append((tdg, _bufs(100 + i, shared_w=shared)))
            # dependent chains: each round feeds the next
            state = [dict(b, w=shared) for _, b in tenants]
            for _ in range(6):
                futs = [fe.submit(f"s{i}", {k: v for k, v in state[i].items()
                                            if k != "w"})
                        for i in range(8)]
                for i, f in enumerate(futs):
                    state[i].update(f.result(300))
                    state[i]["w"] = shared
            # ground truth: replay the same chain in-process
            for i, (tdg, b) in enumerate(tenants):
                ex = ReplayExecutor(tdg)
                ref = dict(b)
                for _ in range(6):
                    ref.update(ex.run(dict(ref)))
                    ref["w"] = shared
                for k in ("x0", "x1"):
                    np.testing.assert_allclose(
                        np.asarray(state[i][k]), np.asarray(ref[k]),
                        rtol=2e-4, atol=2e-4)
            st = fe.stats()
            used = {r["worker"] for r in st["tenants"].values()}
            assert len(used) == 4          # 4 structures spread over 4 workers
            assert st["aggregate"]["failed"] == 0


# ---------------------------------------------------------------------------
# Handshake + auth (in-process WorkerNode: no subprocess needed)
# ---------------------------------------------------------------------------

class TestHandshakeAndAuth:
    @pytest.fixture()
    def node(self):
        node = WorkerNode(DEMO_REGISTRY, token="sekrit", max_batch=1)
        t = threading.Thread(target=node.serve_forever, daemon=True)
        t.start()
        yield node
        if not node._stop.is_set():
            conn = rpc.connect("127.0.0.1", node.port)
            rpc.client_handshake(conn, token="sekrit")
            conn.request({"op": "shutdown", "id": 0})
            conn.close()
        t.join(timeout=10)

    def test_good_token_handshake_advertises_identity(self, node):
        conn = rpc.connect("127.0.0.1", node.port)
        try:
            ack = rpc.client_handshake(conn, token="sekrit")
            assert ack["proto"] == rpc.PROTOCOL_VERSION
            assert ack["pid"] == os.getpid()       # in-process node
            assert ack["topology"] == topology_fingerprint()
            reply = conn.request({"op": "ping", "id": 1})
            assert reply["port"] == node.port
        finally:
            conn.close()

    def test_bad_token_rejected(self, node):
        conn = rpc.connect("127.0.0.1", node.port)
        try:
            with pytest.raises(rpc.AuthError, match="token"):
                rpc.client_handshake(conn, token="wrong")
        finally:
            conn.close()

    def test_missing_token_rejected(self, node):
        conn = rpc.connect("127.0.0.1", node.port)
        try:
            with pytest.raises(rpc.AuthError):
                rpc.client_handshake(conn, token=None)
        finally:
            conn.close()

    def test_protocol_version_mismatch_rejected(self, node):
        conn = rpc.connect("127.0.0.1", node.port)
        try:
            conn.send({"op": "hello", "proto": 99, "token": "sekrit"})
            reply = conn.recv()
            assert reply["op"] == "error" and reply["code"] == "proto"
        finally:
            conn.close()

    def test_rejected_connection_cannot_dispatch(self, node):
        # After a failed handshake the worker drops the socket: a follow-up
        # op must never reach the dispatcher.
        conn = rpc.connect("127.0.0.1", node.port)
        try:
            with pytest.raises(rpc.AuthError):
                rpc.client_handshake(conn, token="wrong")
            with pytest.raises((rpc.ConnectionClosed, OSError)):
                conn.send({"op": "stats", "id": 2})
                conn.recv()
        finally:
            conn.close()


class TestWorkerSpecParsing:
    def test_local_and_remote_specs(self):
        assert parse_worker_spec("local") is None
        assert parse_worker_spec(" LOCAL ") is None
        assert parse_worker_spec("10.0.0.5:7077") == ("10.0.0.5", 7077)
        assert parse_worker_spec("worker-3.fleet.internal:80") == \
            ("worker-3.fleet.internal", 80)

    @pytest.mark.parametrize("bad", ["justahost", ":1234x", "h:0", "h:99999",
                                     "h:", 7077, None])
    def test_bad_specs_fail_at_construction(self, bad):
        with pytest.raises(ValueError, match="worker spec"):
            parse_worker_spec(bad)


# ---------------------------------------------------------------------------
# Device-topology fingerprint (serialize layer)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not executable_serialization_available(),
                    reason="jax build cannot serialize executables")
class TestTopologyFingerprint:
    @pytest.fixture(scope="class")
    def artifact_bytes(self, tmp_path_factory):
        path = str(tmp_path_factory.mktemp("topo") / "t.json")
        warmup_and_save(demo_region("topo[0]"), _bufs(80), path,
                        DEMO_REGISTRY)
        with open(path + ".aot", "rb") as f:
            return f.read()

    def test_fingerprint_embedded_and_matching_hydrates(self, artifact_bytes):
        blob = pickle.loads(artifact_bytes)
        assert blob["topology"] == topology_fingerprint()
        assert executable_from_bytes(artifact_bytes) is not None

    def test_mismatch_rejected_before_xla(self, artifact_bytes):
        blob = pickle.loads(artifact_bytes)
        blob["topology"] = dict(blob["topology"], platform="tpu",
                                device_kind="TPU v4")
        # Poison the XLA payload too: if the fingerprint check ran AFTER
        # deserialization, this would crash inside XLA instead.
        blob["payload"] = b"not an xla executable"
        with pytest.raises(TopologyMismatch, match="re-lower"):
            executable_from_bytes(pickle.dumps(blob))

    def test_jax_version_skew_rejected(self, artifact_bytes):
        blob = pickle.loads(artifact_bytes)
        blob["topology"] = dict(blob["topology"], jax="0.0.1")
        with pytest.raises(TopologyMismatch):
            executable_from_bytes(pickle.dumps(blob))


# ---------------------------------------------------------------------------
# Remote bootstrap: subprocess workers over localhost TCP (the multi-host
# attach path — the frontend holds NO process handle for these workers)
# ---------------------------------------------------------------------------

WORKER_TOKEN = "test-remote-token"


@pytest.fixture(scope="module")
def remote_workers():
    """Two pre-started subprocess workers via the shared bootstrap helper
    (`repro.serving.worker.spawn_worker_subprocess` — the same one
    `benchmarks/cluster.py` uses, so the READY/argv contract has one home).
    Spawning happens in threads so the two jax cold starts overlap."""
    results: list = [None, None]

    def boot(i):
        results[i] = spawn_worker_subprocess(REGISTRY_SPEC,
                                             token=WORKER_TOKEN)

    threads = [threading.Thread(target=boot, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
    if any(r is None for r in results):
        for r in results:
            if r is not None:
                r[0].kill()
        pytest.fail("worker subprocess bootstrap timed out")
    try:
        yield results
    finally:
        for p, _addr in results:
            if p.poll() is None:
                p.kill()
            p.wait(timeout=10)


class TestRemoteBootstrap:
    @pytest.fixture(scope="class")
    def mixed_frontend(self, remote_workers):
        # One pre-started remote worker + one locally spawned: both kinds
        # behind the same router/shipping/requeue machinery.
        (_, addr0), _ = remote_workers
        fe = ClusterFrontend(workers=[addr0, "local"],
                             registry=REGISTRY_SPEC, token=WORKER_TOKEN,
                             max_wait_ms=5.0, name="test-remote-mixed")
        yield fe
        fe.close()

    def test_parity_vs_inprocess_ground_truth(self, mixed_frontend, shared_w):
        # The existing 2-worker parity contract, now with a remote worker
        # in the fleet: WHAT is computed must not change with WHERE.
        tenants = []
        for i in range(4):
            tdg = demo_region(f"rpar[{i}]", waves=2 + (i % 2))
            mixed_frontend.register_tenant(f"rpar{i}", tdg,
                                           pinned={"w": shared_w})
            tenants.append((tdg, _bufs(200 + i, shared_w=shared_w)))
        futs = [mixed_frontend.submit(
            f"rpar{i}", {k: v for k, v in b.items() if k != "w"})
            for i, (_, b) in enumerate(tenants)]
        outs = [f.result(120) for f in futs]
        for (tdg, b), out in zip(tenants, outs):
            _check(out, tdg, b)
        # both kinds of worker actually served something
        used = {mixed_frontend.tenant(f"rpar{i}").worker for i in range(4)}
        assert used == {0, 1}

    def test_health_reports_kinds_and_topology(self, mixed_frontend):
        rows = mixed_frontend.health()
        assert [r["kind"] for r in rows] == ["remote", "local"]
        assert all(r["alive"] for r in rows)
        assert rows[0]["process_alive"] is None      # no handle for remote
        assert rows[1]["process_alive"] is True
        assert rows[0]["topology"] == topology_fingerprint()

    def test_remote_request_error_is_isolated(self, mixed_frontend):
        mixed_frontend.register_tenant("rerr", demo_region("rerr[0]"))
        with pytest.raises(ClusterRemoteError, match="missing"):
            mixed_frontend.serve("rerr", {"x0": jnp.ones((DIM, DIM))})
        good = _bufs(210)
        _check(mixed_frontend.serve("rerr", good),
               demo_region("rerr[0]"), good)

    def test_wire_totals_are_real_bytes(self, mixed_frontend):
        st = mixed_frontend.stats()
        for idx, w in st["wire"].items():
            assert w["messages_sent"] >= 1
            # frames are length-prefixed: bytes must dwarf message counts
            assert w["bytes_sent"] > w["messages_sent"] * 8
            assert w["bytes_received"] > w["messages_received"] * 8
        total = st["frontend"]["wire"]
        assert total["bytes_sent"] == sum(
            w["bytes_sent"] for w in st["wire"].values())


@pytest.mark.skipif(not executable_serialization_available(),
                    reason="jax build cannot serialize executables")
class TestRemoteColdHydration:
    """The acceptance gate: a pre-started remote worker hydrates the
    shipped artifact (0 intern misses, aot_served >= 1) and rejects a
    topology-mismatched artifact loudly instead of crashing."""

    @pytest.fixture(scope="class")
    def cold_remote(self, remote_workers):
        _, (_, addr1) = remote_workers
        fe = ClusterFrontend(workers=[addr1], registry=REGISTRY_SPEC,
                             token=WORKER_TOKEN, name="test-remote-cold")
        yield fe
        fe.close()

    @pytest.fixture(scope="class")
    def warm_artifact(self, tmp_path_factory):
        tdg = demo_region("rwarm[0]", waves=3)
        bufs = _bufs(220)
        path = str(tmp_path_factory.mktemp("rwarm") / "region.json")
        warmup_and_save(tdg, bufs, path, DEMO_REGISTRY)
        return path, tdg, bufs

    def test_cold_remote_worker_hydrates_without_relowering(
            self, cold_remote, warm_artifact):
        path, tdg, bufs = warm_artifact
        rec = cold_remote.register_tenant("rwarm", warm_path=path)
        assert rec.artifact is not None
        out = cold_remote.serve("rwarm", bufs)
        _check(out, tdg, bufs)
        st = cold_remote.stats()
        wk = st["workers"][0]
        assert st["aggregate"]["hydrated_inband"] == 1
        assert st["aggregate"]["aot_served"] >= 1
        assert wk["intern"]["misses"] == 0       # never lowered anything
        assert st["aggregate"]["aot_hydrate_failures"] == 0

    def test_topology_mismatch_rejected_loudly_not_crash(
            self, cold_remote, warm_artifact, tmp_path):
        path, tdg, bufs = warm_artifact
        bad = str(tmp_path / "badtopo.json")
        shutil.copy(path, bad)
        with open(path + ".aot", "rb") as f:
            blob = pickle.loads(f.read())
        blob["topology"] = dict(blob["topology"], platform="tpu",
                                device_kind="TPU v4")
        with open(bad + ".aot", "wb") as f:
            f.write(pickle.dumps(blob))
        before = cold_remote.stats()["aggregate"]
        cold_remote.register_tenant("badtopo", warm_path=bad)
        out = cold_remote.serve("badtopo", bufs)   # re-lower fallback works
        _check(out, tdg, bufs)
        after = cold_remote.stats()["aggregate"]
        assert after["aot_topology_rejects"] == \
            before["aot_topology_rejects"] + 1
        assert after["aot_hydrate_failures"] == \
            before["aot_hydrate_failures"] + 1
        assert len(cold_remote._alive()) == 1      # worker survived

    def test_close_shuts_down_remote_worker(self, cold_remote,
                                            remote_workers):
        # Must run LAST in this class: the frontend owns no process handle,
        # so the best-effort shutdown RPC is the only thing that can stop
        # the subprocess — assert it actually does, with a clean exit.
        proc = remote_workers[1][0]
        cold_remote.close()
        proc.wait(timeout=30)
        assert proc.returncode == 0


# ---------------------------------------------------------------------------
# close() escalation: terminate -> kill, never a leaked local process
# ---------------------------------------------------------------------------

class TestCloseEscalation:
    def test_worker_ignoring_shutdown_is_killed_and_reaped(self, monkeypatch):
        fe = ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             shutdown_grace=0.5, name="test-escalate")
        h = fe._handles[0]
        proc = h.process
        assert proc.is_alive()
        # Simulate a worker that never sees the shutdown RPC *and* shrugs
        # off SIGTERM: close() must escalate to kill() and still reap it.
        monkeypatch.setattr(
            h, "request",
            lambda *a, **k: (_ for _ in ()).throw(RuntimeError("rpc down")))
        monkeypatch.setattr(proc, "terminate", lambda: None)
        fe.close()
        assert not proc.is_alive()
        assert proc.exitcode is not None           # reaped, not abandoned


# ---------------------------------------------------------------------------
# Binary header codec (no processes)
# ---------------------------------------------------------------------------

class TestBinaryCodec:
    """The hot-path codec must be a bit-exact substitute for JSON framing:
    same objects out, same blob discipline, smaller headers."""

    def _roundtrip(self, obj):
        return rpc.decode(rpc.encode(obj, codec="binary"))

    def test_scalars_containers_and_tuple_keys(self):
        obj = {"op": "submit_batch", "id": 3, "none": None, "flag": True,
               "f": 2.5, "s": "text", "tup": (1, 2), "lst": [1, [2, 3]],
               ("k", 1): "tuple-key", "neg": -(1 << 40)}
        back = self._roundtrip(obj)
        assert back == obj
        assert isinstance(back["tup"], tuple)
        assert isinstance(back["lst"], list)

    def test_arrays_bytes_and_dtypes(self):
        obj = {
            "f32": jnp.asarray(np.arange(6, dtype=np.float32).reshape(2, 3)),
            "bf16": jnp.asarray([[1.5, -2.0]], jnp.bfloat16),
            "i32_0d": jnp.asarray(7, jnp.int32),
            "blob": b"\x00\x01binary\xff",
        }
        back = self._roundtrip(obj)
        assert back["blob"] == obj["blob"]
        assert back["f32"].dtype == np.float32
        np.testing.assert_array_equal(back["f32"], np.asarray(obj["f32"]))
        assert str(back["bf16"].dtype) == "bfloat16"
        assert back["i32_0d"].shape == () and int(back["i32_0d"]) == 7
        back["f32"][0, 0] = 9.0          # decoded arrays stay writable copies

    def test_parity_with_json_codec_on_a_submit_frame(self):
        frame = {"op": "submit_batch", "entries": [
            {"id": 11, "tenant": "t", "buffers":
                {"x0": np.arange(12, dtype=np.float32).reshape(3, 4)}}]}
        via_bin = rpc.decode(rpc.encode(frame, codec="binary"))
        via_json = rpc.decode(rpc.encode(frame, codec="json"))
        np.testing.assert_array_equal(
            via_bin["entries"][0]["buffers"]["x0"],
            via_json["entries"][0]["buffers"]["x0"])
        assert via_bin["entries"][0]["id"] == via_json["entries"][0]["id"]
        # the point of the codec: same bytes in the blobs, smaller header
        assert len(rpc.encode(frame, codec="binary")) < \
            len(rpc.encode(frame, codec="json"))

    def test_out_of_range_int_points_at_json(self):
        with pytest.raises(TypeError, match="64-bit"):
            rpc.encode({"n": 1 << 70}, codec="binary")

    def test_unencodable_rejected(self):
        with pytest.raises(TypeError, match="cannot encode"):
            rpc.encode({"fn": lambda: None}, codec="binary")


def _bin_frame(header, blobs=()):
    """Hand-roll a v2 binary frame body around a raw header byte string."""
    parts = [b"B", struct.pack(">I", len(header)), header,
             struct.pack(">I", len(blobs))]
    for b in blobs:
        parts.append(b"\x00")
        parts.append(struct.pack(">Q", len(b)))
        parts.append(b)
    return b"".join(parts)


class TestBinaryHeaderAdversarial:
    """Every malformed binary header a peer could send must surface as
    ProtocolError — never a struct.error / KeyError traceback."""

    def test_unknown_tag(self):
        with pytest.raises(rpc.ProtocolError, match="unknown binary codec"):
            rpc.decode(_bin_frame(b"\x7f"))

    def test_truncated_int_node(self):
        with pytest.raises(rpc.ProtocolError, match="truncated int"):
            rpc.decode(_bin_frame(b"\x03\x00\x00"))

    def test_string_overruns_header(self):
        header = b"\x05" + struct.pack(">I", 999) + b"ab"
        with pytest.raises(rpc.ProtocolError, match="overruns the header"):
            rpc.decode(_bin_frame(header))

    def test_string_invalid_utf8(self):
        header = b"\x05" + struct.pack(">I", 2) + b"\xff\xfe"
        with pytest.raises(rpc.ProtocolError, match="not valid utf-8"):
            rpc.decode(_bin_frame(header))

    def test_container_count_lies(self):
        header = b"\x08" + struct.pack(">I", 0xFFFF0000)
        with pytest.raises(rpc.ProtocolError, match="container count"):
            rpc.decode(_bin_frame(header))

    def test_blob_index_out_of_range(self):
        header = b"\x06" + struct.pack(">I", 3)
        with pytest.raises(rpc.ProtocolError, match="out of range"):
            rpc.decode(_bin_frame(header))

    def test_trailing_header_bytes(self):
        with pytest.raises(rpc.ProtocolError, match="trailing bytes"):
            rpc.decode(_bin_frame(b"\x00\x00"))

    def test_unhashable_dict_key(self):
        # {[]: None} — a list node in key position decodes but cannot hash
        header = (b"\x09" + struct.pack(">I", 1)
                  + b"\x08" + struct.pack(">I", 0) + b"\x00")
        with pytest.raises(rpc.ProtocolError, match="unhashable"):
            rpc.decode(_bin_frame(header))

    def test_bogus_array_dtype(self):
        dt = b"no-such"
        header = (b"\x0a" + struct.pack(">I", 0) + bytes([len(dt)]) + dt
                  + bytes([1]) + struct.pack(">I", 4))
        with pytest.raises(rpc.ProtocolError, match="malformed codec node"):
            rpc.decode(_bin_frame(header, blobs=(b"\x00" * 16,)))

    def test_array_blob_size_mismatch(self):
        dt = b"float32"
        header = (b"\x0a" + struct.pack(">I", 0) + bytes([len(dt)]) + dt
                  + bytes([1]) + struct.pack(">I", 4))
        with pytest.raises(rpc.ProtocolError, match="disagrees with"):
            rpc.decode(_bin_frame(header, blobs=(b"\x00" * 3,)))

    def test_shm_reference_without_a_ring(self):
        # placement=1 blob on a ring-less decode: clean refusal, no deref
        header = b"\x06" + struct.pack(">I", 0)
        body = (b"B" + struct.pack(">I", len(header)) + header
                + struct.pack(">I", 1) + b"\x01" + struct.pack(">QQ", 0, 16))
        with pytest.raises(rpc.ProtocolError, match="no ring attached"):
            rpc.decode(body)


# ---------------------------------------------------------------------------
# Shared-memory ring (no processes)
# ---------------------------------------------------------------------------

class TestShmRing:
    def test_roundtrip_attach_and_stats(self):
        ring = ShmRing.create(4096)
        try:
            pos = ring.alloc(100)
            ring.write(pos, b"x" * 100)
            assert ring.read(pos, 100) == b"x" * 100
            # a second attachment sees the same bytes (the cross-process
            # contract, exercised in-process)
            peer = ShmRing.attach(ring.name, ring.size)
            assert peer.read(pos, 100) == b"x" * 100
            peer.close()
            st = ring.stats()
            assert st["allocated"] == 100 and st["outstanding"] == 100
            ring.ack(pos + 100)
            assert ring.stats()["outstanding"] == 0
        finally:
            ring.close()

    def test_alloc_pads_to_segment_end_instead_of_wrapping(self):
        ring = ShmRing.create(4096)
        try:
            a = ring.alloc(1500)
            ring.ack(a + 1500)
            b = ring.alloc(1500)
            ring.ack(b + 1500)
            c = ring.alloc(1500)            # 3000 + 1500 > 4096: must pad
            assert c % ring.size == 0       # lands at the segment start
            ring.write(c, b"z" * 1500)
            assert ring.read(c, 1500) == b"z" * 1500
        finally:
            ring.close()

    def test_full_ring_blocks_until_peer_acks(self):
        ring = ShmRing.create(4096)
        try:
            first = ring.alloc(2000)
            ring.alloc(2000)
            released = threading.Event()

            def _late_ack():
                time.sleep(0.3)
                released.set()
                ring.ack(first + 2000)

            threading.Thread(target=_late_ack, daemon=True).start()
            t0 = time.monotonic()
            pos = ring.alloc(2000, timeout=30)   # blocks until the ack
            assert released.is_set()
            assert time.monotonic() - t0 >= 0.2
            assert pos % ring.size == 0
        finally:
            ring.close()

    def test_oversized_blob_is_a_value_error(self):
        ring = ShmRing.create(4096)
        try:
            with pytest.raises(ValueError, match="contiguity bound"):
                ring.alloc(3000)                 # > size // 2
        finally:
            ring.close()

    def test_reads_are_bounds_checked(self):
        ring = ShmRing.create(4096)
        try:
            with pytest.raises(rpc.ProtocolError, match="sane segment span"):
                ring.read(0, 10 ** 9)
            with pytest.raises(rpc.ProtocolError, match="sane segment span"):
                ring.read(-1, 4)
            with pytest.raises(rpc.ProtocolError, match="overruns"):
                ring.read(4090, 100)
        finally:
            ring.close()

    def test_closed_ring_fails_allocators(self):
        ring = ShmRing.create(4096)
        ring.close()
        with pytest.raises(rpc.ProtocolError, match="closed"):
            ring.alloc(16)


# ---------------------------------------------------------------------------
# Dispatcher: batching, pipelining window, reply demux (socketpair, no jax)
# ---------------------------------------------------------------------------

def _handle_pair(window=None):
    """A _WorkerHandle wired to a fake worker: the test drives the peer
    end of a socketpair with raw protocol frames."""
    sa, sb = socket.socketpair()
    deaths = []
    handle = _WorkerHandle(
        0,
        SpawnedWorker(idx=0, kind="remote", address=("fake", 0),
                      conn=rpc.RpcConnection(sa)),
        itertools.count(1), deaths.append, window=window)
    return handle, rpc.RpcConnection(sb), deaths


class TestDispatcherWirePath:
    def test_window_pressure_packs_and_replies_demux_out_of_order(self):
        h, peer, _ = _handle_pair(window=1)
        try:
            f1 = h.submit_async("t", {})
            frame1 = peer.recv()
            assert frame1["op"] == "submit_batch"
            assert len(frame1["entries"]) == 1
            # window=1 with frame1 unanswered: these five must queue, and
            # the dispatcher must NOT put another frame on the wire
            futs = [h.submit_async("t", {"n": np.float32(i)})
                    for i in range(5)]
            # poll (not a fixed sleep): wait until all five are queued,
            # then the window invariant — exactly one frame in flight —
            # must hold
            deadline = time.monotonic() + 30
            while h.dispatch_stats()["queued_entries"] < 5 \
                    and time.monotonic() < deadline:
                time.sleep(0.005)
            ds = h.dispatch_stats()
            assert ds["inflight_frames"] == 1
            assert ds["queued_entries"] == 5
            # answering frame1 frees the window slot -> the backlog goes
            # out pre-coalesced: five submissions, ONE frame
            peer.send({"op": "result_batch", "entries": [
                {"id": frame1["entries"][0]["id"], "out": {"ok": 1}}]},
                codec="binary")
            assert f1.result(30)["out"]["ok"] == 1
            frame2 = peer.recv()
            ids = [e["id"] for e in frame2["entries"]]
            assert len(ids) == 5
            # out-of-order completion: reply reversed, each future still
            # resolves to ITS entry by id
            peer.send({"op": "result_batch", "entries": [
                {"id": m, "out": {"echo": m}} for m in reversed(ids)]},
                codec="binary")
            for fut, mid in zip(futs, ids):
                got = fut.result(30)
                assert got["id"] == mid and got["out"]["echo"] == mid
            ds = h.dispatch_stats()
            assert ds["frames_sent"] == 2 and ds["entries_sent"] == 6
            assert ds["inflight_frames"] == 0 and ds["queued_entries"] == 0
            assert ds["entries_per_frame"] == 3.0
        finally:
            h.close()
            peer.close()

    def test_error_entries_fail_only_their_future(self):
        h, peer, _ = _handle_pair()
        try:
            f_ok = h.submit_async("t", {})
            f_bad = h.submit_async("t", {})
            got = []
            while sum(len(f["entries"]) for f in got) < 2:
                got.append(peer.recv())
            mids = [e["id"] for f in got for e in f["entries"]]
            peer.send({"op": "result_batch", "entries": [
                {"id": mids[0], "out": {"y": 1}},
                {"id": mids[1], "error": "KeyError: nope"}]}, codec="binary")
            assert f_ok.result(30)["out"]["y"] == 1
            with pytest.raises(ClusterRemoteError, match="nope"):
                f_bad.result(30)
            assert h.alive                  # a remote error is not a death
        finally:
            h.close()
            peer.close()

    def test_control_timeout_disowns_pending_and_is_counted(self):
        h, peer, _ = _handle_pair()
        try:
            with pytest.raises(ClusterError, match="no reply"):
                h.request({"op": "ping"}, timeout=0.3)
            # the fixed leak: the demux table must NOT retain the entry
            with h._lock:
                assert not h._pending
            assert h.dispatch_stats()["timeouts"] == 1
            # the late reply arrives anyway; the reader drops it silently
            late = peer.recv()
            peer.send({"op": "result", "id": late["id"], "pong": True})

            def _answer_next():
                msg = peer.recv()
                peer.send({"op": "result", "id": msg["id"], "pong": True})

            t = threading.Thread(target=_answer_next, daemon=True)
            t.start()
            # ...and the connection is still healthy for the next request
            assert h.request({"op": "ping"}, timeout=30)["pong"] is True
            t.join(timeout=10)
            assert h.alive
        finally:
            h.close()
            peer.close()


# ---------------------------------------------------------------------------
# Batch admission (in-process RegionServer, no processes)
# ---------------------------------------------------------------------------

class TestSubmitManyAdmission:
    def test_mixed_batch_is_positionally_aligned(self):
        with RegionServer(max_batch=4, name="many") as server:
            tdg = demo_region("many[0]")
            server.register_tenant("m", tdg)
            good_a, good_b = _bufs(300), _bufs(301)
            futs = server.submit_many([
                ("m", good_a),
                ("ghost", good_a),                  # unknown tenant
                ("m", {"x0": good_a["x0"]}),        # missing input slots
                ("m", good_b),
            ])
            assert len(futs) == 4
            _check(futs[0].result(300), tdg, good_a)
            with pytest.raises(KeyError, match="ghost"):
                futs[1].result(300)
            with pytest.raises(KeyError, match="missing"):
                futs[2].result(300)
            _check(futs[3].result(300), tdg, good_b)
            assert server.metrics.snapshot()["admitted"] >= 2


# ---------------------------------------------------------------------------
# Wire path on a live cluster (module-scoped frontend)
# ---------------------------------------------------------------------------

class TestWirePathCluster:
    def test_burst_parity_and_wire_stats(self, frontend, shared_w):
        tdg = demo_region("wire[0]")
        frontend.register_tenant("wire", tdg, pinned={"w": shared_w})
        before = frontend.stats()["frontend"]["wire"]
        bufs_list = [{f"x{s}": jnp.asarray(
            np.random.default_rng(700 + 10 * i + s)
            .standard_normal((DIM, DIM)), jnp.float32) for s in range(2)}
            for i in range(24)]
        futs = [frontend.submit("wire", b) for b in bufs_list]
        for b, f in zip(bufs_list, futs):
            _check(f.result(300), tdg, {**b, "w": shared_w})
        st = frontend.stats()
        after = st["frontend"]["wire"]
        # every submission went through the batch path, never one frame
        # per request more than the burst size
        assert after["entries_sent"] - before["entries_sent"] >= 24
        assert after["frames_sent"] - before["frames_sent"] <= 24
        assert after["frames_sent"] <= after["entries_sent"]
        assert after["encode_seconds"] > 0.0
        assert after["decode_seconds"] > 0.0
        assert after["timeouts"] == 0
        fr = st["frontend"]
        assert fr["transport"] in ("tcp", "shm", "auto")
        assert fr["window"] >= 1
        for row in st["wire"].values():
            assert row["window"] == fr["window"]
            assert row["entries_per_frame"] >= 1.0 or row["frames_sent"] == 0
            assert row["transport"] in ("tcp", "shm")
            assert row["inflight_frames"] == 0      # drained after the burst


# ---------------------------------------------------------------------------
# Shared-memory transport end to end (own 1-worker frontends)
# ---------------------------------------------------------------------------

class TestShmTransport:
    def test_shm_data_plane_carries_tensors_with_parity(self):
        big = 32                # 32x32 f32 = 4 KiB/blob: over the shm floor
        with ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             transport="shm", name="test-shm") as fe:
            row = fe.stats()["wire"][0]
            if row["transport"] != "shm":
                pytest.skip("shm attach refused on this host")
            tdg = demo_region("shm[0]")
            fe.register_tenant("sm", tdg)
            rng = np.random.default_rng(42)
            bufs = {k: jnp.asarray(rng.standard_normal((big, big)),
                                   jnp.float32) for k in ("x0", "x1", "w")}
            out = fe.serve("sm", bufs)
            _check(out, tdg, bufs)
            st = fe.stats()
            row = st["wire"][0]
            assert row["shm_bytes_sent"] >= 3 * big * big * 4
            assert row["shm_bytes_received"] > 0    # replies rode shm too
            assert st["frontend"]["shm_fallbacks"] == 0
            assert st["frontend"]["wire"]["shm_bytes_sent"] == \
                row["shm_bytes_sent"]

    def test_tcp_pinned_worker_forces_counted_fallback(self, monkeypatch):
        # The spawned worker inherits the env pin and refuses the rings;
        # the frontend must land on tcp, count it, and keep full parity.
        monkeypatch.setenv("REPRO_RPC_TRANSPORT", "tcp")
        with ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             transport="shm", name="test-shm-fb") as fe:
            st = fe.stats()
            assert st["wire"][0]["transport"] == "tcp"
            assert st["frontend"]["shm_fallbacks"] == 1
            tdg = demo_region("shmfb[0]")
            fe.register_tenant("fb", tdg)
            bufs = _bufs(500)
            _check(fe.serve("fb", bufs), tdg, bufs)
            assert fe.stats()["wire"][0]["shm_bytes_sent"] == 0


class TestShmSetupRefusal:
    """shm-setup is peer-controlled input: a bogus offer must be refused
    with a reason on a connection that stays fully usable."""

    def _spin_node(self, **kwargs):
        node = WorkerNode(DEMO_REGISTRY, max_batch=1, **kwargs)
        t = threading.Thread(target=node.serve_forever, daemon=True)
        t.start()
        return node, t

    def _shutdown(self, conn, t):
        conn.request({"op": "shutdown", "id": 99})
        conn.close()
        t.join(timeout=10)

    def test_unattachable_segments_refused_not_fatal(self):
        node, t = self._spin_node()
        conn = rpc.connect("127.0.0.1", node.port)
        try:
            rpc.client_handshake(conn)
            reply = conn.request({"op": "shm-setup", "id": 7,
                                  "tx": "repro-ring-no-such-segment",
                                  "rx": "repro-ring-no-such-segment",
                                  "size": 4096})
            assert reply["attached"] is False
            assert reply["reason"]
            # the refusal must not poison the connection
            assert conn.request({"op": "ping", "id": 8})["port"] == node.port
        finally:
            self._shutdown(conn, t)

    def test_tcp_pinned_node_refuses_real_segments(self):
        node, t = self._spin_node(transport="tcp")
        conn = rpc.connect("127.0.0.1", node.port)
        tx, rx = ShmRing.create(4096), ShmRing.create(4096)
        try:
            rpc.client_handshake(conn)
            reply = conn.request({"op": "shm-setup", "id": 7,
                                  "tx": tx.name, "rx": rx.name,
                                  "size": 4096})
            assert reply["attached"] is False
            assert "tcp" in reply["reason"]
        finally:
            tx.close()
            rx.close()
            self._shutdown(conn, t)
