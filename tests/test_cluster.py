"""Cluster tier: RPC codec, sticky routing, artifact shipping, failover.

Process-spawning tests share module-scoped frontends (spawning a jax worker
costs seconds; the suites amortize it) and check every distributed answer
against the in-process ``ReplayExecutor``/``RegionServer`` ground truth —
the RPC front must never change WHAT is computed, only WHERE. Multi-worker
soak lives behind the ``slow`` marker.
"""
import json
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ReplayExecutor, executable_serialization_available,
                        warmup_and_save)
from repro.serving import (ClusterFrontend, ClusterRemoteError, RegionServer,
                           StickyRouter, rpc)
from repro.serving.cluster import resolve_registry
from repro.serving.demo import DEMO_REGISTRY, demo_affine, demo_mix, demo_region

REGISTRY_SPEC = "repro.serving.demo:DEMO_REGISTRY"
DIM = 6


def _bufs(seed, width=2, shared_w=None):
    rng = np.random.default_rng(seed)
    b = {f"x{s}": jnp.asarray(rng.standard_normal((DIM, DIM)), jnp.float32)
         for s in range(width)}
    b["w"] = (shared_w if shared_w is not None
              else jnp.asarray(rng.standard_normal((DIM, DIM)), jnp.float32))
    return b


def _check(out, tdg, bufs):
    want = ReplayExecutor(tdg).run(dict(bufs))
    assert set(out) == set(want)
    for k in want:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Wire codec (no processes)
# ---------------------------------------------------------------------------

class TestRpcCodec:
    def _roundtrip(self, obj):
        return rpc.decode(rpc.encode(obj))

    def test_scalars_and_containers(self):
        obj = {"op": "x", "id": 3, "none": None, "flag": True,
               "f": 2.5, "s": "text", "tup": (1, 2), "lst": [1, [2, 3]],
               ("k", 1): "tuple-key"}
        back = self._roundtrip(obj)
        assert back == obj
        assert isinstance(back["tup"], tuple)
        assert isinstance(back["lst"], list)

    def test_array_dtypes_and_zero_d(self):
        arrays = {
            "f32": jnp.asarray(np.arange(6, dtype=np.float32).reshape(2, 3)),
            "bf16": jnp.asarray([[1.5, -2.0]], jnp.bfloat16),
            "i32_0d": jnp.asarray(7, jnp.int32),
            "np_scalar": np.float32(1.25),
            "bool_arr": np.array([True, False]),
        }
        back = self._roundtrip(arrays)
        assert back["f32"].dtype == np.float32
        np.testing.assert_array_equal(back["f32"], np.asarray(arrays["f32"]))
        assert str(back["bf16"].dtype) == "bfloat16"
        np.testing.assert_array_equal(
            back["bf16"].astype(np.float32),
            np.asarray(arrays["bf16"]).astype(np.float32))
        assert back["i32_0d"].shape == () and int(back["i32_0d"]) == 7
        assert back["np_scalar"].dtype == np.float32
        assert float(back["np_scalar"]) == 1.25
        np.testing.assert_array_equal(back["bool_arr"],
                                      np.array([True, False]))

    def test_nested_pytree_and_bytes(self):
        obj = {"caches": [{"k": jnp.ones((2, 2)), "v": (jnp.zeros((1,)),)}],
               "artifact": b"\x00\x01binary\xff"}
        back = self._roundtrip(obj)
        assert back["artifact"] == b"\x00\x01binary\xff"
        np.testing.assert_array_equal(back["caches"][0]["k"], np.ones((2, 2)))
        assert isinstance(back["caches"][0]["v"], tuple)

    def test_decoded_arrays_are_writable(self):
        back = self._roundtrip({"x": np.zeros((2,), np.float32)})
        back["x"][0] = 1.0      # frombuffer views are read-only; copies aren't
        assert back["x"][0] == 1.0

    def test_unencodable_rejected(self):
        with pytest.raises(TypeError, match="cannot encode"):
            rpc.encode({"fn": lambda: None})

    def test_truncated_frame_rejected(self):
        data = rpc.encode({"a": jnp.ones((4,))})
        with pytest.raises(rpc.ProtocolError):
            rpc.decode(data[:8])


class TestRegistryResolution:
    def test_instance_passthrough(self):
        assert resolve_registry(DEMO_REGISTRY) is DEMO_REGISTRY

    def test_spec_string(self):
        assert resolve_registry(REGISTRY_SPEC) is DEMO_REGISTRY

    def test_bad_spec(self):
        with pytest.raises(ValueError, match="module:attr"):
            resolve_registry("not-a-spec")


# ---------------------------------------------------------------------------
# Routing (no processes)
# ---------------------------------------------------------------------------

class TestStickyRouter:
    def test_sticky_by_key(self):
        r = StickyRouter(4)
        alive = {0, 1, 2, 3}
        w = r.route("sigA", alive)
        for _ in range(5):
            assert r.route("sigA", alive) == w

    def test_distinct_structures_spread_least_loaded(self):
        r = StickyRouter(2)
        alive = {0, 1}
        workers = {r.route(f"sig{i}", alive) for i in range(2)}
        assert workers == {0, 1}

    def test_reroute_excludes_dead(self):
        r = StickyRouter(3)
        alive = {0, 1, 2}
        w = r.route("sig", alive)
        w2 = r.reroute("sig", alive - {w}, exclude={w})
        assert w2 != w
        assert r.route("sig", alive - {w}) == w2   # sticky on the new home

    def test_no_live_workers(self):
        r = StickyRouter(2)
        with pytest.raises(Exception, match="no live workers"):
            r.route("sig", set())


# ---------------------------------------------------------------------------
# Live cluster (module-scoped 2-worker frontend)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def frontend():
    fe = ClusterFrontend(workers=2, registry=REGISTRY_SPEC, max_wait_ms=5.0,
                         name="test-cluster")
    yield fe
    fe.close()


@pytest.fixture(scope="module")
def shared_w():
    return jnp.asarray(np.random.default_rng(99).standard_normal((DIM, DIM)),
                       jnp.float32)


class TestClusterServing:
    def test_parity_vs_inprocess_ground_truth(self, frontend, shared_w):
        tenants = []
        for i in range(4):
            tdg = demo_region(f"par[{i}]")
            frontend.register_tenant(f"par{i}", tdg, pinned={"w": shared_w})
            tenants.append((tdg, _bufs(20 + i, shared_w=shared_w)))
        futs = [frontend.submit(f"par{i}",
                                {k: v for k, v in b.items() if k != "w"})
                for i, (_, b) in enumerate(tenants)]
        outs = [f.result(120) for f in futs]
        for (tdg, b), out in zip(tenants, outs):
            _check(out, tdg, b)

    def test_sticky_routing_by_structure(self, frontend, shared_w):
        # 3 tenants of one structure + 2 of another: each structure must
        # land whole on exactly one worker (warm state never splits).
        for i in range(3):
            frontend.register_tenant(
                f"stA{i}", demo_region(f"stA[{i}]", waves=3),
                pinned={"w": shared_w})
        for i in range(2):
            frontend.register_tenant(
                f"stB{i}", demo_region(f"stB[{i}]", waves=3,
                                       body=demo_affine),
                pinned={"w": shared_w})
        a_workers = {frontend.tenant(f"stA{i}").worker for i in range(3)}
        b_workers = {frontend.tenant(f"stB{i}").worker for i in range(2)}
        assert len(a_workers) == 1
        assert len(b_workers) == 1
        # different payload symbol => different routing key; least-loaded
        # assignment puts it on the other worker of the pair
        assert a_workers != b_workers

    def test_cross_process_coalescing(self, frontend, shared_w):
        # Same-structure tenants routed to one worker still coalesce there:
        # the fleet's coalesced_requests must rise when we fire concurrently.
        before = frontend.stats()["aggregate"]["coalesced_requests"]
        for i in range(3):
            frontend.register_tenant(
                f"co{i}", demo_region(f"co[{i}]", waves=4),
                pinned={"w": shared_w})
        bufs = [_bufs(40 + i, shared_w=shared_w) for i in range(3)]
        for _ in range(3):      # several rounds: at least one coalesces
            futs = [frontend.submit(
                f"co{i}", {k: v for k, v in bufs[i].items() if k != "w"})
                for i in range(3)]
            [f.result(120) for f in futs]
        after = frontend.stats()["aggregate"]["coalesced_requests"]
        assert after > before

    def test_request_error_is_isolated(self, frontend):
        frontend.register_tenant("err", demo_region("err[0]"))
        with pytest.raises(ClusterRemoteError, match="missing"):
            frontend.serve("err", {"x0": jnp.ones((DIM, DIM))})  # no x1/w
        # the worker survived the bad request
        assert len(frontend._alive()) == 2
        good = _bufs(50)
        out = frontend.serve("err", good)
        _check(out, demo_region("err[0]"), good)

    def test_unknown_tenant(self, frontend):
        with pytest.raises(KeyError, match="unknown tenant"):
            frontend.serve("ghost", {})

    def test_duplicate_tenant_rejected(self, frontend):
        frontend.register_tenant("dup", demo_region("dup[0]"))
        with pytest.raises(ValueError, match="already registered"):
            frontend.register_tenant("dup", demo_region("dup[1]"))

    def test_aggregate_sums_worker_metrics(self, frontend):
        st = frontend.stats()
        live = [s for s in st["workers"].values() if s is not None]
        assert st["aggregate"]["admitted"] == sum(
            s["metrics"]["admitted"] for s in live)
        assert st["frontend"]["alive"] == 2
        assert set(st["aggregate"]) >= {
            "admitted", "completed", "failed", "coalesced_requests",
            "aot_served", "aot_hydrate_failures", "pool", "intern"}

    def test_pinned_group_ships_once_per_worker(self, frontend, shared_w):
        # Every pinned registration in this module passes the SAME shared_w
        # object, so there is exactly one pin group, shipped to at most one
        # worker per distinct placement — never once per tenant.
        st = frontend.stats()
        pinned_workers = {r["worker"] for r in st["tenants"].values()}
        assert 1 <= st["frontend"]["pin_groups_shipped"] <= len(pinned_workers)
        for s in st["workers"].values():
            if s is not None:
                assert s["worker"]["pin_groups"] <= 1

    def test_failed_registration_leaves_no_phantom(self, frontend,
                                                   monkeypatch):
        from repro.core import TDG

        def unregistered_payload(x, w):
            return x + w
        bad = TDG("phantom[0]")
        bad.add_task(unregistered_payload, ins=["x0", "w"], outs=["x0"])
        # frontend-side failure (payload has no symbol in DEMO_REGISTRY):
        # fails before any record exists
        with pytest.raises(ValueError, match="not registered"):
            frontend.register_tenant("phantom", bad)
        # worker-side failure (registration RPC errors after the record is
        # inserted): the record must be rolled back, not left as a phantom
        # that blocks the retry
        def boom(widx, record):
            raise ClusterRemoteError("worker rejected registration")
        monkeypatch.setattr(frontend, "_register_on", boom)
        with pytest.raises(ClusterRemoteError, match="rejected"):
            frontend.register_tenant("phantom", demo_region("phantom[0]"))
        monkeypatch.undo()
        frontend.register_tenant("phantom", demo_region("phantom[1]"))
        good = _bufs(55)
        _check(frontend.serve("phantom", good),
               demo_region("phantom[1]"), good)

    def test_health(self, frontend):
        rows = frontend.health()
        assert len(rows) == 2
        assert all(r["alive"] and r["process_alive"] for r in rows)
        assert all(isinstance(r["pid"], int) for r in rows)


# ---------------------------------------------------------------------------
# Warm-artifact shipping + poisoned artifacts (1-worker frontend)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not executable_serialization_available(),
                    reason="jax build cannot serialize executables")
class TestArtifactShipping:
    @pytest.fixture(scope="class")
    def cold_frontend(self):
        fe = ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                             name="test-cold")
        yield fe
        fe.close()

    @pytest.fixture(scope="class")
    def warm_artifact(self, tmp_path_factory):
        tdg = demo_region("warm[0]")
        bufs = _bufs(60)
        path = str(tmp_path_factory.mktemp("warm") / "region.json")
        warmup_and_save(tdg, bufs, path, DEMO_REGISTRY)
        return path, tdg, bufs

    def test_cold_worker_hydrates_without_relowering(self, cold_frontend,
                                                     warm_artifact):
        path, tdg, bufs = warm_artifact
        rec = cold_frontend.register_tenant("warm", warm_path=path)
        assert rec.artifact is not None          # sidecar held for re-shipping
        out = cold_frontend.serve("warm", bufs)
        _check(out, tdg, bufs)
        st = cold_frontend.stats()
        wk = st["workers"][0]
        assert st["aggregate"]["hydrated_inband"] == 1
        assert st["aggregate"]["aot_served"] >= 1
        # THE cold-start claim: the worker served from the shipped binary
        # and never lowered anything itself.
        assert wk["intern"]["misses"] == 0
        assert st["aggregate"]["aot_hydrate_failures"] == 0

    def test_poisoned_artifact_is_loud_but_survivable(self, cold_frontend,
                                                      warm_artifact,
                                                      tmp_path):
        path, tdg, bufs = warm_artifact
        poisoned = str(tmp_path / "poisoned.json")
        with open(path) as f:
            graph = f.read()
        with open(poisoned, "w") as f:
            f.write(graph)
        with open(poisoned + ".aot", "wb") as f:
            f.write(b"not an executable")
        before = cold_frontend.stats()["aggregate"]["aot_hydrate_failures"]
        cold_frontend.register_tenant("poison", warm_path=poisoned)
        out = cold_frontend.serve("poison", bufs)   # lazy fallback still right
        _check(out, tdg, bufs)
        after = cold_frontend.stats()["aggregate"]["aot_hydrate_failures"]
        assert after == before + 1


class TestHydrateFailureMetricInProcess:
    """The satellite bugfix: RegionServer itself must count silent fallbacks."""

    def test_corrupt_sidecar_counts_hydrate_failure(self, tmp_path):
        tdg = demo_region("hf[0]")
        path = str(tmp_path / "hf.json")
        from repro.core.serialize import save_tdg
        save_tdg(tdg, path, DEMO_REGISTRY)
        with open(path + ".aot", "wb") as f:
            f.write(b"garbage bytes")
        with RegionServer(max_batch=1) as server:
            server.register_tenant("hf", warm_path=path,
                                   fn_registry=DEMO_REGISTRY)
            bufs = _bufs(70)
            out = server.serve("hf", bufs)
            _check(out, tdg, bufs)
            assert server.metrics.snapshot()["aot_hydrate_failures"] == 1

    def test_missing_sidecar_is_not_a_failure(self, tmp_path):
        tdg = demo_region("nf[0]")
        path = str(tmp_path / "nf.json")
        from repro.core.serialize import save_tdg
        save_tdg(tdg, path, DEMO_REGISTRY)   # graph only, no .aot at all
        with RegionServer(max_batch=1) as server:
            server.register_tenant("nf", warm_path=path,
                                   fn_registry=DEMO_REGISTRY)
            assert server.metrics.snapshot()["aot_hydrate_failures"] == 0


# ---------------------------------------------------------------------------
# Worker death -> requeue (own 2-worker frontend: it kills one)
# ---------------------------------------------------------------------------

class TestWorkerDeathRequeue:
    def test_kill_requeues_to_sibling_with_parity(self):
        with ClusterFrontend(workers=2, registry=REGISTRY_SPEC,
                             name="test-kill") as fe:
            shared = jnp.asarray(
                np.random.default_rng(7).standard_normal((DIM, DIM)),
                jnp.float32)
            tdg = demo_region("kill[0]")
            fe.register_tenant("k", tdg, pinned={"w": shared})
            bufs = {f"x{s}": jnp.asarray(
                np.random.default_rng(8 + s).standard_normal((DIM, DIM)),
                jnp.float32) for s in range(2)}
            out_before = fe.serve("k", bufs)
            _check(out_before, tdg, {**bufs, "w": shared})
            victim = fe.tenant("k").worker
            fe._handles[victim].process.terminate()
            fe._handles[victim].process.join(timeout=30)
            deadline = time.monotonic() + 30
            while fe._handles[victim].alive and time.monotonic() < deadline:
                time.sleep(0.05)     # reader notices EOF
            out_after = fe.serve("k", bufs)
            for key in out_before:
                np.testing.assert_allclose(np.asarray(out_after[key]),
                                           np.asarray(out_before[key]),
                                           rtol=2e-5, atol=2e-5)
            st = fe.stats()
            assert fe.tenant("k").worker != victim
            assert st["frontend"]["worker_deaths"] >= 1
            assert st["frontend"]["requeues"] >= 1
            assert st["frontend"]["alive"] == 1


# ---------------------------------------------------------------------------
# Multi-worker soak (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestClusterSoak:
    def test_four_workers_dependent_chains(self):
        with ClusterFrontend(workers=4, registry=REGISTRY_SPEC,
                             max_wait_ms=10.0, name="test-soak") as fe:
            shared = jnp.asarray(
                np.random.default_rng(1).standard_normal((DIM, DIM)),
                jnp.float32)
            tenants = []
            for i in range(8):
                tdg = demo_region(f"soak[{i}]", waves=2 + (i % 4))
                fe.register_tenant(f"s{i}", tdg, pinned={"w": shared})
                tenants.append((tdg, _bufs(100 + i, shared_w=shared)))
            # dependent chains: each round feeds the next
            state = [dict(b, w=shared) for _, b in tenants]
            for _ in range(6):
                futs = [fe.submit(f"s{i}", {k: v for k, v in state[i].items()
                                            if k != "w"})
                        for i in range(8)]
                for i, f in enumerate(futs):
                    state[i].update(f.result(300))
                    state[i]["w"] = shared
            # ground truth: replay the same chain in-process
            for i, (tdg, b) in enumerate(tenants):
                ex = ReplayExecutor(tdg)
                ref = dict(b)
                for _ in range(6):
                    ref.update(ex.run(dict(ref)))
                    ref["w"] = shared
                for k in ("x0", "x1"):
                    np.testing.assert_allclose(
                        np.asarray(state[i][k]), np.asarray(ref[k]),
                        rtol=2e-4, atol=2e-4)
            st = fe.stats()
            used = {r["worker"] for r in st["tenants"].values()}
            assert len(used) == 4          # 4 structures spread over 4 workers
            assert st["aggregate"]["failed"] == 0
