"""Training integration: fused step, TDG-granular step equivalence,
end-to-end loss decrease, serve step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EagerExecutor, topo_waves
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim import adamw
from repro.training import make_serve_step, make_tdg_train_region, make_train_step

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen2.5-3b", **kw):
    cfg = reduced(get_config(arch), **kw)
    params = init_params(cfg, KEY)
    opt = adamw(1e-2)
    return cfg, params, opt


def test_fused_step_decreases_loss():
    cfg, params, opt = _setup()
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=4))
    losses = []
    for i in range(30):
        b = ds.batch(i)
        params, state, m = step(params, state,
                                {"tokens": jnp.asarray(b["tokens"])})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_tdg_step_equals_fused_step():
    """The per-layer TDG region must produce the same updated params as the
    fused jit step (same math, different orchestration)."""
    cfg, params, opt = _setup(num_layers=2, tie_embeddings=False)
    tokens = jax.random.randint(KEY, (2, 16), 2, cfg.vocab_size)

    fused = make_train_step(cfg, opt)
    p_ref, s_ref, m_ref = fused(params, opt.init(params),
                                {"tokens": tokens})

    region = make_tdg_train_region(cfg, opt)
    out = region(params=params, opt_state=opt.init(params), tokens=tokens)
    assert region.records == 1
    np.testing.assert_allclose(float(out["loss"]), float(m_ref["ce"]),
                               rtol=1e-4)
    # AdamW divides by sqrt(nu)+eps: tiny-gradient entries amplify f32
    # reassociation differences between the two orchestrations, so compare
    # with an epsilon floor (atol dominated by lr*sqrt-denominator noise;
    # CPU XLA's threaded reductions make the reassociation order vary run
    # to run, with observed excursions up to ~5e-4 on these shapes).
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-3, rtol=5e-3),
        out["params"], p_ref)

    # replay (2nd call): record ran tasks op-by-op, replay is one fused
    # executable — same AdamW sqrt-denominator noise floor applies
    out2 = region(params=params, opt_state=opt.init(params), tokens=tokens)
    assert region.replays == 1
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-3, rtol=5e-3),
        out2["params"], out["params"])


def test_tdg_step_eager_executor_matches():
    """Run the recorded train TDG through the dynamic scheduler: same loss."""
    cfg, params, opt = _setup(num_layers=2, tie_embeddings=False)
    tokens = jax.random.randint(KEY, (2, 16), 2, cfg.vocab_size)
    region = make_tdg_train_region(cfg, opt, name="tdg_eager_check")
    out = region(params=params, opt_state=opt.init(params), tokens=tokens)
    ex = EagerExecutor(region.tdg, n_workers=4)
    out_e = ex.run({"params": params, "opt_state": opt.init(params),
                    "tokens": tokens}, outputs=["loss"])
    np.testing.assert_allclose(float(out_e["loss"]), float(out["loss"]),
                               rtol=1e-5)


def test_tdg_step_structure():
    cfg, params, opt = _setup(num_layers=3, tie_embeddings=False)
    region = make_tdg_train_region(cfg, opt, name="tdg_struct")
    region.build_static(
        params=jax.eval_shape(lambda: init_params(cfg, KEY)),
        opt_state=jax.eval_shape(lambda: opt.init(init_params(cfg, KEY))),
        tokens=jax.ShapeDtypeStruct((2, 16), jnp.int32))
    n = cfg.num_layers
    # embed + n fwd + head_loss + head_bwd + n bwd + embed_bwd + opt
    assert region.tdg.num_tasks == 2 * n + 5
    waves = topo_waves(region.tdg)
    names = [region.tdg.tasks[t].label() for t in waves[1]]
    assert "fwd_L0" in names          # fwd chain starts in wave 1
    # bwd of layer i and nothing else can overlap with head_bwd
    assert any("head_bwd" in region.tdg.tasks[t].label()
               for w in waves for t in w)


def test_serve_step_runs_and_caches_advance():
    cfg, params, _ = _setup(arch="qwen2.5-3b")
    from repro.models import prefill
    B = 2
    batch = {"tokens": jax.random.randint(KEY, (B, 8), 2, cfg.vocab_size)}
    logits, caches, pos = prefill(params, cfg, batch, max_len=16)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(3,))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    for _ in range(4):
        tok, caches = serve(params, tok[:, None], pos, caches)
        pos = pos + 1
    assert tok.shape == (B,)
    assert int(pos[0]) == 12


@pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b"])
def test_serve_step_ssm_families(arch):
    cfg, params, _ = _setup(arch=arch)
    from repro.models import prefill
    batch = {"tokens": jax.random.randint(KEY, (1, 8), 2, cfg.vocab_size)}
    logits, caches, pos = prefill(params, cfg, batch, max_len=64)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(3,))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    for _ in range(3):
        tok, caches = serve(params, tok[:, None], pos, caches)
        pos = pos + 1
    assert np.isfinite(np.asarray(tok)).all()
