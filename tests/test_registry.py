"""Kernel substrate registry + compat shim tests.

Fast section: registry semantics (registration, resolution, mode state,
eager env validation), compat feature detection, ReplayExecutor substrate
pinning, and one small ref-vs-interpret parity case per op — these run in
the default tier-1 sweep and are the acceptance check that all four Pallas
kernels run green in interpret mode through the registry.

Slow section (``-m slow``): broader interpret-mode parity sweeps over
shapes/dtypes, excluded from the default run to keep tier-1 fast.
"""
import pathlib
import subprocess
import sys
import threading

ROOT = pathlib.Path(__file__).resolve().parents[1]

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TDG, ReplayExecutor
from repro.kernels import compat, ops, ref, registry


def _arr(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ------------------------------------------------------------------ registry

class TestRegistrySemantics:
    def test_all_ops_registered(self):
        assert {"attention", "rmsnorm", "grouped_matmul", "ssd"} <= set(
            registry.ops())

    def test_every_op_has_all_substrates(self):
        for op in ("attention", "rmsnorm", "grouped_matmul", "ssd"):
            modes = {m for _, m in registry.substrates(op)}
            assert modes == {"pallas", "ref", "interpret"}, (op, modes)

    def test_set_kernel_mode_rejects_bogus(self):
        with pytest.raises(ValueError, match="invalid kernel mode"):
            registry.set_kernel_mode("fastplz")

    def test_env_mode_validated_eagerly(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNELS", "bogus")
        with pytest.raises(ValueError, match="REPRO_KERNELS"):
            registry._env_mode()

    def test_mode_scope_restores_on_exit_and_error(self):
        before = registry.kernel_mode()
        with registry.kernel_mode_scope("interpret"):
            assert registry.kernel_mode() == "interpret"
        assert registry.kernel_mode() == before
        with pytest.raises(RuntimeError):
            with registry.kernel_mode_scope("ref"):
                raise RuntimeError("boom")
        assert registry.kernel_mode() == before

    def test_mode_scope_is_thread_local(self):
        """A scope on one thread must not leak into another (concurrent
        executors pin different substrates)."""
        seen = {}

        def worker():
            seen["mode"] = registry.kernel_mode()

        with registry.kernel_mode_scope("interpret"):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
            assert registry.kernel_mode() == "interpret"
        assert seen["mode"] == registry.kernel_mode()  # base, not the scope

    def test_auto_resolves_per_platform(self):
        concrete = registry.resolved_mode("auto")
        assert concrete in ("pallas", "ref")
        assert concrete == ("pallas" if compat.tpu_available() else "ref")

    def test_unknown_op_raises_with_known_ops(self):
        with pytest.raises(KeyError, match="registered ops"):
            registry.resolve("transmogrify")

    def test_missing_substrate_lists_alternatives(self):
        registry.register("_probe_partial", "ref", fn=lambda: "ref")
        try:
            with pytest.raises(KeyError, match="available"):
                registry.resolve("_probe_partial", mode="interpret")
        finally:
            registry._impls.pop(("_probe_partial", "*", "ref"), None)

    def test_register_decorator_and_override(self):
        key = ("_probe_override", "*", "ref")
        try:
            @registry.register("_probe_override", "ref")
            def first():
                return 1

            assert registry.dispatch("_probe_override", mode="ref") == 1
            registry.register("_probe_override", "ref", fn=lambda: 2)
            assert registry.dispatch("_probe_override", mode="ref") == 2
        finally:
            registry._impls.pop(key, None)

    def test_cannot_register_auto(self):
        with pytest.raises(ValueError, match="resolution rule"):
            registry.register("x", "auto", fn=lambda: None)

    def test_dispatch_explicit_mode_overrides_global(self, rng):
        x, w = _arr(rng, 8, 64), _arr(rng, 64)
        with registry.kernel_mode_scope("interpret"):
            got = registry.dispatch("rmsnorm", x, w, mode="ref")
        np.testing.assert_allclose(got, ref.rmsnorm_ref(x, w),
                                   atol=1e-6, rtol=1e-6)

    @pytest.mark.slow
    def test_bogus_env_fails_at_import(self):
        proc = subprocess.run(
            [sys.executable, "-c", "import repro.kernels.ops"],
            capture_output=True, text=True,
            env={"PYTHONPATH": str(ROOT / "src"), "REPRO_KERNELS": "bogus",
                 "PATH": "/usr/bin:/bin:/usr/local/bin"},
            cwd=str(ROOT))
        assert proc.returncode != 0
        assert "REPRO_KERNELS" in proc.stderr


# -------------------------------------------------------------------- compat

class TestCompat:
    def test_compiler_params_resolved_by_feature_detection(self):
        params = compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"))
        if compat.has_tpu_compiler_params():
            assert params is not None
            assert tuple(params.dimension_semantics) == ("parallel",
                                                         "arbitrary")
        else:
            assert params is None

    def test_unknown_hint_fields_are_dropped(self):
        params = compat.tpu_compiler_params(
            dimension_semantics=("parallel",),
            definitely_not_a_real_hint_field_xyz=1)
        if compat.has_tpu_compiler_params():
            assert not hasattr(params, "definitely_not_a_real_hint_field_xyz")

    def test_interpret_supported_here(self):
        # this repo's CPU CI depends on interpret mode existing
        assert compat.interpret_supported()

    def test_pallas_call_interpret_smoke(self):
        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        x = jnp.ones((8, 128), jnp.float32)
        out = compat.pallas_call(
            kernel, out_shape=jnp.zeros_like(x),
            compiler_params=compat.tpu_compiler_params(),
            name="double", interpret=True)(x)
        np.testing.assert_allclose(out, 2.0)


# -------------------------------------------------- executor substrate pinning

class TestReplayExecutorPinning:
    @pytest.fixture()
    def probe_op(self):
        registry.register("_probe_sub", "ref",
                          fn=lambda x: x + jnp.float32(1.0))
        registry.register("_probe_sub", "interpret",
                          fn=lambda x: x + jnp.float32(2.0))
        registry.register("_probe_sub", "pallas",
                          fn=lambda x: x + jnp.float32(3.0))
        yield "_probe_sub"
        for mode in ("ref", "interpret", "pallas"):
            registry._impls.pop(("_probe_sub", "*", mode), None)

    def _tdg(self, probe_op):
        tdg = TDG("probe")
        tdg.add_task(lambda x: registry.dispatch(probe_op, x),
                     ins=["x"], outs=["y"])
        return tdg, {"x": jnp.zeros((4,), jnp.float32)}

    def test_substrate_resolved_once_at_construction(self, probe_op):
        tdg, bufs = self._tdg(probe_op)
        ex = ReplayExecutor(tdg, kernel_mode="interpret")
        registry.set_kernel_mode("ref")
        try:
            out = ex.run(dict(bufs))
        finally:
            registry.set_kernel_mode("auto")
        # global says ref (+1) but the executor pinned interpret (+2)
        np.testing.assert_allclose(out["y"], 2.0)

    def test_default_mode_captured_from_global(self, probe_op):
        tdg, bufs = self._tdg(probe_op)
        with registry.kernel_mode_scope("interpret"):
            ex = ReplayExecutor(tdg)
        assert ex.kernel_mode == "interpret"
        np.testing.assert_allclose(ex.run(dict(bufs))["y"], 2.0)

    def test_cache_keyed_by_mode(self, probe_op):
        tdg, bufs = self._tdg(probe_op)
        a = ReplayExecutor(tdg, kernel_mode="ref")
        b = ReplayExecutor(tdg, kernel_mode="interpret")
        np.testing.assert_allclose(a.run(dict(bufs))["y"], 1.0)
        np.testing.assert_allclose(b.run(dict(bufs))["y"], 2.0)

    def test_auto_is_pinned_to_concrete(self, probe_op):
        tdg, _ = self._tdg(probe_op)
        ex = ReplayExecutor(tdg, kernel_mode="auto")
        assert ex.kernel_mode in ("pallas", "ref")


# ------------------------------------------- ref vs interpret parity (fast)

class TestParityFast:
    """One small case per op: the registry's interpret substrate (real
    Pallas kernel bodies) must match the jnp references on CPU."""

    def _pair(self, op, *args, **kwargs):
        with registry.kernel_mode_scope("ref"):
            want = registry.dispatch(op, *args, **kwargs)
        with registry.kernel_mode_scope("interpret"):
            got = registry.dispatch(op, *args, **kwargs)
        return got, want

    def test_rmsnorm(self, rng):
        got, want = self._pair("rmsnorm", _arr(rng, 16, 64), _arr(rng, 64))
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    def test_grouped_matmul(self, rng):
        got, want = self._pair("grouped_matmul",
                               _arr(rng, 2, 16, 128, scale=0.3),
                               _arr(rng, 2, 128, 128, scale=0.3))
        np.testing.assert_allclose(got, want, atol=3e-3, rtol=1e-4)

    def test_attention(self, rng):
        q, k, v = (_arr(rng, 1, 64, 2, 32), _arr(rng, 1, 64, 1, 32),
                   _arr(rng, 1, 64, 1, 32))
        got, want = self._pair("attention", q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_ssd(self, rng):
        x = _arr(rng, 1, 64, 2, 16)
        dt = jnp.abs(_arr(rng, 1, 64, 2)) * 0.1 + 0.01
        A = -jnp.abs(_arr(rng, 2)) - 0.1
        Bm = _arr(rng, 1, 64, 1, 16, scale=0.5)
        Cm = _arr(rng, 1, 64, 1, 16, scale=0.5)
        (y_got, h_got), (y_want, h_want) = self._pair(
            "ssd", x, dt, A, Bm, Cm, chunk=32)
        np.testing.assert_allclose(y_got, y_want, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(h_got, h_want, atol=1e-3, rtol=1e-3)


# ------------------------------------------- ref vs interpret parity (slow)

@pytest.mark.slow
class TestParitySweep:
    """Broader interpret sweeps (shapes, dtypes, op variants) — `-m slow`."""

    def _pair(self, op, *args, **kwargs):
        with registry.kernel_mode_scope("ref"):
            want = registry.dispatch(op, *args, **kwargs)
        with registry.kernel_mode_scope("interpret"):
            got = registry.dispatch(op, *args, **kwargs)
        return got, want

    @pytest.mark.parametrize("shape", [(4, 17, 64), (2, 128, 256)])
    @pytest.mark.parametrize("residual", [False, True])
    def test_rmsnorm(self, rng, shape, residual):
        x, w = _arr(rng, *shape), _arr(rng, shape[-1])
        r = _arr(rng, *shape) if residual else None
        got, want = self._pair("rmsnorm", x, w, residual=r)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("E,C,d,f", [(4, 64, 128, 128), (2, 100, 256, 128)])
    def test_grouped_matmul(self, rng, E, C, d, f, dtype):
        got, want = self._pair("grouped_matmul",
                               _arr(rng, E, C, d, dtype=dtype, scale=0.3),
                               _arr(rng, E, d, f, dtype=dtype, scale=0.3))
        atol = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}[dtype] * d
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=atol, rtol=1e-2)

    @pytest.mark.parametrize("kw", [
        dict(causal=True), dict(causal=False), dict(causal=True, window=64),
        dict(causal=True, chunk=64), dict(causal=True, q_offset=128),
    ])
    def test_attention_variants(self, rng, kw):
        sq = 1 if kw.get("q_offset") else 128
        q = _arr(rng, 2, sq, 4, 64)
        k, v = _arr(rng, 2, 128, 2, 64), _arr(rng, 2, 128, 2, 64)
        got, want = self._pair("attention", q, k, v, **kw)
        np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)

    @pytest.mark.parametrize("S,H,P,G,N,chunk", [
        (128, 2, 32, 1, 16, 32), (256, 4, 64, 2, 32, 64),
    ])
    def test_ssd(self, rng, S, H, P, G, N, chunk):
        x = _arr(rng, 2, S, H, P)
        dt = jnp.abs(_arr(rng, 2, S, H)) * 0.1 + 0.01
        A = -jnp.abs(_arr(rng, H)) - 0.1
        Bm = _arr(rng, 2, S, G, N, scale=0.5)
        Cm = _arr(rng, 2, S, G, N, scale=0.5)
        D = _arr(rng, H)
        (y_got, h_got), (y_want, h_want) = self._pair(
            "ssd", x, dt, A, Bm, Cm, D=D, chunk=chunk)
        np.testing.assert_allclose(y_got, y_want, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(h_got, h_want, atol=1e-3, rtol=1e-3)
