"""Chaos soak: seeded faults against the self-healing cluster tier.

    PYTHONPATH=src python -m benchmarks.chaos [--smoke] [--out PATH]

The self-healing machinery (heartbeat leases, supervised respawn, request
deadlines, retry/backoff, load shedding — ``repro.serving.cluster`` +
``repro.serving.faults``) is exercised end to end by a three-phase soak:

* **baseline** — a clean 2-worker fleet serves warm-shipped tenants with
  no faults armed; records throughput and checks exact parity against
  ``ReplayExecutor``. This is the yardstick the recovered fleet is held
  to.

* **chaos** — a seeded :class:`~repro.serving.faults.FaultPlan` is
  exported via ``REPRO_FAULT_PLAN`` before the fleet starts (workers
  inherit it; the frontend arms it too), ``REPRO_QUEUE_BOUND`` bounds the
  workers' admission queues, and a burst of deadline-bounded requests is
  driven through while one worker is SIGKILLed mid-burst. The plan drops
  a submit frame at a worker, drops a result frame at the frontend,
  stalls a shm ring ack and delays sends — every recovery path (death
  requeue, retry backoff, deadline shedding, queue shedding, ring-credit
  self-healing, supervised warm respawn) runs in one soak. The gate is
  the robustness contract: **every request resolves** — a correct result
  (exact parity) or a *typed* error (``DeadlineExceeded`` / ``QueueFull``
  / ``ClusterError``) — no hangs, no bare futures timeouts, no foreign
  exceptions.

* **recovery** — faults are disarmed, the supervisor has respawned the
  killed slot, and the same tenants are driven again. Gates: the
  replacement came back *warm* (the respawn re-shipped the frontend-held
  artifact: zero intern misses on the replacement, ``aot_served >= 1``,
  zero hydrate failures), results keep exact parity, and throughput is
  within tolerance of the baseline (the fleet healed, not limped).

After ``frontend.close()`` the harness asserts nothing leaked: every
worker pid ever spawned (including the replacement) is gone, and no
``repro-ring-*`` shared-memory segments created by this process remain
in ``/dev/shm``.

Determinism: the fault plan is seeded and fires on exact per-point event
counters; the kill lands at a fixed request index. Counts of *which*
typed error each shed request gets vary with scheduling (single-core CI
hosts), so gates assert the resolution contract and recovery invariants,
never exact error tallies.

The report lands in ``BENCH_chaos.json``; ``--smoke`` is the CI-sized
variant wired into ``scripts/ci.sh --bench-smoke``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import signal
import tempfile
import time

import numpy as np

from repro.serving import faults as faults_mod

REGISTRY_SPEC = "repro.serving.demo:DEMO_REGISTRY"

#: The seeded chaos schedule. ``after`` offsets skip the per-tenant warm
#: serves (frames 1-2 at each point), so faults land inside the burst.
CHAOS_RULES = [
    {"role": "worker", "point": "recv", "op": "submit_batch",
     "after": 3, "count": 1, "action": "drop"},
    {"role": "worker", "point": "send", "op": "result_batch",
     "after": 1, "count": 2, "action": "delay", "secs": 0.05},
    {"role": "worker", "point": "ring_ack", "after": 2, "count": 1,
     "action": "drop"},
    {"role": "frontend", "point": "recv", "op": "result_batch",
     "after": 4, "count": 1, "action": "drop"},
]
CHAOS_SEED = 2026


def _make_tenants(n_tenants: int, dim: int, waves: int, width: int,
                  workdir: str):
    """Warm-artifact tenants over distinct structures (spread by router).

    Each tenant is warmed ONCE in this process (``warmup_and_save``) and
    registered from the artifact, so the frontend holds the bytes it
    needs to re-ship at respawn — the warm-respawn gate depends on that.
    """
    import jax.numpy as jnp

    from repro.core import ReplayExecutor, warmup_and_save
    from repro.serving.demo import DEMO_REGISTRY, demo_region

    rng = np.random.default_rng(0)
    shared_w = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    tenants = []
    for i in range(n_tenants):
        tdg = demo_region(f"chaos[{i}]", waves=waves + i, width=width)
        bufs = {f"x{k}": jnp.asarray(rng.standard_normal((dim, dim)),
                                     jnp.float32) for k in range(width)}
        warm_path = os.path.join(workdir, f"chaos{i}.json")
        warmup_and_save(tdg, {**bufs, "w": shared_w}, warm_path,
                        DEMO_REGISTRY)
        expected = {k: np.asarray(v) for k, v in
                    ReplayExecutor(tdg).run({**bufs, "w": shared_w}).items()}
        tenants.append({"name": f"c{i}", "warm_path": warm_path,
                        "bufs": bufs, "expected": expected})
    return tenants, shared_w


def _check_parity(out: dict, expected: dict) -> None:
    for k in expected:
        np.testing.assert_allclose(np.asarray(out[k]), expected[k],
                                   rtol=2e-4, atol=2e-4)


def _new_frontend(workers: int, name: str, deadline_s: float,
                  heartbeat_secs: float = 0.5):
    from repro.serving import ClusterFrontend
    return ClusterFrontend(workers=workers, registry=REGISTRY_SPEC,
                           max_batch=4, max_wait_ms=5.0,
                           heartbeat_secs=heartbeat_secs, lease_misses=3,
                           respawn_max=5, request_deadline=deadline_s,
                           retry_budget=2, name=name)


def _register_all(frontend, tenants, shared_w) -> None:
    for t in tenants:
        frontend.register_tenant(t["name"], warm_path=t["warm_path"],
                                 pinned={"w": shared_w})


def _drive_rounds(frontend, tenants, rounds: int) -> float:
    """Sequential warm serves (parity-checked); returns requests/sec."""
    t0 = time.perf_counter()
    for _ in range(rounds):
        for t in tenants:
            out = frontend.serve(t["name"], t["bufs"], timeout=300)
            _check_parity(out, t["expected"])
    wall = time.perf_counter() - t0
    return rounds * len(tenants) / max(wall, 1e-9)


def _wait_pids_gone(pids, timeout_s: float = 30.0) -> list[int]:
    """Pids from ``pids`` still alive after ``timeout_s`` (leak check)."""
    deadline = time.monotonic() + timeout_s
    leaked = set(pids)
    while leaked and time.monotonic() < deadline:
        for pid in list(leaked):
            try:
                os.kill(pid, 0)
            except OSError:
                leaked.discard(pid)
        if leaked:
            time.sleep(0.2)
    return sorted(leaked)


def bench_baseline(tenants, shared_w, rounds: int,
                   deadline_s: float) -> dict:
    frontend = _new_frontend(2, "bench-chaos-base", deadline_s)
    try:
        _register_all(frontend, tenants, shared_w)
        _drive_rounds(frontend, tenants, 1)            # warm off the clock
        rps = _drive_rounds(frontend, tenants, rounds)
        stats = frontend.stats()
    finally:
        frontend.close()
    return {"throughput_rps": rps, "requests": rounds * len(tenants),
            "aot_served": stats["aggregate"]["aot_served"],
            "intern_misses": sum(w["intern"]["misses"]
                                 for w in stats["workers"].values()
                                 if w is not None)}


def bench_chaos_and_recovery(tenants, shared_w, n_requests: int,
                             deadline_s: float, recovery_rounds: int) -> dict:
    """The soak: armed fleet, mid-burst SIGKILL, resolution + recovery."""
    from repro.serving import (ClusterError, DeadlineExceeded, FaultPlan,
                               QueueFull)

    plan = FaultPlan(rules=CHAOS_RULES, seed=CHAOS_SEED)
    os.environ[faults_mod.FAULT_PLAN_ENV] = plan.to_json()
    os.environ["REPRO_QUEUE_BOUND"] = "16"
    pids: set[int] = set()
    try:
        frontend = _new_frontend(2, "bench-chaos-soak", deadline_s)
        try:
            _register_all(frontend, tenants, shared_w)
            pids.update(h.process.pid for h in frontend._handles
                        if h.process is not None)
            # One warm serve per tenant: proves the fleet is up and moves
            # the frame counters past the rules' `after` offsets.
            for t in tenants:
                _check_parity(frontend.serve(t["name"], t["bufs"],
                                             timeout=300), t["expected"])

            victim = frontend.stats()["tenants"][tenants[0]["name"]]["worker"]
            victim_pid = frontend._handles[victim].process.pid

            # Burst in small waves so the dispatcher cuts several wire
            # frames (one giant coalesced frame would starve the per-frame
            # fault counters of events).
            futures = []
            kill_at = n_requests // 3
            killed_at = None
            for i in range(n_requests):
                t = tenants[i % len(tenants)]
                futures.append((t, frontend.submit(
                    t["name"], t["bufs"], deadline_s=deadline_s)))
                if i % 4 == 3:
                    time.sleep(0.02)
                if i == kill_at:
                    os.kill(victim_pid, signal.SIGKILL)
                    killed_at = i
                    # The replacement must bootstrap CLEAN: its env must
                    # not re-arm the plan (fresh counters would re-fire
                    # rules during the recovery phase).
                    os.environ.pop(faults_mod.FAULT_PLAN_ENV, None)

            # Resolution contract: every future resolves — result or
            # typed error — within deadline + supervisor slack. A bare
            # futures TimeoutError here is a hang and fails the soak.
            ok = 0
            typed: dict[str, int] = {}
            other: list[str] = []
            wait = deadline_s + 90.0
            t0 = time.perf_counter()
            for t, fut in futures:
                exc = fut.exception(timeout=max(1.0,
                                                wait - (time.perf_counter()
                                                        - t0)))
                if exc is None:
                    _check_parity(fut.result(), t["expected"])
                    ok += 1
                elif isinstance(exc, (DeadlineExceeded, QueueFull,
                                      ClusterError)):
                    name = type(exc).__name__
                    typed[name] = typed.get(name, 0) + 1
                else:
                    other.append(f"{type(exc).__name__}: {exc}")
            resolve_wall = time.perf_counter() - t0

            # Wait for the supervisor to respawn the killed slot.
            t0 = time.perf_counter()
            while time.perf_counter() - t0 < 120.0:
                if frontend.respawns >= 1 and \
                        all(h.alive for h in frontend._handles):
                    break
                time.sleep(0.1)
            recovery_wait_s = time.perf_counter() - t0
            pids.update(h.process.pid for h in frontend._handles
                        if h.process is not None)

            # Capture what fired (the armed plan is the env round-trip of
            # `plan`, installed at frontend construction), then disarm
            # everything before timing the healed fleet.
            armed = faults_mod.active()
            fired = armed.fired() if armed is not None else []
            faults_mod.clear()

            recovery_rps = _drive_rounds(frontend, tenants, recovery_rounds)
            stats = frontend.stats()
        finally:
            frontend.close()
            faults_mod.clear()
    finally:
        os.environ.pop(faults_mod.FAULT_PLAN_ENV, None)
        os.environ.pop("REPRO_QUEUE_BOUND", None)

    # Every pid this soak ever spawned must be gone after close().
    leaked = _wait_pids_gone(pids)

    victim_stats = stats["workers"].get(victim) or {}
    fe = stats["frontend"]
    return {
        "requests": n_requests,
        "killed_at_request": killed_at,
        "victim": victim,
        "ok": ok,
        "typed_errors": typed,
        "other_errors": other,
        "resolve_wall_s": resolve_wall,
        "recovery_wait_s": recovery_wait_s,
        "worker_deaths": fe["worker_deaths"],
        "respawns": fe["respawns"],
        "respawn_failures": fe["respawn_failures"],
        "requeues": fe["requeues"],
        "retries": fe["retries"],
        "heartbeat_misses": fe["heartbeat_misses"],
        "deadline_failures": fe["deadline_failures"],
        "shed": stats["aggregate"].get("shed", 0),
        "deadline_sheds": stats["aggregate"].get("deadline_sheds", 0),
        "recovery_throughput_rps": recovery_rps,
        "victim_intern_misses": victim_stats.get("intern", {}).get(
            "misses", -1),
        "victim_aot_served": victim_stats.get("metrics", {}).get(
            "aot_served", -1),
        "aot_hydrate_failures": stats["aggregate"]["aot_hydrate_failures"],
        "artifacts_shipped": fe["artifacts_shipped"],
        "plan": {"seed": CHAOS_SEED, "rules": CHAOS_RULES,
                 "frontend_fired": fired},
        "leaked_pids": sorted(leaked),
    }


def bench_warm_respawn(tenant, shared_w, deadline_s: float) -> dict:
    """Kill a ONE-worker fleet's only worker; the replacement must serve.

    With no sibling to requeue to, the retry backoff has to wait out the
    supervised respawn, and the respawn's re-registration re-ships the
    frontend-held artifact — so the replacement serving at all proves the
    whole loop, and serving *warm* (zero intern misses, ``aot_served >=
    1`` in a process that never compiled) proves the artifact ship. The
    2-worker soak can't gate this: its victim's tenants requeue to the
    sibling and stay there (sticky routing), so the replacement idles.
    """
    from repro.serving import ClusterError, DeadlineExceeded

    frontend = _new_frontend(1, "bench-chaos-respawn", deadline_s,
                             heartbeat_secs=0.3)
    pids = set()
    try:
        frontend.register_tenant(tenant["name"], warm_path=tenant["warm_path"],
                                 pinned={"w": shared_w})
        pids.add(frontend._handles[0].process.pid)
        _check_parity(frontend.serve(tenant["name"], tenant["bufs"],
                                     timeout=300), tenant["expected"])
        t0 = time.perf_counter()
        os.kill(frontend._handles[0].process.pid, signal.SIGKILL)
        # A real client retries typed errors; the in-frontend retry
        # budget alone can expire while the slot is still respawning.
        out = None
        client_attempts = 0
        while out is None:
            client_attempts += 1
            try:
                out = frontend.serve(tenant["name"], tenant["bufs"],
                                     timeout=deadline_s)
            except (ClusterError, DeadlineExceeded):
                if time.perf_counter() - t0 > 90.0:
                    raise
                time.sleep(0.25)
        respawn_to_serve_s = time.perf_counter() - t0
        _check_parity(out, tenant["expected"])
        pids.add(frontend._handles[0].process.pid)
        stats = frontend.stats()
    finally:
        frontend.close()
    worker = stats["workers"][0] or {}
    return {
        "respawn_to_serve_s": respawn_to_serve_s,
        "client_attempts": client_attempts,
        "respawns": stats["frontend"]["respawns"],
        "retries": stats["frontend"]["retries"],
        "shm_fallbacks": stats["frontend"]["shm_fallbacks"],
        "intern_misses": worker.get("intern", {}).get("misses", -1),
        "aot_served": worker.get("metrics", {}).get("aot_served", -1),
        "aot_hydrate_failures": stats["aggregate"]["aot_hydrate_failures"],
        "leaked_pids": _wait_pids_gone(pids),
    }


def run(n_requests: int = 48, baseline_rounds: int = 4,
        recovery_rounds: int = 4, dim: int = 16, waves: int = 2,
        width: int = 3, deadline_s: float = 25.0,
        out_path: str = "BENCH_chaos.json") -> dict:
    shm_before = set(glob.glob(f"/dev/shm/repro-ring-{os.getpid()}-*"))
    workdir = tempfile.mkdtemp(prefix="bench_chaos_")
    tenants, shared_w = _make_tenants(2, dim, waves, width, workdir)

    print("# phase 1/4: baseline (clean 2-worker fleet, warm-shipped)",
          flush=True)
    baseline = bench_baseline(tenants, shared_w, baseline_rounds, deadline_s)
    print(f"  {baseline['throughput_rps']:.1f} req/s | aot_served "
          f"{baseline['aot_served']} | intern misses "
          f"{baseline['intern_misses']}", flush=True)

    print("# phase 2/4: chaos soak (seeded fault plan + mid-burst SIGKILL)",
          flush=True)
    chaos = bench_chaos_and_recovery(tenants, shared_w, n_requests,
                                     deadline_s, recovery_rounds)
    print(f"  {chaos['ok']}/{chaos['requests']} ok | typed "
          f"{chaos['typed_errors']} | deaths {chaos['worker_deaths']} | "
          f"respawns {chaos['respawns']} | requeues {chaos['requeues']} | "
          f"retries {chaos['retries']} | shed {chaos['shed']} | "
          f"deadline sheds {chaos['deadline_sheds']}", flush=True)

    print("# phase 3/4: recovery (faults disarmed, respawned fleet)",
          flush=True)
    ratio = chaos["recovery_throughput_rps"] / max(
        baseline["throughput_rps"], 1e-9)
    print(f"  {chaos['recovery_throughput_rps']:.1f} req/s "
          f"({ratio:.2f}x baseline) | victim intern misses "
          f"{chaos['victim_intern_misses']} | leaked pids "
          f"{chaos['leaked_pids']}", flush=True)

    print("# phase 4/4: warm respawn (1-worker fleet, replacement must "
          "serve)", flush=True)
    respawn = bench_warm_respawn(tenants[0], shared_w, deadline_s)
    print(f"  kill -> warm serve {respawn['respawn_to_serve_s']:.2f} s "
          f"({respawn['client_attempts']} client attempts) | intern misses "
          f"{respawn['intern_misses']} | aot_served {respawn['aot_served']} "
          f"| shm fallbacks {respawn['shm_fallbacks']}", flush=True)

    shm_leaked = sorted(set(glob.glob(
        f"/dev/shm/repro-ring-{os.getpid()}-*")) - shm_before)
    report = {"bench": "chaos", "dim": dim, "waves": waves, "width": width,
              "deadline_s": deadline_s, "baseline": baseline, "chaos": chaos,
              "recovery_ratio": ratio, "warm_respawn": respawn,
              "shm_leaked": shm_leaked}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {out_path}", flush=True)
    return report


def _assert_gates(report: dict, recovery_tolerance: float) -> None:
    chaos = report["chaos"]
    # The robustness contract: every request in the soak resolved — a
    # parity-checked result or a typed error. No hangs, nothing foreign.
    assert chaos["ok"] + sum(chaos["typed_errors"].values()) \
        == chaos["requests"], chaos
    assert not chaos["other_errors"], chaos
    assert chaos["ok"] >= 1, chaos
    # The kill was noticed (lease expiry or broken pipe), the slot was
    # respawned by the supervisor, and inflight work moved to a sibling.
    assert chaos["worker_deaths"] >= 1, chaos
    assert chaos["respawns"] >= 1, chaos
    assert chaos["requeues"] >= 1, chaos
    # The soak's replacement never lowered anything (its tenants moved to
    # the sibling; if anything reached it, it was hydrated, not compiled).
    assert chaos["victim_intern_misses"] == 0, chaos
    assert chaos["aot_hydrate_failures"] == 0, chaos
    # The healed fleet performs: recovery throughput within tolerance of
    # the clean baseline (single-core CI jitters; this is a limp check,
    # not a benchmark).
    assert report["recovery_ratio"] >= recovery_tolerance, report
    # Warm respawn (1-worker fleet): the replacement hydrated the
    # re-shipped artifact and served from AOT — it never compiled.
    respawn = report["warm_respawn"]
    assert respawn["respawns"] >= 1, respawn
    assert respawn["intern_misses"] == 0, respawn
    assert respawn["aot_served"] >= 1, respawn
    assert respawn["aot_hydrate_failures"] == 0, respawn
    # Nothing leaked: no worker processes, no shm segments.
    assert not chaos["leaked_pids"], chaos
    assert not respawn["leaked_pids"], respawn
    assert not report["shm_leaked"], report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized soak: smaller burst, looser recovery "
                         "tolerance; same resolution/respawn/leak gates")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)
    if args.smoke:
        report = run(n_requests=32, baseline_rounds=3, recovery_rounds=3,
                     dim=16, waves=2, width=3, deadline_s=25.0,
                     out_path=args.out)
        _assert_gates(report, recovery_tolerance=0.35)
        print("# smoke ok: 100% resolution under seeded chaos + SIGKILL, "
              "warm respawn (0 intern misses), no leaked pids/shm, "
              "recovered throughput within tolerance")
    else:
        report = run(out_path=args.out)
        _assert_gates(report, recovery_tolerance=0.5)
        print(f"# acceptance: {report['chaos']['ok']}/"
              f"{report['chaos']['requests']} results + typed errors "
              f"{report['chaos']['typed_errors']}; respawns "
              f"{report['chaos']['respawns']}; recovery "
              f"{report['recovery_ratio']:.2f}x baseline; zero leaks")


if __name__ == "__main__":
    main()
