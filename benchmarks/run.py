"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                            [--kernels MODE[,MODE...]]

Sections:
  contention             Fig. 2 / Table 1  (orchestration overhead vs #tasks)
  speedup_grid           Figs. 6/7         (granularity x workers heatmaps)
  amortization           Figs. 8/9         (record-cost amortization)
  granularity_stability  Fig. 10           (stability under fine granularity)
  roofline               (beyond paper)    (dry-run roofline terms)

``--kernels`` sweeps the kernel substrate (see ``repro.kernels.registry``):
each listed mode (``auto``, ``pallas``, ``ref``, ``interpret``) runs the
selected sections under that substrate, so contention/amortization numbers
for registry-dispatched workloads (rmsnorm, attention) are comparable
across substrates from one invocation.

Prints ``name,us_per_call,derived`` CSV rows per section.
"""
from __future__ import annotations

import argparse
import time


_EPILOG = """\
benchmark modules in this package (sections marked * run via this driver):
  contention.py*            orchestration overhead vs #tasks (Fig. 2/Table 1)
  speedup_grid.py*          granularity x workers heatmaps   (Figs. 6/7)
  amortization.py*          record-cost amortization          (Figs. 8/9)
  granularity_stability.py* stability under fine granularity  (Fig. 10)
  roofline.py*              dry-run roofline terms            (beyond paper)
  fusion.py                 wave-fused vs unrolled lowering; standalone:
                            python -m benchmarks.fusion [--smoke]
  serving.py                multi-tenant batched admission vs serial replay;
                            standalone: python -m benchmarks.serving [--smoke]
  cluster.py                distributed frontend: RPC overhead, warm-artifact
                            cold start, worker scaling; standalone:
                            python -m benchmarks.cluster [--smoke]
"""


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        epilog=_EPILOG, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="run a single section by name")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated kernel substrate sweep "
                         "(auto,pallas,ref,interpret); default: the current "
                         "global mode (REPRO_KERNELS or auto)")
    args = ap.parse_args(argv)

    from repro.kernels import registry

    if args.kernels is None:
        modes = [registry.kernel_mode()]    # respect REPRO_KERNELS
    else:
        modes = [m.strip() for m in args.kernels.split(",") if m.strip()]
    for m in modes:
        registry.validate_mode(m)   # fail fast, before any section runs

    from . import (amortization, contention, granularity_stability, roofline,
                   speedup_grid)

    sections = {
        "contention": lambda: contention.run(
            task_counts=(1, 4, 16, 64) if args.quick
            else (1, 4, 16, 64, 256, 1024)),
        "speedup_grid": lambda: speedup_grid.run(
            workloads=("cholesky", "axpy", "rmsnorm") if args.quick
            else ("cholesky", "heat", "nbody", "axpy", "dotp",
                  "rmsnorm", "attention"),
            grains=(4, 8) if args.quick else (4, 8, 16),
            workers=(1, 4) if args.quick else (1, 4, 8)),
        "amortization": lambda: amortization.run(
            workloads=("cholesky", "axpy") if args.quick
            else ("cholesky", "heat", "axpy", "dotp", "rmsnorm"),
            iter_counts=(4, 16) if args.quick else (4, 64)),
        "granularity_stability": lambda: granularity_stability.run(
            grains=(4, 8) if args.quick else (2, 4, 8, 16, 32)),
        "roofline": roofline.run,
    }
    for mode in modes:
        if len(modes) > 1:
            print(f"\n########## kernels={mode} ##########", flush=True)
        with registry.kernel_mode_scope(mode):
            for name, fn in sections.items():
                if args.only and name != args.only:
                    continue
                print(f"\n===== {name} [kernels={mode}] =====", flush=True)
                t0 = time.time()
                fn()
                print(f"# section {name} done in {time.time()-t0:.1f}s",
                      flush=True)


if __name__ == "__main__":
    main()
