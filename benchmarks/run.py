"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Sections:
  contention             Fig. 2 / Table 1  (orchestration overhead vs #tasks)
  speedup_grid           Figs. 6/7         (granularity x workers heatmaps)
  amortization           Figs. 8/9         (record-cost amortization)
  granularity_stability  Fig. 10           (stability under fine granularity)
  roofline               (beyond paper)    (dry-run roofline terms)

Prints ``name,us_per_call,derived`` CSV rows per section.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="run a single section by name")
    args = ap.parse_args(argv)

    from . import (amortization, contention, granularity_stability, roofline,
                   speedup_grid)

    sections = {
        "contention": lambda: contention.run(
            task_counts=(1, 4, 16, 64) if args.quick
            else (1, 4, 16, 64, 256, 1024)),
        "speedup_grid": lambda: speedup_grid.run(
            workloads=("cholesky", "axpy") if args.quick
            else ("cholesky", "heat", "nbody", "axpy", "dotp"),
            grains=(4, 8) if args.quick else (4, 8, 16),
            workers=(1, 4) if args.quick else (1, 4, 8)),
        "amortization": lambda: amortization.run(
            workloads=("cholesky", "axpy") if args.quick
            else ("cholesky", "heat", "axpy", "dotp"),
            iter_counts=(4, 16) if args.quick else (4, 64)),
        "granularity_stability": lambda: granularity_stability.run(
            grains=(4, 8) if args.quick else (2, 4, 8, 16, 32)),
        "roofline": roofline.run,
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        fn()
        print(f"# section {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
