"""Multi-tenant serving: batched admission vs serial per-request replay.

    PYTHONPATH=src python -m benchmarks.serving [--smoke] [--devices N] \
        [--out PATH]

For each tenant count N, this drives N concurrent tenants — structurally
identical taskgraph regions (same payload function, private buffers, one
shared weight buffer) — through ``repro.serving.RegionServer`` twice:

  * **serial**   (``max_batch=1``): per-request replay through the same
    admission queue — the baseline. The N tenants still share ONE interned
    executable via ``lower.py``'s structural intern cache (the reported
    intern hit rate must be >= N-1).
  * **batched**  (``max_batch=N``): concurrent same-structure requests
    coalesce into one ``vmap``-batched fused replay; the shared weight slot
    is broadcast, private slots are stacked.

Each tenant issues ``rounds`` *dependent* requests (outputs feed the next
request), so the phases replay a realistic decode-style chain. The report
(``BENCH_serving.json``) records throughput, p50/p99 latency, batch
occupancy, pool and intern counters per N, plus serial/batched output
parity. Acceptance for this repo: at >= 8 tenants, batched admission beats
serial replay on throughput, and intern hits >= N-1.

Two further phases exercise the continuous (iteration-level) scheduler:

  * **streams** — the same dependent chain driven two ways at 8 tenants:
    request-level (client round-trip per step, legacy dispatcher) vs
    continuous (``submit_stream``: resident server-side decode, outputs
    carried between fused steps). Gates: identical finals, continuous
    throughput >= request-level.
  * **--devices N** — the batched phase re-run under an N-device replay
    mesh (``RegionServer(mesh=...)``), swept over 1..N faked host devices;
    finals must be bit-exact against the 1-device run.
  * **open-loop** (``--open-loop --rate R``) — seeded Poisson arrivals
    from tenants split across QoS tiers 0/1, driven into a deliberately
    narrow ``max_batch`` so a backlog forms. Reports per-tier p50/p99 and
    mean step occupancy; gates (under overload): tier-1 p99 < tier-0 p99,
    and the execution-pattern trace ring is non-empty and schema-valid.
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np


def _tenant_region(i: int, waves: int, width: int, body):
    from repro.core import TDG

    tdg = TDG(f"bench[{i}]")
    for _w in range(waves):
        for s in range(width):
            tdg.add_task(body, ins=[f"x{s}", "w"], outs=[f"x{s}"],
                         name=f"t{_w}.{s}")
    return tdg


def _run_phase(n_tenants: int, rounds: int, max_batch: int,
               max_wait_ms: float, dim: int, waves: int, width: int,
               mesh=None) -> dict:
    import jax.numpy as jnp

    from repro.core import clear_intern_cache
    from repro.serving import RegionServer

    def body(x, w):
        return jnp.tanh(x @ w) * 0.5 + x

    clear_intern_cache()
    server = RegionServer(max_batch=max_batch, max_wait_ms=max_wait_ms,
                          mesh=mesh,
                          name=f"bench-{'batched' if max_batch > 1 else 'serial'}")
    rng = np.random.default_rng(0)
    shared_w = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    starts = []
    for i in range(n_tenants):
        server.register_tenant(f"t{i}", _tenant_region(i, waves, width, body))
        bufs = {f"x{s}": jnp.asarray(rng.standard_normal((dim, dim)),
                                     jnp.float32) for s in range(width)}
        bufs["w"] = shared_w            # same object: broadcast, not stacked
        starts.append(bufs)

    finals: list[dict | None] = [None] * n_tenants
    errors: list[BaseException] = []

    def tenant_loop(i: int, n_rounds: int, keep_final: bool) -> None:
        try:
            bufs = dict(starts[i])
            out = {}
            for _ in range(n_rounds):
                out = server.serve(f"t{i}", bufs, timeout=300)
                bufs.update(out)
                bufs["w"] = shared_w
            if keep_final:
                finals[i] = {k: np.asarray(v) for k, v in out.items()}
        except BaseException as e:       # surface thread failures to caller
            errors.append(e)

    def run_threads(n_rounds: int, keep_final: bool) -> float:
        threads = [threading.Thread(target=tenant_loop,
                                    args=(i, n_rounds, keep_final))
                   for i in range(n_tenants)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return time.perf_counter() - t0

    run_threads(1, keep_final=False)     # warm: trace+compile off the clock
    wall = run_threads(rounds, keep_final=True)
    stats = server.stats()
    server.close()
    m = stats["metrics"]
    return {
        "max_batch": max_batch,
        "requests": n_tenants * rounds,
        "wall_s": wall,
        "throughput_rps": n_tenants * rounds / max(wall, 1e-9),
        "latency_p50_ms": m["latency"]["p50_s"] * 1e3,
        "latency_p99_ms": m["latency"]["p99_s"] * 1e3,
        "batches": m["batches"],
        "batch_occupancy_mean": m["batch_occupancy_mean"],
        "batch_occupancy_max": m["batch_occupancy_max"],
        "coalesced_requests": m["coalesced_requests"],
        "batch_fallbacks": m["batch_fallbacks"],
        "queue_depth_peak": m["queue_depth_peak"],
        "pool": stats["pool"],
        "intern": stats["intern"],
        "_finals": finals,
    }


def _bench_setup(n_tenants: int, dim: int, waves: int, width: int,
                 server, body_loops: int = 1):
    """Register ``n_tenants`` identical-structure tenants; seeded buffers.

    ``body_loops`` scales per-task compute without changing the region
    structure — the open-loop phase needs service time (not scheduling
    overhead) to dominate each step, or queueing delay, which is where
    tier QoS acts, would be noise.
    """
    import jax.numpy as jnp

    def body(x, w):
        for _ in range(body_loops):
            x = jnp.tanh(x @ w) * 0.5 + x
        return x

    rng = np.random.default_rng(0)
    shared_w = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    starts = []
    for i in range(n_tenants):
        tier = i % 2
        server.register_tenant(f"t{i}", _tenant_region(i, waves, width, body),
                               tier=tier)
        bufs = {f"x{s}": jnp.asarray(rng.standard_normal((dim, dim)),
                                     jnp.float32) for s in range(width)}
        bufs["w"] = shared_w            # same object: broadcast, not stacked
        starts.append(bufs)
    return starts


def _run_streams_phase(n_tenants: int, steps: int, dim: int, waves: int,
                       width: int, continuous: bool,
                       max_wait_ms: float = 25.0) -> dict:
    """Drive ``steps``-step dependent chains for every tenant, one of two ways.

    ``continuous=False``: client-driven — each tenant thread round-trips
    one request per step (the legacy run-to-completion dispatcher).
    ``continuous=True``: ONE ``submit_stream`` per tenant; the carry
    happens server-side between fused steps of the resident batch.
    """
    import threading as _threading

    from repro.core import clear_intern_cache
    from repro.serving import RegionServer

    clear_intern_cache()
    server = RegionServer(
        max_batch=n_tenants, max_wait_ms=max_wait_ms, continuous=continuous,
        name=f"bench-streams-{'cont' if continuous else 'reqlevel'}")
    starts = _bench_setup(n_tenants, dim, waves, width, server)
    finals: list[dict | None] = [None] * n_tenants

    def run_once(n_steps: int, keep: bool) -> float:
        errors: list[BaseException] = []
        if continuous:
            t0 = time.perf_counter()
            futs = [server.submit_stream(f"t{i}", starts[i], n_steps)
                    for i in range(n_tenants)]
            outs = [f.result(timeout=300) for f in futs]
            wall = time.perf_counter() - t0
            if keep:
                for i, out in enumerate(outs):
                    finals[i] = {k: np.asarray(v) for k, v in out.items()}
            return wall

        def chain(i: int) -> None:
            try:
                bufs, out = dict(starts[i]), {}
                for _ in range(n_steps):
                    out = server.serve(f"t{i}", bufs, timeout=300)
                    bufs.update(out)
                if keep:
                    finals[i] = {k: np.asarray(v) for k, v in out.items()}
            except BaseException as e:
                errors.append(e)

        threads = [_threading.Thread(target=chain, args=(i,))
                   for i in range(n_tenants)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return time.perf_counter() - t0

    run_once(1, keep=False)             # warm: trace+compile off the clock
    wall = run_once(steps, keep=True)
    stats = server.stats()
    server.close()
    m = stats["metrics"]
    return {
        "continuous": continuous,
        "tenants": n_tenants,
        "steps": steps,
        "wall_s": wall,
        "throughput_sps": n_tenants * steps / max(wall, 1e-9),
        "batches": m["batches"],
        "batch_occupancy_mean": m["batch_occupancy_mean"],
        "joins": m.get("joins", 0),
        "leaves": m.get("leaves", 0),
        "trace": m.get("trace"),
        "pool": {k: stats["pool"][k] for k in ("hits", "misses", "entries")},
        "intern": stats["intern"],
        "_finals": finals,
    }


def _run_open_loop(n_tenants: int, n_requests: int, rate: float, dim: int,
                   waves: int, width: int, max_batch: int = 2,
                   seed: int = 0, body_loops: int = 32) -> dict:
    """Open-loop Poisson arrivals into a continuous server, tiers 0/1.

    ``max_batch`` is kept deliberately below the tenant count so the
    offered load exceeds per-step service capacity and a backlog forms —
    that backlog is where tier-weighted admission (weight ``2**tier``)
    separates the tiers' tails. Arrivals and tenant choice are seeded, so
    the offered sequence is reproducible; per-request latency is measured
    server-side (admission -> completion) in the per-tier reservoirs.
    """
    from repro.core import clear_intern_cache
    from repro.serving import (RegionServer, ServerMetrics, validate_trace)

    clear_intern_cache()
    server = RegionServer(max_batch=max_batch, max_wait_ms=1.0,
                          continuous=True, name="bench-openloop")
    starts = _bench_setup(n_tenants, dim, waves, width, server,
                          body_loops=body_loops)

    # Warm every pow-2 bucket the run can hit, then zero the metrics so
    # compile time never pollutes the tier latency comparison.
    futs = [server.submit(f"t{i}", starts[i]) for i in range(n_tenants)]
    for f in futs:
        f.result(timeout=300)
    server.metrics = ServerMetrics()

    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
    arrive = np.cumsum(inter)
    choice = rng.integers(0, n_tenants, n_requests)
    futs, tiers = [], []
    t0 = time.perf_counter()
    for k in range(n_requests):
        delay = t0 + arrive[k] - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        i = int(choice[k])
        futs.append(server.submit(f"t{i}", starts[i]))
        tiers.append(i % 2)
    for f in futs:
        f.result(timeout=300)
    wall = time.perf_counter() - t0
    stats = server.stats()
    trace = server.metrics.trace.snapshot()
    server.close()
    validate_trace(trace)
    m = stats["metrics"]
    tier_lat = {t: {"p50_ms": s["p50_s"] * 1e3, "p99_ms": s["p99_s"] * 1e3,
                    "count": s["count"]}
                for t, s in m["tiers"].items()}
    return {
        "tenants": n_tenants,
        "requests": n_requests,
        "offered_rate_rps": rate,
        "achieved_rps": n_requests / max(wall, 1e-9),
        "max_batch": max_batch,
        "tier_latency": tier_lat,
        "batch_occupancy_mean": m["batch_occupancy_mean"],
        "queue_depth_peak": m["queue_depth_peak"],
        "trace_steps": len(trace),
        "trace_summary": m["trace"],
    }


def run(tenant_counts=(1, 2, 4, 8), rounds: int = 16, dim: int = 16,
        waves: int = 4, width: int = 4, max_wait_ms: float = 25.0,
        out_path: str = "BENCH_serving.json") -> dict:
    results = []
    for n in tenant_counts:
        serial = _run_phase(n, rounds, 1, 0.0, dim, waves, width)
        batched = _run_phase(n, rounds, n, max_wait_ms, dim, waves, width)
        # Parity: both phases replay the same dependent chain from the same
        # inputs; fused-vs-vmapped forms may reassociate f32.
        parity = 0.0
        for a, b in zip(serial.pop("_finals"), batched.pop("_finals")):
            assert a is not None and b is not None
            for k in a:
                np.testing.assert_allclose(b[k], a[k], rtol=2e-4, atol=2e-4)
                parity = max(parity, float(np.abs(a[k] - b[k]).max()))
        row = {
            "tenants": n,
            "rounds": rounds,
            "tasks_per_region": waves * width,
            "serial": serial,
            "batched": batched,
            "speedup_throughput": (batched["throughput_rps"]
                                   / max(serial["throughput_rps"], 1e-9)),
            "intern_hits_serial": serial["intern"]["hits"],
            "parity_max_abs_diff": parity,
        }
        results.append(row)
        print(f"tenants={n:3d}: serial {serial['throughput_rps']:8.1f} req/s "
              f"(p50 {serial['latency_p50_ms']:6.2f} ms) | batched "
              f"{batched['throughput_rps']:8.1f} req/s "
              f"(p50 {batched['latency_p50_ms']:6.2f} ms, occ "
              f"{batched['batch_occupancy_mean']:.2f}) | "
              f"{row['speedup_throughput']:5.2f}x | intern hits "
              f"{row['intern_hits_serial']}", flush=True)
    report = {"bench": "serving", "dim": dim, "waves": waves, "width": width,
              "tenant_sweep": results}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {out_path}", flush=True)
    return report


def _streams_section(steps: int, dim: int, waves: int, width: int,
                     n_tenants: int = 8) -> dict:
    """Continuous vs request-level streams at ``n_tenants``; checks parity."""
    reqlevel = _run_streams_phase(n_tenants, steps, dim, waves, width,
                                  continuous=False)
    cont = _run_streams_phase(n_tenants, steps, dim, waves, width,
                              continuous=True)
    parity = 0.0
    for a, b in zip(reqlevel.pop("_finals"), cont.pop("_finals")):
        assert a is not None and b is not None
        for k in a:
            np.testing.assert_allclose(b[k], a[k], rtol=2e-4, atol=2e-4)
            parity = max(parity, float(np.abs(a[k] - b[k]).max()))
    section = {
        "tenants": n_tenants, "steps": steps,
        "request_level": reqlevel, "continuous": cont,
        "speedup_throughput": (cont["throughput_sps"]
                               / max(reqlevel["throughput_sps"], 1e-9)),
        "parity_max_abs_diff": parity,
    }
    print(f"streams tenants={n_tenants} steps={steps}: request-level "
          f"{reqlevel['throughput_sps']:8.1f} steps/s | continuous "
          f"{cont['throughput_sps']:8.1f} steps/s "
          f"(occ {cont['batch_occupancy_mean']:.2f}) | "
          f"{section['speedup_throughput']:5.2f}x", flush=True)
    return section


def _devices_section(n_devices: int, rounds: int, dim: int, waves: int,
                     width: int, n_tenants: int = 8) -> dict:
    """Batched admission under a replay mesh, swept over device counts.

    Every tenant chain re-runs from identical seeded inputs, so the
    sharded server's finals must be BIT-EXACT against the 1-device run:
    sharding the coalesced request axis moves lanes, never values.
    """
    import jax

    from repro.launch.mesh import make_replay_mesh

    avail = min(n_devices, jax.device_count())
    counts = [n for n in (1, 2, 4, 8, 16) if n <= avail]
    sweep = []
    ref_finals = None
    for n in counts:
        mesh = make_replay_mesh(n) if n > 1 else None
        phase = _run_phase(n_tenants, rounds, n_tenants, 25.0, dim, waves,
                           width, mesh=mesh)
        finals = phase.pop("_finals")
        parity = 0.0
        if ref_finals is None:
            ref_finals = finals
        else:
            for a, b in zip(ref_finals, finals):
                assert a is not None and b is not None
                for k in a:
                    np.testing.assert_array_equal(b[k], a[k])
                    parity = max(parity, float(np.abs(a[k] - b[k]).max()))
        sweep.append({"devices": n,
                      "throughput_rps": phase["throughput_rps"],
                      "latency_p50_ms": phase["latency_p50_ms"],
                      "batch_occupancy_mean": phase["batch_occupancy_mean"],
                      "coalesced_requests": phase["coalesced_requests"],
                      "parity_max_abs_diff": parity})
        print(f"devices={n:2d}: {phase['throughput_rps']:8.1f} req/s "
              f"(p50 {phase['latency_p50_ms']:6.2f} ms, occ "
              f"{phase['batch_occupancy_mean']:.2f}) "
              f"parity_max_abs_diff={parity}", flush=True)
    return {"tenants": n_tenants, "rounds": rounds, "sweep": sweep}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny sweep + continuous/QoS gates "
                         "(continuous >= request-level at 8 tenants, tier-1 "
                         "p99 < tier-0 p99 under overload, trace "
                         "schema-valid)")
    ap.add_argument("--open-loop", action="store_true",
                    help="run ONLY the open-loop Poisson phase (seeded "
                         "arrivals, QoS tiers 0/1) and print per-tier "
                         "p50/p99 + mean occupancy")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="[--open-loop] offered arrival rate, req/s")
    ap.add_argument("--requests", type=int, default=256,
                    help="[--open-loop] total arrivals")
    ap.add_argument("--devices", type=int, default=0,
                    help="also sweep mesh-sharded batched admission over "
                         "1..N faked host devices; gates on bit-exact "
                         "finals vs the 1-device run")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    if args.devices > 1:
        from benchmarks.fusion import force_host_devices

        force_host_devices(args.devices)
    if args.open_loop:
        ol = _run_open_loop(8, args.requests, args.rate, 64, 3, 2)
        print(f"open-loop rate={args.rate:.0f}/s: achieved "
              f"{ol['achieved_rps']:.1f} req/s, occ "
              f"{ol['batch_occupancy_mean']:.2f}, queue peak "
              f"{ol['queue_depth_peak']}, trace {ol['trace_steps']} steps")
        for t in sorted(ol["tier_latency"]):
            s = ol["tier_latency"][t]
            print(f"  tier {t}: n {s['count']}  p50 {s['p50_ms']:.2f} ms  "
                  f"p99 {s['p99_ms']:.2f} ms")
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"bench": "serving-open-loop", "open_loop": ol},
                          f, indent=1)
            print(f"# wrote {args.out}", flush=True)
        return
    if args.smoke:
        report = run(tenant_counts=(2, 4), rounds=4, dim=8, waves=2, width=2,
                     out_path=None)
        for row in report["tenant_sweep"]:
            n = row["tenants"]
            assert row["parity_max_abs_diff"] < 1e-3, row
            assert row["intern_hits_serial"] >= n - 1, row
            # >= 2 requests genuinely served by one fused vmap call —
            # fallback-degraded groups do not count as coalesced.
            assert row["batched"]["coalesced_requests"] >= 2, row
        streams = _streams_section(steps=8, dim=8, waves=2, width=2)
        report["streams"] = streams
        assert streams["parity_max_abs_diff"] < 1e-3, streams
        assert streams["speedup_throughput"] >= 1.0, streams
        # Calibrated overload: offered rate >> service rate (the whole
        # backlog queues within ~4 steps), heavy per-step compute so
        # queueing delay — where tier-weighted admission acts — dominates
        # wall time. Seeded arrivals make the tier tally deterministic.
        ol = _run_open_loop(8, 120, 20000.0, 64, 3, 2)
        report["open_loop"] = ol
        assert ol["trace_steps"] > 0, ol
        t0, t1 = ol["tier_latency"].get("0"), ol["tier_latency"].get("1")
        assert t0 and t1, ol
        assert t1["p99_ms"] < t0["p99_ms"], ol
        if args.devices > 1:
            report["devices"] = _devices_section(args.devices, rounds=4,
                                                 dim=8, waves=2, width=2,
                                                 n_tenants=4)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(report, f, indent=1)
            print(f"# wrote {args.out}", flush=True)
        print("# smoke ok: parity + coalescing + continuous>=request-level "
              "+ tier-1 p99 < tier-0 p99 under overload + schema-valid trace")
    else:
        report = run(out_path=None)
        for row in report["tenant_sweep"]:
            n = row["tenants"]
            assert row["intern_hits_serial"] >= n - 1, row
            if n >= 8:
                assert row["speedup_throughput"] > 1.0, row
                print(f"# acceptance [tenants={n}]: "
                      f"{row['speedup_throughput']:.2f}x batched-vs-serial "
                      f"throughput, {row['intern_hits_serial']} intern hits "
                      f"(>= {n - 1} required)")
        report["streams"] = _streams_section(steps=16, dim=16, waves=4,
                                             width=4)
        report["open_loop"] = _run_open_loop(8, args.requests, args.rate,
                                             64, 3, 2)
        if args.devices > 1:
            report["devices"] = _devices_section(args.devices, rounds=8,
                                                 dim=16, waves=4, width=4)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(report, f, indent=1)
            print(f"# wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
