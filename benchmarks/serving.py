"""Multi-tenant serving: batched admission vs serial per-request replay.

    PYTHONPATH=src python -m benchmarks.serving [--smoke] [--out PATH]

For each tenant count N, this drives N concurrent tenants — structurally
identical taskgraph regions (same payload function, private buffers, one
shared weight buffer) — through ``repro.serving.RegionServer`` twice:

  * **serial**   (``max_batch=1``): per-request replay through the same
    admission queue — the baseline. The N tenants still share ONE interned
    executable via ``lower.py``'s structural intern cache (the reported
    intern hit rate must be >= N-1).
  * **batched**  (``max_batch=N``): concurrent same-structure requests
    coalesce into one ``vmap``-batched fused replay; the shared weight slot
    is broadcast, private slots are stacked.

Each tenant issues ``rounds`` *dependent* requests (outputs feed the next
request), so the phases replay a realistic decode-style chain. The report
(``BENCH_serving.json``) records throughput, p50/p99 latency, batch
occupancy, pool and intern counters per N, plus serial/batched output
parity. Acceptance for this repo: at >= 8 tenants, batched admission beats
serial replay on throughput, and intern hits >= N-1.
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np


def _tenant_region(i: int, waves: int, width: int, body):
    from repro.core import TDG

    tdg = TDG(f"bench[{i}]")
    for _w in range(waves):
        for s in range(width):
            tdg.add_task(body, ins=[f"x{s}", "w"], outs=[f"x{s}"],
                         name=f"t{_w}.{s}")
    return tdg


def _run_phase(n_tenants: int, rounds: int, max_batch: int,
               max_wait_ms: float, dim: int, waves: int, width: int) -> dict:
    import jax.numpy as jnp

    from repro.core import clear_intern_cache
    from repro.serving import RegionServer

    def body(x, w):
        return jnp.tanh(x @ w) * 0.5 + x

    clear_intern_cache()
    server = RegionServer(max_batch=max_batch, max_wait_ms=max_wait_ms,
                          name=f"bench-{'batched' if max_batch > 1 else 'serial'}")
    rng = np.random.default_rng(0)
    shared_w = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    starts = []
    for i in range(n_tenants):
        server.register_tenant(f"t{i}", _tenant_region(i, waves, width, body))
        bufs = {f"x{s}": jnp.asarray(rng.standard_normal((dim, dim)),
                                     jnp.float32) for s in range(width)}
        bufs["w"] = shared_w            # same object: broadcast, not stacked
        starts.append(bufs)

    finals: list[dict | None] = [None] * n_tenants
    errors: list[BaseException] = []

    def tenant_loop(i: int, n_rounds: int, keep_final: bool) -> None:
        try:
            bufs = dict(starts[i])
            out = {}
            for _ in range(n_rounds):
                out = server.serve(f"t{i}", bufs, timeout=300)
                bufs.update(out)
                bufs["w"] = shared_w
            if keep_final:
                finals[i] = {k: np.asarray(v) for k, v in out.items()}
        except BaseException as e:       # surface thread failures to caller
            errors.append(e)

    def run_threads(n_rounds: int, keep_final: bool) -> float:
        threads = [threading.Thread(target=tenant_loop,
                                    args=(i, n_rounds, keep_final))
                   for i in range(n_tenants)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return time.perf_counter() - t0

    run_threads(1, keep_final=False)     # warm: trace+compile off the clock
    wall = run_threads(rounds, keep_final=True)
    stats = server.stats()
    server.close()
    m = stats["metrics"]
    return {
        "max_batch": max_batch,
        "requests": n_tenants * rounds,
        "wall_s": wall,
        "throughput_rps": n_tenants * rounds / max(wall, 1e-9),
        "latency_p50_ms": m["latency"]["p50_s"] * 1e3,
        "latency_p99_ms": m["latency"]["p99_s"] * 1e3,
        "batches": m["batches"],
        "batch_occupancy_mean": m["batch_occupancy_mean"],
        "batch_occupancy_max": m["batch_occupancy_max"],
        "coalesced_requests": m["coalesced_requests"],
        "batch_fallbacks": m["batch_fallbacks"],
        "queue_depth_peak": m["queue_depth_peak"],
        "pool": stats["pool"],
        "intern": stats["intern"],
        "_finals": finals,
    }


def run(tenant_counts=(1, 2, 4, 8), rounds: int = 16, dim: int = 16,
        waves: int = 4, width: int = 4, max_wait_ms: float = 25.0,
        out_path: str = "BENCH_serving.json") -> dict:
    results = []
    for n in tenant_counts:
        serial = _run_phase(n, rounds, 1, 0.0, dim, waves, width)
        batched = _run_phase(n, rounds, n, max_wait_ms, dim, waves, width)
        # Parity: both phases replay the same dependent chain from the same
        # inputs; fused-vs-vmapped forms may reassociate f32.
        parity = 0.0
        for a, b in zip(serial.pop("_finals"), batched.pop("_finals")):
            assert a is not None and b is not None
            for k in a:
                np.testing.assert_allclose(b[k], a[k], rtol=2e-4, atol=2e-4)
                parity = max(parity, float(np.abs(a[k] - b[k]).max()))
        row = {
            "tenants": n,
            "rounds": rounds,
            "tasks_per_region": waves * width,
            "serial": serial,
            "batched": batched,
            "speedup_throughput": (batched["throughput_rps"]
                                   / max(serial["throughput_rps"], 1e-9)),
            "intern_hits_serial": serial["intern"]["hits"],
            "parity_max_abs_diff": parity,
        }
        results.append(row)
        print(f"tenants={n:3d}: serial {serial['throughput_rps']:8.1f} req/s "
              f"(p50 {serial['latency_p50_ms']:6.2f} ms) | batched "
              f"{batched['throughput_rps']:8.1f} req/s "
              f"(p50 {batched['latency_p50_ms']:6.2f} ms, occ "
              f"{batched['batch_occupancy_mean']:.2f}) | "
              f"{row['speedup_throughput']:5.2f}x | intern hits "
              f"{row['intern_hits_serial']}", flush=True)
    report = {"bench": "serving", "dim": dim, "waves": waves, "width": width,
              "tenant_sweep": results}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {out_path}", flush=True)
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny sweep; asserts parity + structural "
                         "sharing (throughput is reported, not gated — too "
                         "noisy at smoke size)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    if args.smoke:
        report = run(tenant_counts=(2, 4), rounds=4, dim=8, waves=2, width=2,
                     out_path=args.out)
        for row in report["tenant_sweep"]:
            n = row["tenants"]
            assert row["parity_max_abs_diff"] < 1e-3, row
            assert row["intern_hits_serial"] >= n - 1, row
            # >= 2 requests genuinely served by one fused vmap call —
            # fallback-degraded groups do not count as coalesced.
            assert row["batched"]["coalesced_requests"] >= 2, row
        print("# smoke ok: parity + shared interned executable + coalescing")
    else:
        report = run(out_path=args.out)
        for row in report["tenant_sweep"]:
            n = row["tenants"]
            assert row["intern_hits_serial"] >= n - 1, row
            if n >= 8:
                assert row["speedup_throughput"] > 1.0, row
                print(f"# acceptance [tenants={n}]: "
                      f"{row['speedup_throughput']:.2f}x batched-vs-serial "
                      f"throughput, {row['intern_hits_serial']} intern hits "
                      f"(>= {n - 1} required)")


if __name__ == "__main__":
    main()
