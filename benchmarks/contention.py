"""Paper Fig. 2 / Table 1: task-orchestration overhead vs task count.

Reproduces the paper's experiment structure on this framework:
  * workload = Listing 1 (series of independent task chains), total FLOPs
    held constant while task count grows (granularity shrinks);
  * ``Computation`` = ideal time (serial_time x ceil(tasks/workers) / tasks),
    paper Eq. (1); ``Overhead`` = measured - Computation, Eq. (2);
  * eager executor (dynamic per-task dispatch, per-worker queues) plays the
    vanilla LLVM-like runtime; ``central_queue=True`` plays GOMP's single
    queue; replay is the Taskgraph.

Output CSV: name,us_per_call,derived (one row per configuration).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import TDG, EagerExecutor, ReplayExecutor

from .common import csv_row, timeit

PER_TASK_ELEMS = 1024          # fine-grained tasks: ~us of compute each
SERIES = 4


def _make_tdg(n_tasks: int) -> tuple[TDG, dict]:
    """SERIES waves of n_tasks chains (paper Listing 1). Per-task work is a
    small fixed vector op (~paper's 10k-instruction fine-grain regime), so
    orchestration — not FLOPs — dominates, exactly the effect under study."""

    def fn(x):
        return jnp.tanh(x) * 1.0001 + 0.1

    tdg = TDG(f"listing1[{n_tasks}]")
    for s in range(SERIES):
        for t in range(n_tasks):
            tdg.add_task(fn, inouts=[f"x{t}"], name=f"t{s}.{t}")
    bufs = {f"x{t}": jnp.ones((PER_TASK_ELEMS,), jnp.float32)
            for t in range(n_tasks)}
    return tdg, bufs


def _ideal_time(n_tasks: int) -> float:
    """Computation term (paper Eq. 1): orchestration-free execution of the
    same total work — one fused jit, SERIES-deep chain over all elements.
    (One physical core here, so c(Th)=1; worker counts still exercise the
    queue policies and their bookkeeping.)"""
    x = jnp.ones((PER_TASK_ELEMS * n_tasks,), jnp.float32)

    @jax.jit
    def chain(x):
        for _ in range(SERIES):
            x = jnp.tanh(x) * 1.0001 + 0.1
        return x

    return timeit(lambda: chain(x), reps=5)


def run(task_counts=(1, 4, 16, 64, 256, 1024), workers: int = 4):
    rows = []
    print("# contention: overhead(ms) vs task count (fine-grained tasks, "
          f"{PER_TASK_ELEMS} elems each, {workers} workers)")
    print("name,us_per_call,derived")
    for n in task_counts:
        tdg, bufs = _make_tdg(n)
        ideal = _ideal_time(n)

        eager = EagerExecutor(tdg, n_workers=workers)
        eager.run(dict(bufs))                       # warm compile
        t_eager = timeit(lambda: eager.run(dict(bufs)), reps=5)

        central = EagerExecutor(tdg, n_workers=workers, central_queue=True,
                                round_robin_roots=False)
        central.run(dict(bufs))
        t_central = timeit(lambda: central.run(dict(bufs)), reps=5)

        replay = ReplayExecutor(tdg)
        replay.run(dict(bufs))
        t_replay = timeit(lambda: replay.run(dict(bufs)), reps=5)

        oh_e = (t_eager - ideal) * 1e3
        oh_c = (t_central - ideal) * 1e3
        oh_r = (t_replay - ideal) * 1e3
        tasks = SERIES * n
        rows.append((tasks, oh_c, oh_e, oh_r))
        print(csv_row(f"contention/central_queue/tasks={tasks}",
                      f"{t_central*1e6:.1f}",
                      f"overhead_ms={oh_c:.2f};ideal_ms={ideal*1e3:.2f}"))
        print(csv_row(f"contention/eager/tasks={tasks}",
                      f"{t_eager*1e6:.1f}", f"overhead_ms={oh_e:.2f}"))
        print(csv_row(f"contention/taskgraph_replay/tasks={tasks}",
                      f"{t_replay*1e6:.1f}", f"overhead_ms={oh_r:.2f}"))
    return rows


if __name__ == "__main__":
    run()
