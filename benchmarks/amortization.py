"""Paper Figs. 8/9: record-cost amortization over repeated executions.

Runs each workload end-to-end for N iterations INCLUDING the first-call
record cost, vs the vanilla eager execution of the same N iterations, for
N in {4, 64}: speedup < 1 at small N (record not amortized), -> the
optimal-TDG speedup as N grows (paper's observation on CG/FT class W).
"""
from __future__ import annotations

import time

import jax

from repro.core import EagerExecutor, ReplayExecutor, lower_tdg

from .common import csv_row
from .workloads import WORKLOADS


def _time_replay_with_record(tdg, bufs, iters: int) -> float:
    replay = ReplayExecutor(tdg)
    t0 = time.perf_counter()
    for _ in range(iters):
        replay.run(dict(bufs))        # 1st call pays lower+compile (record)
    return time.perf_counter() - t0


def _time_eager(tdg, bufs, iters: int, workers: int = 4) -> float:
    ex = EagerExecutor(tdg, n_workers=workers)  # per-task compile = vanilla
    t0 = time.perf_counter()                    # task creation cost
    for _ in range(iters):
        ex.run(dict(bufs))
    return time.perf_counter() - t0


def run(workloads=("cholesky", "heat", "axpy", "dotp"), iter_counts=(4, 64)):
    print("# amortization: speedup incl. record/compile cost, by iterations")
    print("name,us_per_call,derived")
    rows = []
    for wname in workloads:
        for iters in iter_counts:
            tdg, bufs, _ = WORKLOADS[wname](nb=8)
            t_r = _time_replay_with_record(tdg, bufs, iters)
            tdg2, bufs2, _ = WORKLOADS[wname](nb=8)
            t_e = _time_eager(tdg2, bufs2, iters)
            sp = t_e / t_r
            rows.append((wname, iters, sp))
            print(csv_row(f"amortization/{wname}/iters={iters}",
                          f"{t_r/iters*1e6:.1f}",
                          f"eager_total_s={t_e:.3f};replay_total_s={t_r:.3f};"
                          f"speedup={sp:.2f}"))
    return rows


if __name__ == "__main__":
    run()
