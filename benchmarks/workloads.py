"""The paper's application kernels as taskgraph regions, parameterized by
block count (task granularity): Cholesky, Heat (Gauss-Seidel), N-body,
AXPY, DOTP — plus kernel-substrate workloads (RMSNorm, attention) whose
task bodies dispatch through ``repro.kernels.registry``, so a single flag
(``--kernels`` on ``benchmarks.run`` / ``REPRO_KERNELS``) sweeps them over
the pallas | ref | interpret substrates. Each returns
(TDG, buffers, verify_fn)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TDG
from repro.kernels import ops, ref as kref


def cholesky(n: int = 512, nb: int = 8):
    bs = n // nb
    rng = np.random.default_rng(0)
    m = rng.standard_normal((n, n))
    spd = m @ m.T + n * np.eye(n)

    def potrf(a):
        return jnp.linalg.cholesky(a)

    def trsm(lkk, a):
        return jax.scipy.linalg.solve_triangular(lkk, a.T, lower=True).T

    def syrk(a, l):
        return a - l @ l.T

    def gemm(a, l1, l2):
        return a - l1 @ l2.T

    tdg = TDG(f"cholesky[{nb}]")
    for k in range(nb):
        tdg.add_task(potrf, ins=[f"A{k}{k}"], outs=[f"L{k}{k}"])
        for i in range(k + 1, nb):
            tdg.add_task(trsm, ins=[f"L{k}{k}", f"A{i}{k}"], outs=[f"L{i}{k}"])
        for i in range(k + 1, nb):
            tdg.add_task(syrk, ins=[f"A{i}{i}", f"L{i}{k}"], outs=[f"A{i}{i}"])
            for j in range(k + 1, i):
                tdg.add_task(gemm, ins=[f"A{i}{j}", f"L{i}{k}", f"L{j}{k}"],
                             outs=[f"A{i}{j}"])
    bufs = {f"A{i}{j}": jnp.asarray(spd[i*bs:(i+1)*bs, j*bs:(j+1)*bs])
            for i in range(nb) for j in range(nb) if j <= i}

    def verify(out):
        L = np.zeros((n, n))
        for i in range(nb):
            for j in range(i + 1):
                L[i*bs:(i+1)*bs, j*bs:(j+1)*bs] = np.asarray(out[f"L{i}{j}"])
        np.testing.assert_allclose(L, np.linalg.cholesky(spd), atol=1e-6 * n)

    return tdg, bufs, verify


def heat(n: int = 512, nb: int = 8, iters: int = 2):
    """Gauss-Seidel wavefront stencil over an nb x nb block grid."""
    bs = n // nb
    rng = np.random.default_rng(1)
    grid = rng.standard_normal((n, n)).astype(np.float32)

    def relax(c, up, left):
        # one Jacobi-ish sweep using already-updated up/left halos (G-S order)
        top = up[-1:, :]
        lft = left[:, -1:]
        padded = jnp.concatenate([top, c], 0)
        padl = jnp.concatenate([lft, c[:, :-1]], 1)
        return 0.25 * (c + padded[:-1] + padl + jnp.roll(c, -1, 0))

    def relax_edge(c):
        return 0.25 * (2 * c + jnp.roll(c, 1, 0) + jnp.roll(c, -1, 0))

    tdg = TDG(f"heat[{nb}]x{iters}")
    for it in range(iters):
        for i in range(nb):
            for j in range(nb):
                if i == 0 or j == 0:
                    tdg.add_task(relax_edge, inouts=[f"B{i}{j}"],
                                 name=f"gs{it}.{i}.{j}")
                else:
                    tdg.add_task(relax,
                                 ins=[f"B{i-1}{j}", f"B{i}{j-1}"],
                                 inouts=[f"B{i}{j}"],
                                 name=f"gs{it}.{i}.{j}")
    bufs = {f"B{i}{j}": jnp.asarray(grid[i*bs:(i+1)*bs, j*bs:(j+1)*bs])
            for i in range(nb) for j in range(nb)}
    return tdg, bufs, lambda out: None


def nbody(n_particles: int = 2048, nb: int = 8):
    """Embarrassingly parallel force computation over particle blocks."""
    rng = np.random.default_rng(2)
    pos = rng.standard_normal((n_particles, 3)).astype(np.float32)
    bs = n_particles // nb
    allpos = jnp.asarray(pos)

    def forces(block):
        d = block[:, None, :] - allpos[None, :, :]
        r2 = (d * d).sum(-1) + 1e-3
        w = jax.lax.rsqrt(r2) / r2
        return (d * w[..., None]).sum(1)

    tdg = TDG(f"nbody[{nb}]")
    for b in range(nb):
        tdg.add_task(forces, ins=[f"P{b}"], outs=[f"F{b}"], name=f"force{b}")
    bufs = {f"P{b}": jnp.asarray(pos[b*bs:(b+1)*bs]) for b in range(nb)}
    return tdg, bufs, lambda out: None


def axpy(n: int = 1 << 22, nb: int = 8):
    rng = np.random.default_rng(3)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    bs = n // nb

    def ax(xb, yb):
        return 2.5 * xb + yb

    tdg = TDG(f"axpy[{nb}]")
    for b in range(nb):
        tdg.add_task(ax, ins=[f"x{b}", f"y{b}"], outs=[f"z{b}"])
    bufs = {}
    for b in range(nb):
        bufs[f"x{b}"] = jnp.asarray(x[b*bs:(b+1)*bs])
        bufs[f"y{b}"] = jnp.asarray(y[b*bs:(b+1)*bs])

    def verify(out):
        z = np.concatenate([np.asarray(out[f"z{b}"]) for b in range(nb)])
        np.testing.assert_allclose(z, 2.5 * x + y, rtol=1e-5, atol=1e-6)

    return tdg, bufs, verify


def dotp(n: int = 1 << 22, nb: int = 8):
    rng = np.random.default_rng(4)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    bs = n // nb

    def dot(xb, yb):
        return (xb * yb).sum()

    def reduce(*ps):
        return jnp.stack(ps).sum()

    tdg = TDG(f"dotp[{nb}]")
    for b in range(nb):
        tdg.add_task(dot, ins=[f"x{b}", f"y{b}"], outs=[f"p{b}"])
    tdg.add_task(reduce, ins=[f"p{b}" for b in range(nb)], outs=["dot"])
    bufs = {}
    for b in range(nb):
        bufs[f"x{b}"] = jnp.asarray(x[b*bs:(b+1)*bs])
        bufs[f"y{b}"] = jnp.asarray(y[b*bs:(b+1)*bs])

    def verify(out):
        np.testing.assert_allclose(float(out["dot"]), float(x @ y), rtol=1e-3)

    return tdg, bufs, verify


def rmsnorm_blocks(n_tokens: int = 8192, d: int = 512, nb: int = 8,
                   depth: int = 2):
    """Chains of fused RMSNorm over token blocks — registry-dispatched.

    Each task calls ``ops.rmsnorm`` so the executing substrate (compiled
    Pallas / jnp ref / interpreted Pallas) is whatever the kernel registry
    resolves at trace time; replay pins it once, eager pays it per task.
    """
    rng = np.random.default_rng(5)
    x = rng.standard_normal((n_tokens, d)).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32) * 0.1 + 1.0
    bs = n_tokens // nb

    def norm(xb, wv):
        return ops.rmsnorm(xb, wv)

    tdg = TDG(f"rmsnorm[{nb}]x{depth}")
    for it in range(depth):
        for b in range(nb):
            tdg.add_task(norm, ins=[f"x{b}" if it == 0 else f"h{it-1}.{b}",
                                    "w"],
                         outs=[f"h{it}.{b}"], name=f"norm{it}.{b}")
    bufs = {f"x{b}": jnp.asarray(x[b*bs:(b+1)*bs]) for b in range(nb)}
    bufs["w"] = jnp.asarray(w)

    def verify(out):
        h = x
        for _ in range(depth):
            h = np.asarray(kref.rmsnorm_ref(jnp.asarray(h), jnp.asarray(w)))
        got = np.concatenate([np.asarray(out[f"h{depth-1}.{b}"])
                              for b in range(nb)])
        np.testing.assert_allclose(got, h, atol=1e-4, rtol=1e-4)

    return tdg, bufs, verify


def attention_blocks(n_seqs: int = 16, seq: int = 128, heads: int = 4,
                     head_dim: int = 64, nb: int = 4):
    """Causal attention over a fixed pool of sequences — registry-dispatched.

    Total work is constant (``n_seqs`` sequences); ``nb`` only sets the task
    granularity (sequences-per-task = n_seqs/nb), matching the
    fixed-work/varying-blocks convention of the other workloads. Each task
    calls ``ops.attention``: with ``--kernels interpret`` it replays the real
    flash-attention Pallas body, with ``ref`` the XLA oracle — same TDG,
    same buffers.
    """
    assert n_seqs % nb == 0, (n_seqs, nb)
    per = n_seqs // nb
    rng = np.random.default_rng(6)
    mk = lambda: rng.standard_normal((per, seq, heads, head_dim)).astype(np.float32)
    qs, ks, vs = [mk() for _ in range(nb)], [mk() for _ in range(nb)], \
                 [mk() for _ in range(nb)]

    def attn(q, k, v):
        return ops.attention(q, k, v, causal=True)

    tdg = TDG(f"attention[{nb}]")
    for b in range(nb):
        tdg.add_task(attn, ins=[f"q{b}", f"k{b}", f"v{b}"], outs=[f"o{b}"],
                     name=f"attn{b}")
    bufs = {}
    for b in range(nb):
        bufs[f"q{b}"], bufs[f"k{b}"], bufs[f"v{b}"] = (
            jnp.asarray(qs[b]), jnp.asarray(ks[b]), jnp.asarray(vs[b]))

    def verify(out):
        for b in range(nb):
            want = kref.attention_ref(jnp.asarray(qs[b]), jnp.asarray(ks[b]),
                                      jnp.asarray(vs[b]), causal=True)
            np.testing.assert_allclose(np.asarray(out[f"o{b}"]),
                                       np.asarray(want), atol=2e-3, rtol=2e-3)

    return tdg, bufs, verify


WORKLOADS = {
    "cholesky": cholesky,
    "heat": heat,
    "nbody": nbody,
    "axpy": axpy,
    "dotp": dotp,
    "rmsnorm": rmsnorm_blocks,
    "attention": attention_blocks,
}
