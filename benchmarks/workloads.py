"""The paper's application kernels as taskgraph regions, parameterized by
block count (task granularity): Cholesky, Heat (Gauss-Seidel), N-body,
AXPY, DOTP. Each returns (TDG, buffers, verify_fn)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TDG


def cholesky(n: int = 512, nb: int = 8):
    bs = n // nb
    rng = np.random.default_rng(0)
    m = rng.standard_normal((n, n))
    spd = m @ m.T + n * np.eye(n)

    def potrf(a):
        return jnp.linalg.cholesky(a)

    def trsm(lkk, a):
        return jax.scipy.linalg.solve_triangular(lkk, a.T, lower=True).T

    def syrk(a, l):
        return a - l @ l.T

    def gemm(a, l1, l2):
        return a - l1 @ l2.T

    tdg = TDG(f"cholesky[{nb}]")
    for k in range(nb):
        tdg.add_task(potrf, ins=[f"A{k}{k}"], outs=[f"L{k}{k}"])
        for i in range(k + 1, nb):
            tdg.add_task(trsm, ins=[f"L{k}{k}", f"A{i}{k}"], outs=[f"L{i}{k}"])
        for i in range(k + 1, nb):
            tdg.add_task(syrk, ins=[f"A{i}{i}", f"L{i}{k}"], outs=[f"A{i}{i}"])
            for j in range(k + 1, i):
                tdg.add_task(gemm, ins=[f"A{i}{j}", f"L{i}{k}", f"L{j}{k}"],
                             outs=[f"A{i}{j}"])
    bufs = {f"A{i}{j}": jnp.asarray(spd[i*bs:(i+1)*bs, j*bs:(j+1)*bs])
            for i in range(nb) for j in range(nb) if j <= i}

    def verify(out):
        L = np.zeros((n, n))
        for i in range(nb):
            for j in range(i + 1):
                L[i*bs:(i+1)*bs, j*bs:(j+1)*bs] = np.asarray(out[f"L{i}{j}"])
        np.testing.assert_allclose(L, np.linalg.cholesky(spd), atol=1e-6 * n)

    return tdg, bufs, verify


def heat(n: int = 512, nb: int = 8, iters: int = 2):
    """Gauss-Seidel wavefront stencil over an nb x nb block grid."""
    bs = n // nb
    rng = np.random.default_rng(1)
    grid = rng.standard_normal((n, n)).astype(np.float32)

    def relax(c, up, left):
        # one Jacobi-ish sweep using already-updated up/left halos (G-S order)
        top = up[-1:, :]
        lft = left[:, -1:]
        padded = jnp.concatenate([top, c], 0)
        padl = jnp.concatenate([lft, c[:, :-1]], 1)
        return 0.25 * (c + padded[:-1] + padl + jnp.roll(c, -1, 0))

    def relax_edge(c):
        return 0.25 * (2 * c + jnp.roll(c, 1, 0) + jnp.roll(c, -1, 0))

    tdg = TDG(f"heat[{nb}]x{iters}")
    for it in range(iters):
        for i in range(nb):
            for j in range(nb):
                if i == 0 or j == 0:
                    tdg.add_task(relax_edge, inouts=[f"B{i}{j}"],
                                 name=f"gs{it}.{i}.{j}")
                else:
                    tdg.add_task(relax,
                                 ins=[f"B{i-1}{j}", f"B{i}{j-1}"],
                                 inouts=[f"B{i}{j}"],
                                 name=f"gs{it}.{i}.{j}")
    bufs = {f"B{i}{j}": jnp.asarray(grid[i*bs:(i+1)*bs, j*bs:(j+1)*bs])
            for i in range(nb) for j in range(nb)}
    return tdg, bufs, lambda out: None


def nbody(n_particles: int = 2048, nb: int = 8):
    """Embarrassingly parallel force computation over particle blocks."""
    rng = np.random.default_rng(2)
    pos = rng.standard_normal((n_particles, 3)).astype(np.float32)
    bs = n_particles // nb
    allpos = jnp.asarray(pos)

    def forces(block):
        d = block[:, None, :] - allpos[None, :, :]
        r2 = (d * d).sum(-1) + 1e-3
        w = jax.lax.rsqrt(r2) / r2
        return (d * w[..., None]).sum(1)

    tdg = TDG(f"nbody[{nb}]")
    for b in range(nb):
        tdg.add_task(forces, ins=[f"P{b}"], outs=[f"F{b}"], name=f"force{b}")
    bufs = {f"P{b}": jnp.asarray(pos[b*bs:(b+1)*bs]) for b in range(nb)}
    return tdg, bufs, lambda out: None


def axpy(n: int = 1 << 22, nb: int = 8):
    rng = np.random.default_rng(3)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    bs = n // nb

    def ax(xb, yb):
        return 2.5 * xb + yb

    tdg = TDG(f"axpy[{nb}]")
    for b in range(nb):
        tdg.add_task(ax, ins=[f"x{b}", f"y{b}"], outs=[f"z{b}"])
    bufs = {}
    for b in range(nb):
        bufs[f"x{b}"] = jnp.asarray(x[b*bs:(b+1)*bs])
        bufs[f"y{b}"] = jnp.asarray(y[b*bs:(b+1)*bs])

    def verify(out):
        z = np.concatenate([np.asarray(out[f"z{b}"]) for b in range(nb)])
        np.testing.assert_allclose(z, 2.5 * x + y, rtol=1e-5, atol=1e-6)

    return tdg, bufs, verify


def dotp(n: int = 1 << 22, nb: int = 8):
    rng = np.random.default_rng(4)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    bs = n // nb

    def dot(xb, yb):
        return (xb * yb).sum()

    def reduce(*ps):
        return jnp.stack(ps).sum()

    tdg = TDG(f"dotp[{nb}]")
    for b in range(nb):
        tdg.add_task(dot, ins=[f"x{b}", f"y{b}"], outs=[f"p{b}"])
    tdg.add_task(reduce, ins=[f"p{b}" for b in range(nb)], outs=["dot"])
    bufs = {}
    for b in range(nb):
        bufs[f"x{b}"] = jnp.asarray(x[b*bs:(b+1)*bs])
        bufs[f"y{b}"] = jnp.asarray(y[b*bs:(b+1)*bs])

    def verify(out):
        np.testing.assert_allclose(float(out["dot"]), float(x @ y), rtol=1e-3)

    return tdg, bufs, verify


WORKLOADS = {
    "cholesky": cholesky,
    "heat": heat,
    "nbody": nbody,
    "axpy": axpy,
    "dotp": dotp,
}
