"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def timeit(fn, reps: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(*cols) -> str:
    return ",".join(str(c) for c in cols)
