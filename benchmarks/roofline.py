"""Roofline table from the dry-run artifacts (benchmark per §Roofline).

Reads ``dryrun_artifacts/*.json`` (written by repro.launch.dryrun) and
prints, per (arch x shape x mesh):

    compute_s    = HLO_FLOPs / peak_FLOPs          (per device)
    memory_s     = HLO_bytes / HBM_bw
    collective_s = link_bytes / ICI_bw
    dominant term, MODEL_FLOPS/HLO_FLOPs ratio, and the bottleneck note.

Also emits *kernel-adjusted* compute/memory columns: the CPU dry-run lowers
the pure-XLA attention (full S^2 causal-masked scores, HBM-visible), while
the production TPU path is the Pallas flash kernel (block-skipped causal ~
S^2/2 FLOPs, scores never leave VMEM). The adjustment subtracts the
analytically-known overcount; both raw and adjusted are reported.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART_DIR = pathlib.Path(__file__).resolve().parents[1] / "dryrun_artifacts"


def _attention_correction(arch: str, shape_name: str, chips: int):
    """(extra_flops, extra_bytes) per device done by the XLA attention path
    vs the flash kernel."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if cfg.family == "ssm":
        return 0.0, 0.0
    S, B = shape.seq_len, shape.global_batch
    mult = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[shape.kind]
    H, hd, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    if shape.kind == "decode":
        return 0.0, 0.0          # decode attends the whole cache either way
    Tq = B * S
    n_full = L
    span = S
    if cfg.attention == "sliding" and cfg.window:
        return 0.0, 0.0          # banded path is already ~exact
    if cfg.attention == "chunked" and cfg.attn_chunk:
        k = cfg.global_attn_every or 0
        n_full = L // k if k else 0
        n_local = L - n_full
        # local layers: diag blocks compute c vs c/2 causal-useful
        extra_local_flops = mult * n_local * 4 * H * hd * (cfg.attn_chunk / 2) * Tq
        extra_local_bytes = 3 * n_local * B * H * S * cfg.attn_chunk * 4
        span = S
        extra_full_flops = mult * n_full * 4 * H * hd * (span / 2) * Tq
        extra_full_bytes = 3 * n_full * B * H * S * span * 4
        return ((extra_local_flops + extra_full_flops) / chips,
                (extra_local_bytes + extra_full_bytes) / chips)
    # full causal attention: XLA path does S^2, flash does ~S^2/2
    extra_flops = mult * n_full * 4 * H * hd * (span / 2) * Tq
    # scores round-trip HBM ~3x (write s, read for softmax, read p)
    extra_bytes = 3 * n_full * B * H * S * span * 4
    return extra_flops / chips, extra_bytes / chips


def load_rows():
    rows = []
    for f in sorted(ART_DIR.glob("*.json")):
        art = json.loads(f.read_text())
        if "skipped" in art or art.get("opts"):
            continue
        rl = art["roofline"]
        extra_f, extra_b = _attention_correction(
            art["arch"], art["shape"], art["chips"])
        adj_comp = max(rl["hlo_flops_per_device"] - extra_f, 0) / PEAK_FLOPS_BF16
        adj_mem = max(rl["hlo_bytes_per_device"] - extra_b, 0) / HBM_BW
        terms = {"compute_s": adj_comp, "memory_s": adj_mem,
                 "collective_s": rl["collective_s"]}
        rows.append({
            "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
            "kind": art["kind"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "adj_compute_s": adj_comp, "adj_memory_s": adj_mem,
            "dominant": max(terms, key=terms.get),
            "useful": rl["useful_flop_ratio"],
            "roofline_frac": (adj_comp / max(terms.values())
                              if max(terms.values()) > 0 else None),
            "step_s_bound": max(terms.values()),
            "mem_gb": art["memory"].get("temp_size_in_bytes", 0) / 1e9,
        })
    return rows


def run():
    rows = load_rows()
    print("# roofline (from dry-run artifacts; *_s = seconds/step/device)")
    print("name,us_per_call,derived")
    for r in rows:
        frac = f"{r['roofline_frac']:.3f}" if r["roofline_frac"] else "n/a"
        useful = f"{r['useful']:.3f}" if r["useful"] else "n/a"
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{r['step_s_bound']*1e6:.0f},"
              f"dom={r['dominant']};comp={r['adj_compute_s']:.4f};"
              f"mem={r['adj_memory_s']:.4f};coll={r['collective_s']:.4f};"
              f"useful={useful};roofline_frac={frac};"
              f"temp_gb={r['mem_gb']:.1f}")
    if not rows:
        print("roofline/NO_ARTIFACTS,0,run repro.launch.dryrun first")
    return rows


if __name__ == "__main__":
    run()
