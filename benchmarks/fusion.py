"""Wave-fused vs unrolled replay lowering: trace / compile / steady-state.

    PYTHONPATH=src python -m benchmarks.fusion [--smoke] [--devices N] \
        [--out PATH]

For each task granularity (waves x width grids of isomorphic matmul-chain
tasks, the shape of the paper's Listing-1 / pipeline regions) this measures,
for the unrolled and the wave-fused lowering:

  * trace wall time        (jit(fn).lower(specs))
  * compile wall time      (.compile())
  * jaxpr equation count   (traced program size)
  * steady-state replay    (median call time on the compiled executable)
  * output parity          (fused allclose unfused)

and emits ``BENCH_fusion.json`` with a ``speedup_trace_compile`` figure per
grid. The acceptance bar for this repo: >= 3x trace+compile reduction on a
>= 512-task isomorphic-wave TDG.

``--devices N`` additionally sweeps the SHARDED fused lowering
(``lower_tdg(..., mesh=make_replay_mesh(n))``) over n in {1, 2, 4, ..., N}
faked host devices (the flag must be set before jax initializes, which is
why this module imports jax lazily) and records the sweep under a
``devices`` key. Sharding the stacked batch axis only moves lanes between
devices, so the gate is exact: ``parity_max_abs_diff == 0.0`` against the
single-device fused output at every device count.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def force_host_devices(n: int) -> None:
    """Fake ``n`` host devices. Must run before jax first initializes."""
    if "jax" in sys.modules:
        import jax

        if jax.device_count() < n:
            raise SystemExit(
                f"--devices {n}: jax already initialized with "
                f"{jax.device_count()} device(s); set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n} before launch")
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()


def _grid(n_waves: int, width: int, dim: int):
    import jax.numpy as jnp

    from repro.core import TDG

    def body(x):
        return jnp.tanh(x @ x.T) @ x * 0.5 + x

    tdg = TDG(f"grid[{n_waves}x{width}]")
    for w in range(n_waves):
        for t in range(width):
            tdg.add_task(body, inouts=[f"x{t}"], name=f"t{w}.{t}")
    rng = np.random.default_rng(7)
    bufs = {f"x{t}": jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
            for t in range(width)}
    return tdg, bufs


def _measure(tdg, bufs, fuse: bool, reps: int, mesh=None) -> dict:
    import jax

    from benchmarks.common import timeit
    from repro.core import lower_tdg

    fn = lower_tdg(tdg, jit=False, fuse=fuse, mesh=mesh)
    specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in bufs.items()}
    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(specs)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    jaxpr_eqns = len(jax.make_jaxpr(fn)(specs).eqns)
    out = compiled(bufs)
    replay_s = timeit(lambda: compiled(bufs), reps=reps, warmup=1)
    return {
        "trace_s": t1 - t0,
        "compile_s": t2 - t1,
        "trace_compile_s": t2 - t0,
        "jaxpr_eqns": jaxpr_eqns,
        "replay_s": replay_s,
        "_out": out,
    }


def run(grids=((4, 16), (8, 32), (8, 64)), dim: int = 16, reps: int = 5,
        out_path: str = "BENCH_fusion.json") -> dict:
    results = []
    for n_waves, width in grids:
        tdg, bufs = _grid(n_waves, width, dim)
        unfused = _measure(tdg, bufs, fuse=False, reps=reps)
        fused = _measure(tdg, bufs, fuse=True, reps=reps)
        max_abs_diff = 0.0
        for k in unfused["_out"]:
            a = np.asarray(unfused["_out"][k])
            b = np.asarray(fused["_out"][k])
            np.testing.assert_allclose(b, a, rtol=2e-5, atol=2e-5)
            max_abs_diff = max(max_abs_diff, float(np.abs(a - b).max()))
        row = {
            "tasks": tdg.num_tasks,
            "waves": n_waves,
            "width": width,
            "dim": dim,
            "unfused": {k: v for k, v in unfused.items() if k != "_out"},
            "fused": {k: v for k, v in fused.items() if k != "_out"},
            "speedup_trace_compile": (unfused["trace_compile_s"]
                                      / max(fused["trace_compile_s"], 1e-12)),
            "speedup_replay": (unfused["replay_s"]
                               / max(fused["replay_s"], 1e-12)),
            "jaxpr_shrink": (unfused["jaxpr_eqns"]
                             / max(fused["jaxpr_eqns"], 1)),
            "parity_max_abs_diff": max_abs_diff,
        }
        results.append(row)
        print(f"{tdg.region:>16}: tasks={row['tasks']:5d} "
              f"trace+compile {unfused['trace_compile_s']:7.3f}s -> "
              f"{fused['trace_compile_s']:7.3f}s "
              f"({row['speedup_trace_compile']:5.2f}x)  "
              f"eqns {unfused['jaxpr_eqns']:6d} -> {fused['jaxpr_eqns']:5d}  "
              f"replay {unfused['replay_s']*1e3:7.2f}ms -> "
              f"{fused['replay_s']*1e3:7.2f}ms", flush=True)
    report = {"bench": "fusion", "dim": dim, "grids": results}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {out_path}", flush=True)
    return report


def run_devices(grids=((4, 16), (8, 32)), dim: int = 16, reps: int = 5,
                n_devices: int = 8) -> list:
    """Sharded vs single-device fused replay over 1..n_devices.

    Requires ``force_host_devices(n_devices)`` (or real devices) before jax
    initializes. Parity against the 1-device fused output must be EXACT at
    every device count — the callers gate on it.
    """
    import jax

    from repro.launch.mesh import make_replay_mesh

    avail = min(n_devices, jax.device_count())
    counts = [n for n in (1, 2, 4, 8, 16, 32) if n <= avail]
    rows = []
    for n_waves, width in grids:
        tdg, bufs = _grid(n_waves, width, dim)
        sweep = []
        ref = None
        for n in counts:
            mesh = make_replay_mesh(n) if n > 1 else None
            m = _measure(tdg, bufs, fuse=True, reps=reps, mesh=mesh)
            if ref is None:
                ref = m["_out"]
            diff = max(float(np.abs(np.asarray(ref[k])
                                    - np.asarray(m["_out"][k])).max())
                       for k in ref)
            sweep.append({
                "devices": n,
                **{k: v for k, v in m.items() if k != "_out"},
                "parity_max_abs_diff": diff,
            })
            print(f"{tdg.region:>16}: devices={n:2d} "
                  f"trace+compile {m['trace_compile_s']:7.3f}s  "
                  f"replay {m['replay_s']*1e3:7.2f}ms  "
                  f"parity_max_abs_diff={diff}", flush=True)
        rows.append({"tasks": tdg.num_tasks, "waves": n_waves,
                     "width": width, "dim": dim, "sweep": sweep})
    return rows


def _gate_devices_parity(device_rows: list) -> None:
    for row in device_rows:
        for point in row["sweep"]:
            assert point["parity_max_abs_diff"] == 0.0, (
                "sharded fused replay diverged from single-device", point)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: one tiny grid, asserts parity + "
                         "jaxpr shrink (wall-time speedup is reported, "
                         "not gated — too noisy at smoke size)")
    ap.add_argument("--devices", type=int, default=0,
                    help="also sweep the sharded fused lowering over "
                         "1..N faked host devices; gates on EXACT parity "
                         "vs the single-device fused output")
    ap.add_argument("--out", default="BENCH_fusion.json")
    args = ap.parse_args(argv)
    if args.devices > 1:
        force_host_devices(args.devices)
    if args.smoke:
        report = run(grids=((3, 12),), dim=8, reps=2, out_path="")
        row = report["grids"][0]
        assert row["parity_max_abs_diff"] < 1e-3, row
        assert row["jaxpr_shrink"] > 1.0, row
        if args.devices > 1:
            report["devices"] = run_devices(grids=((3, 12),), dim=8, reps=2,
                                            n_devices=args.devices)
            _gate_devices_parity(report["devices"])
        print(f"# smoke ok: jaxpr_shrink={row['jaxpr_shrink']:.2f} "
              f"speedup={row['speedup_trace_compile']:.2f}x"
              + (" + exact sharded parity" if args.devices > 1 else ""))
    else:
        report = run(out_path="")
        big = [r for r in report["grids"] if r["tasks"] >= 512]
        for r in big:
            print(f"# acceptance [{r['waves']}x{r['width']}]: "
                  f"{r['speedup_trace_compile']:.2f}x trace+compile "
                  f"(target >= 3x)")
        if args.devices > 1:
            report["devices"] = run_devices(n_devices=args.devices)
            _gate_devices_parity(report["devices"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
