"""Paper Fig. 10: execution-time stability as task granularity shrinks —
now an adaptive-vs-static sweep.

The paper's GCC proof-of-concept shows Taskgraph holding execution time
roughly flat as tasks get drastically finer while the vanilla runtime
degrades. We sweep block counts per workload and time three executors over
identical TDGs and buffers:

* **eager**  — ``EagerExecutor`` (dynamic scheduler, per-task dispatch):
  the vanilla baseline whose per-task cost grows with task count;
* **static** — ``ReplayExecutor(batcher="vmap")``: fused replay under the
  pre-cost-model plan (every fused class vmap-batched);
* **adaptive** — ``ReplayExecutor(batcher="auto")``: per-class batcher
  selection from probe-measured flops/bytes (``core.costmodel``), the plan
  the cost report audits.

Gates (enforced in ``--smoke``, which ``scripts/ci.sh --bench-smoke`` runs):

1. **Bit-exact parity** — adaptive and static replay agree to
   ``max_abs_diff == 0.0`` at every workload/grain. The cost model picks
   *where* each class computes (one vmap kernel vs a sequential lane
   scan), never what; any nonzero diff is a bug, not noise. (Payloads
   whose batched forms genuinely reassociate — CPU triangular solve —
   report ``flops = -1`` and stay vmap under both plans by design.)
2. **Adaptive beats-or-matches static at every grain** within a timing
   tolerance. Where the model picks vmap everywhere the two plans trace
   identical programs, so only measurement noise separates them; where it
   picks ``lax.map`` (memory-bound cache-resident members, e.g. heat's
   fine-grain stencil blocks) adaptive must actually win.
3. **Relative flatness (Fig. 10)** — replay's fine/coarse degradation
   ratio must beat eager's: replay cost grows with *work*, eager's with
   task count. Absolute flatness is the wrong gate off the paper's
   hardware; the ratio-of-ratios is scale-free.
4. The sweep must be non-vacuous: at least one class decision in the sweep
   selects ``map`` (else gate 2 never tested the adaptive path).

Full run (writes the committed artifact):
    PYTHONPATH=src python -m benchmarks.granularity_stability \
        --out BENCH_granularity.json
Smoke:  PYTHONPATH=src python -m benchmarks.granularity_stability --smoke \
        --out /tmp/BENCH_granularity_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core import EagerExecutor, ReplayExecutor
from repro.launch.costreport import structure_report

from .common import csv_row, timeit
from .workloads import cholesky, heat

#: Timing tolerance for the adaptive-vs-static gate: single-core CPU CI
#: jitter on identical programs runs a few percent; 1.2x flags a real
#: regression (a wrong map/unroll pick costs 1.3-2x) without flaking.
ADAPTIVE_TOL = 1.2
#: Relative-flatness tolerance: replay_degradation <= eager_degradation
#: * this. Eager's per-task dispatch makes its ratio grow so much faster
#: that 1.1 leaves plenty of signal.
FLATNESS_TOL = 1.1


def _max_abs_diff(a: dict, b: dict) -> float:
    return max((float(np.max(np.abs(np.asarray(a[k]) - np.asarray(b[k]))))
                if np.asarray(a[k]).size else 0.0) for k in a)


def _sweep(workload_name: str, make, grains, n: int, reps: int) -> list[dict]:
    rows = []
    for nb in grains:
        try:
            tdg, bufs, _verify = make(n=n, nb=nb)
        except (AssertionError, ZeroDivisionError):
            continue
        report = structure_report(tdg, bufs)
        static = ReplayExecutor(tdg, batcher="vmap")
        adaptive = ReplayExecutor(tdg, batcher="auto")
        out_static = static.run(dict(bufs))
        out_adaptive = adaptive.run(dict(bufs))
        diff = _max_abs_diff(out_static, out_adaptive)
        t_static = timeit(lambda: static.run(dict(bufs)), reps=reps)
        t_adaptive = timeit(lambda: adaptive.run(dict(bufs)), reps=reps)
        eager = EagerExecutor(tdg, n_workers=4)
        eager.run(dict(bufs))
        t_eager = timeit(lambda: eager.run(dict(bufs)), reps=reps)
        batchers: dict[str, int] = {}
        for d in report["decisions"]:
            if d["fused"]:
                batchers[d["batcher"]] = batchers.get(d["batcher"], 0) + 1
        rows.append({
            "workload": workload_name,
            "nb": nb,
            "tasks": tdg.num_tasks,
            "eager_ms": t_eager * 1e3,
            "static_ms": t_static * 1e3,
            "adaptive_ms": t_adaptive * 1e3,
            "adaptive_vs_static": t_adaptive / t_static,
            "max_abs_diff": diff,
            "batchers": batchers,
            "decisions": report["decisions"],
        })
        print(csv_row(
            f"stability/{workload_name}/blocks={nb}",
            f"{t_adaptive*1e6:.1f}",
            f"eager_ms={t_eager*1e3:.2f};static_ms={t_static*1e3:.2f};"
            f"adaptive_ms={t_adaptive*1e3:.2f};"
            f"batchers={'+'.join(f'{k}:{v}' for k, v in sorted(batchers.items())) or 'none'};"
            f"max_abs_diff={diff:g}"))
    return rows


def _gate(rows: list[dict]) -> dict:
    """Evaluate the four gates; returns {name: {ok, detail}}."""
    gates: dict = {}
    bad_parity = [(r["workload"], r["nb"], r["max_abs_diff"])
                  for r in rows if r["max_abs_diff"] != 0.0]
    gates["parity_bit_exact"] = {
        "ok": not bad_parity,
        "detail": bad_parity or "max_abs_diff == 0.0 everywhere"}
    slow = [(r["workload"], r["nb"], round(r["adaptive_vs_static"], 3))
            for r in rows if r["adaptive_vs_static"] > ADAPTIVE_TOL]
    gates["adaptive_beats_or_matches_static"] = {
        "ok": not slow,
        "detail": slow or f"adaptive <= {ADAPTIVE_TOL}x static at every grain"}
    flat: list = []
    by_w: dict[str, list[dict]] = {}
    for r in rows:
        by_w.setdefault(r["workload"], []).append(r)
    for w, wrows in by_w.items():
        if len(wrows) < 2:
            continue
        coarse, fine = wrows[0], wrows[-1]
        replay_deg = fine["adaptive_ms"] / coarse["adaptive_ms"]
        eager_deg = fine["eager_ms"] / coarse["eager_ms"]
        flat.append({"workload": w, "replay_degradation": round(replay_deg, 3),
                     "eager_degradation": round(eager_deg, 3),
                     "ok": replay_deg <= eager_deg * FLATNESS_TOL})
    gates["replay_flatter_than_eager"] = {
        "ok": all(f["ok"] for f in flat) and bool(flat), "detail": flat}
    n_map = sum(r["batchers"].get("map", 0) for r in rows)
    gates["adaptive_path_exercised"] = {
        "ok": n_map > 0,
        "detail": f"{n_map} map-batched classes across the sweep"}
    return gates


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="granularity stability: eager vs static vs adaptive")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid + enforce the gates")
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args(argv)

    if args.smoke:
        configs = [("cholesky", cholesky, (2, 4, 8), 128, 3),
                   ("heat", heat, (2, 4, 8), 256, 3)]
    else:
        configs = [("cholesky", cholesky, (2, 4, 8, 16), 512, 5),
                   ("heat", heat, (2, 4, 8, 16, 32), 512, 5)]

    print("# granularity stability: absolute ms vs block count "
          f"({'smoke' if args.smoke else 'full'})")
    print("name,us_per_call,derived")
    rows: list[dict] = []
    for wname, make, grains, n, reps in configs:
        rows.extend(_sweep(wname, make, grains, n, reps))

    gates = _gate(rows)
    for name, g in gates.items():
        print(csv_row(f"stability/gate/{name}", int(g["ok"]), g["detail"]))

    doc = {"mode": "smoke" if args.smoke else "full",
           "adaptive_tol": ADAPTIVE_TOL, "flatness_tol": FLATNESS_TOL,
           "gates": {k: g["ok"] for k, g in gates.items()},
           "rows": rows}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.out}")

    failed = [k for k, g in gates.items() if not g["ok"]]
    if args.smoke and failed:
        print(f"GATE FAILURES: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
