"""Paper Fig. 10: execution-time stability as task granularity shrinks.

The paper's GCC proof-of-concept shows Taskgraph holding execution time
roughly flat as tasks get drastically finer while the vanilla runtime
degrades. We sweep block counts for Cholesky and Heat and report absolute
times for eager vs replay.
"""
from __future__ import annotations

from repro.core import EagerExecutor, ReplayExecutor

from .common import csv_row, timeit
from .workloads import WORKLOADS


def run(workloads=("cholesky", "heat"), grains=(2, 4, 8, 16, 32)):
    print("# granularity stability: absolute ms vs block count")
    print("name,us_per_call,derived")
    rows = []
    for wname in workloads:
        base_replay = None
        for nb in grains:
            try:
                tdg, bufs, _ = WORKLOADS[wname](nb=nb)
            except (AssertionError, ZeroDivisionError):
                continue
            replay = ReplayExecutor(tdg)
            replay.run(dict(bufs))
            t_replay = timeit(lambda: replay.run(dict(bufs)), reps=3)
            eager = EagerExecutor(tdg, n_workers=4)
            eager.run(dict(bufs))
            t_eager = timeit(lambda: eager.run(dict(bufs)), reps=3)
            if base_replay is None:
                base_replay = t_replay
            rows.append((wname, nb, t_eager, t_replay))
            print(csv_row(
                f"stability/{wname}/blocks={nb}",
                f"{t_replay*1e6:.1f}",
                f"eager_ms={t_eager*1e3:.2f};replay_ms={t_replay*1e3:.2f};"
                f"replay_vs_coarsest={t_replay/base_replay:.2f}"))
    return rows


if __name__ == "__main__":
    run()
