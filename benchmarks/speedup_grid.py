"""Paper Figs. 6/7: Taskgraph speedup over vanilla tasking, as a grid of
task granularity (block count) x worker count, for the paper's application
kernels (Cholesky, Heat, N-body, AXPY, DOTP).

speedup = T_eager / T_replay   (paper: Time_task / Time_Taskgraph)

Fig. 6 = unstructured (`task depend` webs: cholesky/heat);
Fig. 7 = structured  (`taskloop`-like independent grids: nbody/axpy/dotp).
"""
from __future__ import annotations

from repro.core import EagerExecutor, ReplayExecutor

from .common import csv_row, timeit
from .workloads import WORKLOADS


def run(workloads=("cholesky", "heat", "nbody", "axpy", "dotp"),
        grains=(4, 8, 16), workers=(1, 4, 8)):
    print("# speedup grid: eager(vanilla)/replay(taskgraph) per "
          "(workload x blocks x workers)")
    print("name,us_per_call,derived")
    rows = []
    for wname in workloads:
        for nb in grains:
            try:
                tdg, bufs, verify = WORKLOADS[wname](nb=nb)
            except (AssertionError, ZeroDivisionError):
                continue
            replay = ReplayExecutor(tdg)
            out = replay.run(dict(bufs))
            verify(out)
            t_replay = timeit(lambda: replay.run(dict(bufs)), reps=3)
            for w in workers:
                eager = EagerExecutor(tdg, n_workers=w)
                eager.run(dict(bufs))
                t_eager = timeit(lambda: eager.run(dict(bufs)), reps=3)
                sp = t_eager / t_replay
                rows.append((wname, nb, w, sp))
                print(csv_row(
                    f"speedup/{wname}/blocks={nb}/workers={w}",
                    f"{t_replay*1e6:.1f}",
                    f"eager_us={t_eager*1e6:.1f};speedup={sp:.2f}"))
    return rows


if __name__ == "__main__":
    run()
