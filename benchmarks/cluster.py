"""Distributed serving: RPC overhead, warm-artifact cold start, scaling.

    PYTHONPATH=src python -m benchmarks.cluster [--smoke] [--out PATH]

Four questions about the cluster tier (``repro.serving.cluster``), each a
phase of this benchmark:

* **overhead** — what does the socket RPC front cost? The same tenants and
  request chains run through an in-process ``RegionServer`` and through a
  1-worker ``ClusterFrontend`` — once per transport (``tcp`` and ``shm``,
  the shared-memory data plane). The report records throughput, per-request
  overhead (wire codec + framing + process hop) and the wire breakdown
  (encode/decode seconds, entries per batch frame, shm bytes) for each
  transport; outputs are checked for exact parity against the in-process
  run. The headline numbers come from the best negotiated transport.

* **cold start** — does shipping the warm ``.aot`` artifact beat making the
  worker re-lower? A tenant is warmed once (``serialize.warmup_and_save``);
  then two *fresh* (cold) frontends register it — one from the warm
  artifact (bytes shipped in-band, worker hydrates) and one from the bare
  TDG (worker pays trace+compile on first request). The measured span is
  registration through first result. Acceptance for this repo: the
  warm-ship cold start beats the re-lower cold start, the shipped worker
  reports zero intern misses (it never lowered) and ``aot_served >= 1``.

* **scaling** — 8 tenants over 4 distinct structures driven through 1, 2
  and 4 workers. Sticky-by-structure routing spreads structures across the
  fleet, so added workers add parallelism without ever splitting one
  structure's warm state across hosts.

* **remote bootstrap** — the multi-host path, exercised over localhost
  TCP: a worker is started as a *plain subprocess* running ``python -m
  repro.serving.worker`` (no ``multiprocessing`` handle — exactly what an
  ssh/k8s bootstrap would produce), the frontend attaches by
  ``workers=["host:port"]`` with a handshake token, ships the warm
  artifact, and must get in-process-identical results with the worker
  fully warm (``hydrated_inband >= 1``, ``aot_served >= 1``, zero intern
  misses) and the worker process reaped by ``frontend.close()``'s
  shutdown RPC.

The report lands in ``BENCH_cluster.json``; ``--smoke`` is the CI-sized
variant wired into ``scripts/ci.sh --bench-smoke`` (parity + cold-start +
remote-bootstrap gates asserted; raw throughput reported but not gated —
too noisy at smoke size).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import tempfile
import threading
import time

import numpy as np

REGISTRY_SPEC = "repro.serving.demo:DEMO_REGISTRY"


def _make_tenants(n_tenants: int, n_structures: int, dim: int, waves: int,
                  width: int):
    """``n_tenants`` regions over ``n_structures`` distinct structures.

    Structures differ by depth (``waves + s``), so they canonicalize to
    different ``structure_signature`` keys and route independently.
    """
    import jax.numpy as jnp

    from repro.serving.demo import demo_region

    rng = np.random.default_rng(0)
    shared_w = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    tenants = []
    for i in range(n_tenants):
        s = i % n_structures
        tdg = demo_region(f"bench[{i}]", waves=waves + s, width=width)
        bufs = {f"x{k}": jnp.asarray(rng.standard_normal((dim, dim)),
                                     jnp.float32) for k in range(width)}
        tenants.append({"name": f"t{i}", "tdg": tdg, "bufs": bufs,
                        "structure": s})
    return tenants, shared_w


def _drive(serve, tenants, shared_w, rounds: int) -> tuple[float, list]:
    """Drive every tenant's dependent request chain concurrently."""
    finals: list[dict | None] = [None] * len(tenants)
    errors: list[BaseException] = []

    def loop(i: int) -> None:
        try:
            bufs = dict(tenants[i]["bufs"])
            out = {}
            for _ in range(rounds):
                out = serve(tenants[i]["name"], bufs)
                bufs.update(out)
            finals[i] = {k: np.asarray(v) for k, v in out.items()}
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=loop, args=(i,))
               for i in range(len(tenants))]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return time.perf_counter() - t0, finals


def bench_overhead(n_tenants: int, rounds: int, dim: int, waves: int,
                   width: int, max_wait_ms: float) -> dict:
    """In-process RegionServer vs 1-worker ClusterFrontend, per transport."""
    from repro.core import clear_intern_cache
    from repro.serving import ClusterFrontend, RegionServer

    tenants, shared_w = _make_tenants(n_tenants, 1, dim, waves, width)
    n_requests = n_tenants * rounds

    clear_intern_cache()
    server = RegionServer(max_batch=n_tenants, max_wait_ms=max_wait_ms,
                          name="bench-inproc")
    for t in tenants:
        server.register_tenant(t["name"], t["tdg"])

    def serve_local(name, bufs):
        return server.serve(name, {**bufs, "w": shared_w}, timeout=300)

    _drive(serve_local, tenants, shared_w, 1)          # warm off the clock
    wall_local, finals_local = _drive(serve_local, tenants, shared_w, rounds)
    server.close()
    inproc_rps = n_requests / max(wall_local, 1e-9)

    sweep: dict[str, dict] = {}
    aggregate = None
    for transport in ("tcp", "shm"):
        frontend = ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                                   max_batch=n_tenants,
                                   max_wait_ms=max_wait_ms,
                                   transport=transport,
                                   name=f"bench-rpc-{transport}")
        for t in tenants:
            frontend.register_tenant(t["name"], t["tdg"],
                                     pinned={"w": shared_w})

        def serve_rpc(name, bufs):
            return frontend.serve(name, {k: v for k, v in bufs.items()
                                         if k != "w"}, timeout=300)

        _drive(serve_rpc, tenants, shared_w, 1)        # warm off the clock
        wall_rpc, finals_rpc = _drive(serve_rpc, tenants, shared_w, rounds)
        stats = frontend.stats()
        frontend.close()
        aggregate = stats["aggregate"]

        parity = 0.0
        for a, b in zip(finals_local, finals_rpc):
            for k in a:
                np.testing.assert_allclose(b[k], a[k], rtol=2e-4, atol=2e-4)
                parity = max(parity, float(np.abs(a[k] - b[k]).max()))
        wire = stats["frontend"]["wire"]
        row0 = stats["wire"][0]
        sweep[transport] = {
            "transport_negotiated": row0["transport"],
            "shm_fallbacks": stats["frontend"]["shm_fallbacks"],
            "throughput_rps": n_requests / max(wall_rpc, 1e-9),
            "overhead_ms_per_request": (wall_rpc - wall_local) / n_requests
            * 1e3,
            "parity_max_abs_diff": parity,
            "entries_per_frame": row0["entries_per_frame"],
            "window": row0["window"],
            "wire": wire,
        }
        print(f"  [{transport}] rpc "
              f"{sweep[transport]['throughput_rps']:.1f} req/s | overhead "
              f"{sweep[transport]['overhead_ms_per_request']:.2f} ms/req | "
              f"{row0['entries_per_frame']:.1f} entries/frame | shm "
              f"{wire['shm_bytes_sent']} B tx (negotiated "
              f"{row0['transport']})", flush=True)

    # Headline = the transport a default ("auto") frontend would land on:
    # shm when the rings attached, tcp otherwise.
    best = sweep["shm"] if sweep["shm"]["transport_negotiated"] == "shm" \
        else sweep["tcp"]
    return {
        "tenants": n_tenants,
        "rounds": rounds,
        "requests": n_requests,
        "inproc_throughput_rps": inproc_rps,
        "rpc_throughput_rps": best["throughput_rps"],
        "rpc_overhead_ms_per_request": best["overhead_ms_per_request"],
        "aggregate": aggregate,
        "parity_max_abs_diff": max(r["parity_max_abs_diff"]
                                   for r in sweep.values()),
        "transports": sweep,
    }


def bench_cold_start(dim: int, waves: int, width: int) -> dict:
    """Warm-artifact shipping vs per-worker re-lowering, both from cold."""
    import jax.numpy as jnp

    from repro.core import warmup_and_save
    from repro.serving import ClusterFrontend
    from repro.serving.demo import DEMO_REGISTRY, demo_region

    rng = np.random.default_rng(1)
    shared_w = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    bufs = {f"x{k}": jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
            for k in range(width)}
    tdg = demo_region("cold[0]", waves=waves, width=width)
    tmp = tempfile.mkdtemp(prefix="bench_cluster_")
    warm_path = os.path.join(tmp, "cold.json")
    info = warmup_and_save(tdg, {**bufs, "w": shared_w}, warm_path,
                           DEMO_REGISTRY)

    def cold_first_request(register_kwargs) -> tuple[float, dict, dict]:
        frontend = ClusterFrontend(workers=1, registry=REGISTRY_SPEC,
                                   name="bench-cold")
        try:
            t0 = time.perf_counter()
            frontend.register_tenant("cold", pinned={"w": shared_w},
                                     **register_kwargs)
            out = frontend.serve("cold", bufs, timeout=600)
            dt = time.perf_counter() - t0
            stats = frontend.stats()
        finally:
            frontend.close()
        return dt, out, stats

    ship_s, out_ship, st_ship = cold_first_request({"warm_path": warm_path})
    relower_s, out_relower, st_re = cold_first_request({"tdg": tdg})
    for k in out_ship:
        np.testing.assert_allclose(out_ship[k], out_relower[k],
                                   rtol=2e-4, atol=2e-4)
    ship_worker = st_ship["workers"][0]
    return {
        "artifact_bytes": os.path.getsize(warm_path + ".aot"),
        "compile_seconds_at_warmup": info["compile_seconds"],
        "trace_seconds_at_warmup": info["trace_seconds"],
        "warm_ship_first_request_s": ship_s,
        "relower_first_request_s": relower_s,
        "speedup_cold_start": relower_s / max(ship_s, 1e-9),
        "ship_aot_served": st_ship["aggregate"]["aot_served"],
        "ship_intern_misses": ship_worker["intern"]["misses"],
        "ship_hydrated_inband": st_ship["aggregate"]["hydrated_inband"],
        "relower_intern_misses":
            sum(s["intern"]["misses"] for s in st_re["workers"].values()
                if s is not None),
        "aot_hydrate_failures": st_ship["aggregate"]["aot_hydrate_failures"],
    }


def bench_scaling(worker_counts, n_tenants: int, n_structures: int,
                  rounds: int, dim: int, waves: int, width: int,
                  max_wait_ms: float, repeats: int = 5) -> list[dict]:
    """Fixed tenant load, growing worker fleet (sticky by structure).

    Each fleet size is timed ``repeats`` times and the MEAN wall reported:
    a single sub-second sample is dominated by scheduler noise and by
    whether the tenant chains happen to phase-lock into the coalescing
    window (bimodal on few-core CI hosts, where N worker processes
    time-share the frontend's cores); the mean reports sustained
    throughput across both modes instead of a lucky lock-step run.
    """
    from repro.serving import ClusterFrontend

    rows = []
    for workers in worker_counts:
        tenants, shared_w = _make_tenants(n_tenants, n_structures, dim,
                                          waves, width)
        frontend = ClusterFrontend(workers=workers, registry=REGISTRY_SPEC,
                                   max_batch=max(2, n_tenants // n_structures),
                                   max_wait_ms=max_wait_ms,
                                   name=f"bench-scale-{workers}")
        for t in tenants:
            frontend.register_tenant(t["name"], t["tdg"],
                                     pinned={"w": shared_w})

        def serve_rpc(name, bufs):
            return frontend.serve(name, {k: v for k, v in bufs.items()
                                         if k != "w"}, timeout=300)

        _drive(serve_rpc, tenants, shared_w, 1)        # warm off the clock
        walls = [_drive(serve_rpc, tenants, shared_w, rounds)[0]
                 for _ in range(repeats)]
        wall = sum(walls) / len(walls)
        stats = frontend.stats()
        frontend.close()
        workers_used = len({r["worker"]
                            for r in stats["tenants"].values()})
        wire = stats["frontend"]["wire"]
        rows.append({
            "workers": workers,
            "workers_used": workers_used,
            "tenants": n_tenants,
            "structures": n_structures,
            "requests": n_tenants * rounds,
            "throughput_rps": n_tenants * rounds / max(wall, 1e-9),
            "entries_per_frame": (round(wire["entries_sent"]
                                        / wire["frames_sent"], 3)
                                  if wire["frames_sent"] else 0.0),
            "wire": wire,
            "transport": stats["frontend"]["transport"],
            "shm_fallbacks": stats["frontend"]["shm_fallbacks"],
            "aggregate": stats["aggregate"],
        })
        print(f"workers={workers}: {rows[-1]['throughput_rps']:8.1f} req/s "
              f"({workers_used} workers used, coalesced "
              f"{stats['aggregate']['coalesced_requests']}, "
              f"{rows[-1]['entries_per_frame']:.1f} entries/frame)",
              flush=True)
    return rows


def bench_remote_bootstrap(dim: int, waves: int, width: int,
                           rounds: int) -> dict:
    """Subprocess worker over localhost TCP: parity, warm ship, clean reap."""
    import jax.numpy as jnp

    from repro.core import ReplayExecutor, warmup_and_save
    from repro.serving import ClusterFrontend
    from repro.serving.demo import DEMO_REGISTRY, demo_region
    from repro.serving.worker import spawn_worker_subprocess

    rng = np.random.default_rng(2)
    bufs = {f"x{k}": jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
            for k in range(width)}
    bufs["w"] = jnp.asarray(rng.standard_normal((dim, dim)), jnp.float32)
    tdg = demo_region("remote[0]", waves=waves, width=width)
    tmp = tempfile.mkdtemp(prefix="bench_remote_")
    warm_path = os.path.join(tmp, "remote.json")
    warmup_and_save(tdg, bufs, warm_path, DEMO_REGISTRY)

    token = "bench-remote-token"
    t0 = time.perf_counter()
    proc, addr = spawn_worker_subprocess(REGISTRY_SPEC, token=token)
    bootstrap_s = time.perf_counter() - t0
    reaped = False
    try:
        frontend = ClusterFrontend(workers=[addr], registry=REGISTRY_SPEC,
                                   token=token, name="bench-remote")
        try:
            t0 = time.perf_counter()
            frontend.register_tenant("remote", warm_path=warm_path)
            out = frontend.serve("remote", bufs, timeout=600)
            first_request_s = time.perf_counter() - t0
            for _ in range(rounds - 1):
                out = frontend.serve("remote", bufs, timeout=600)
            stats = frontend.stats()
        finally:
            frontend.close()
        t0 = time.perf_counter()
        try:
            proc.wait(timeout=30)
            reaped = True
        except subprocess.TimeoutExpired:
            proc.kill()
        reap_s = time.perf_counter() - t0
    finally:
        if proc.poll() is None:
            proc.kill()
    want = ReplayExecutor(tdg).run(dict(bufs))
    parity = 0.0
    for k in want:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-4, atol=2e-4)
        parity = max(parity, float(np.abs(np.asarray(out[k])
                                          - np.asarray(want[k])).max()))
    worker = stats["workers"][0]
    return {
        "address": addr,
        "bootstrap_s": bootstrap_s,
        "warm_ship_first_request_s": first_request_s,
        "requests": rounds,
        "parity_max_abs_diff": parity,
        "hydrated_inband": stats["aggregate"]["hydrated_inband"],
        "aot_served": stats["aggregate"]["aot_served"],
        "intern_misses": worker["intern"]["misses"],
        "aot_hydrate_failures": stats["aggregate"]["aot_hydrate_failures"],
        "aot_topology_rejects": stats["aggregate"]["aot_topology_rejects"],
        "wire": stats["frontend"]["wire"],
        "worker_reaped": reaped,
        "reap_s": reap_s,
    }


def run(n_tenants: int = 8, rounds: int = 12, dim: int = 24, waves: int = 3,
        width: int = 4, n_structures: int = 4, worker_counts=(1, 2, 4),
        max_wait_ms: float = 25.0,
        out_path: str = "BENCH_cluster.json") -> dict:
    print("# phase 1/4: RPC frontend overhead vs in-process", flush=True)
    overhead = bench_overhead(n_tenants, rounds, dim, waves, width,
                              max_wait_ms)
    print(f"  inproc {overhead['inproc_throughput_rps']:.1f} req/s | rpc "
          f"{overhead['rpc_throughput_rps']:.1f} req/s | overhead "
          f"{overhead['rpc_overhead_ms_per_request']:.2f} ms/req", flush=True)
    print("# phase 2/4: cold start — warm-artifact ship vs re-lower",
          flush=True)
    cold = bench_cold_start(dim, waves + 2, width)
    print(f"  ship {cold['warm_ship_first_request_s']*1e3:.0f} ms | re-lower "
          f"{cold['relower_first_request_s']*1e3:.0f} ms | "
          f"{cold['speedup_cold_start']:.2f}x "
          f"({cold['artifact_bytes']} artifact bytes)", flush=True)
    print("# phase 3/4: worker scaling", flush=True)
    scaling = bench_scaling(worker_counts, n_tenants, n_structures, rounds,
                            dim, waves, width, max_wait_ms)
    print("# phase 4/4: remote bootstrap (subprocess worker, localhost TCP)",
          flush=True)
    remote = bench_remote_bootstrap(dim, waves, width, rounds)
    print(f"  bootstrap {remote['bootstrap_s']*1e3:.0f} ms | first request "
          f"{remote['warm_ship_first_request_s']*1e3:.0f} ms | hydrated "
          f"{remote['hydrated_inband']} | intern misses "
          f"{remote['intern_misses']} | reaped {remote['worker_reaped']}",
          flush=True)
    report = {"bench": "cluster", "dim": dim, "waves": waves, "width": width,
              "overhead": overhead, "cold_start": cold, "scaling": scaling,
              "remote_bootstrap": remote}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {out_path}", flush=True)
    return report


def _assert_gates(report: dict, overhead_budget_ms: float | None = None,
                  scaling_tolerance: float = 0.9) -> None:
    overhead, cold = report["overhead"], report["cold_start"]
    # The RPC front must never change WHAT is computed: replies are
    # bit-identical to the in-process run on EVERY transport.
    for name, row in overhead["transports"].items():
        assert row["parity_max_abs_diff"] == 0.0, (name, row)
        assert row["wire"]["timeouts"] == 0, (name, row)
    # The wire-path acceptance: the batch/pipelined/shm front stays under
    # the per-request overhead budget (the pre-coalescing rewrite cut the
    # seed's ~5.8 ms/req; the budget holds the line at a 3x reduction).
    if overhead_budget_ms is not None:
        assert overhead["rpc_overhead_ms_per_request"] < overhead_budget_ms, \
            overhead
    # Monotone scaling: adding workers must never LOSE throughput (the
    # seed's wire path collapsed 145 -> 50 req/s from 1 to 4 workers).
    # Per-step tolerance absorbs scheduler noise on few-core hosts (the
    # mean-of-N walls still jitter 15-20% when N worker processes
    # time-share one core); the full fleet must strictly beat one worker.
    rps = [r["throughput_rps"] for r in report["scaling"]]
    for prev, cur in zip(rps, rps[1:]):
        assert cur >= prev * scaling_tolerance, report["scaling"]
    assert rps[-1] >= rps[0], report["scaling"]
    # The headline acceptance: shipping the compiled artifact must beat
    # making the cold worker re-lower, and the shipped worker must actually
    # be warm (hydrated, served from AOT, never lowered anything).
    assert cold["warm_ship_first_request_s"] < \
        cold["relower_first_request_s"], cold
    assert cold["ship_hydrated_inband"] >= 1, cold
    assert cold["ship_aot_served"] >= 1, cold
    assert cold["ship_intern_misses"] == 0, cold
    assert cold["relower_intern_misses"] >= 1, cold
    assert cold["aot_hydrate_failures"] == 0, cold
    # The multi-host acceptance: a pre-started subprocess worker (no
    # multiprocessing handle) serves with parity, fully warm from the
    # shipped artifact, and is cleanly reaped by the shutdown RPC.
    remote = report["remote_bootstrap"]
    assert remote["parity_max_abs_diff"] < 1e-3, remote
    assert remote["hydrated_inband"] >= 1, remote
    assert remote["aot_served"] >= 1, remote
    assert remote["intern_misses"] == 0, remote
    assert remote["aot_hydrate_failures"] == 0, remote
    assert remote["worker_reaped"], remote


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny grid; asserts per-transport parity, "
                         "the rpc overhead budget, tolerant monotone "
                         "1->2->4 worker scaling, and the warm-ship gates")
    ap.add_argument("--out", default="BENCH_cluster.json")
    args = ap.parse_args(argv)
    if args.smoke:
        # Same tenant topology and region shape as the full run (8 tenants
        # over 4 structures, dim 24): the scaling phase's signal — admission
        # windows overlapping across workers — needs real per-request work;
        # at toy sizes a single worker is simply optimal and the phase
        # measures nothing. Smoke trims rounds, not the shape.
        report = run(n_tenants=8, rounds=3, dim=24, waves=2, width=4,
                     n_structures=4, worker_counts=(1, 2, 4),
                     out_path=args.out)
        # Smoke sizes are noisy: the budget is a regression tripwire (the
        # seed wire path measured ~5.8 ms/req), not the full-run target,
        # and the scaling tolerance is looser for the same reason.
        _assert_gates(report, overhead_budget_ms=4.0, scaling_tolerance=0.7)
        print("# smoke ok: rpc parity on tcp+shm + overhead under budget + "
              "monotone 1->2->4 workers + warm-ship beats re-lower + "
              "remote bootstrap warm and reaped")
    else:
        report = run(out_path=args.out)
        # Full-size acceptance: >= 3x under the seed's 5.77 ms/req.
        _assert_gates(report, overhead_budget_ms=1.93, scaling_tolerance=0.75)
        print(f"# acceptance: cold-start ship "
              f"{report['cold_start']['speedup_cold_start']:.2f}x faster "
              f"than re-lower; rpc overhead "
              f"{report['overhead']['rpc_overhead_ms_per_request']:.2f} "
              f"ms/req; scaling "
              + " -> ".join(f"{r['throughput_rps']:.1f}"
                            for r in report["scaling"]) + " req/s")


if __name__ == "__main__":
    main()
