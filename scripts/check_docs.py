#!/usr/bin/env python
"""Docs reference check: every file the docs point at must exist.

Scans README.md and docs/*.md for

  * backticked repo paths (``src/.../*.py``, ``scripts/*.sh``,
    ``examples/*.py``, ``benchmarks/*.py``, ``tests/*.py``, directories
    like ``src/repro/serving/``), and
  * relative markdown links (``[text](docs/architecture.md)``),

and fails if any named file or directory is missing — so the architecture
docs cannot silently rot as modules move. Run via ``scripts/ci.sh
--docs-smoke`` or directly:

    python scripts/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# The load-bearing doc set. docs/*.md are globbed, so a deleted doc would
# otherwise vanish from the check silently instead of failing it; every doc
# named here must exist AND be scanned.
REQUIRED_DOCS = (
    "README.md",
    "docs/architecture.md",
    "docs/kernels.md",
    "docs/serving.md",
)

# Repo-relative paths we expect to find in backticks. Deliberately NOT
# matching bare module names ("fuse.py") — those are anchored by the
# module-map tables, which use full src/ paths.
_PATH_RE = re.compile(
    r"`((?:src|scripts|examples|benchmarks|tests|docs)/[\w./-]+)`")
_LINK_RE = re.compile(r"\]\((?!https?://|#)([\w./-]+?)(?:#[\w-]*)?\)")


def references(md: pathlib.Path) -> set[str]:
    text = md.read_text()
    refs = set(_PATH_RE.findall(text))
    refs.update(_LINK_RE.findall(text))
    return refs


def main() -> int:
    docs = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    missing: list[tuple[str, str]] = []
    checked = 0
    scanned = {str(md.relative_to(ROOT)) for md in docs if md.exists()}
    for req in REQUIRED_DOCS:
        if req not in scanned:
            missing.append((req, "<required doc is missing>"))
    for md in docs:
        if not md.exists():
            missing.append((str(md.relative_to(ROOT)), "<the doc itself>"))
            continue
        for ref in sorted(references(md)):
            checked += 1
            # Markdown links resolve relative to the doc; backticked repo
            # paths are repo-root-relative. Accept either resolution.
            if not ((ROOT / ref).exists() or (md.parent / ref).exists()):
                missing.append((str(md.relative_to(ROOT)), ref))
    if missing:
        print("docs reference check FAILED — missing targets:")
        for doc, ref in missing:
            print(f"  {doc}: {ref}")
        return 1
    print(f"docs reference check ok: {checked} references across "
          f"{len(docs)} docs all resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
