#!/usr/bin/env bash
# Tier-1 verification — the single source of truth for the test invocation,
# so local runs and CI cannot drift. Usage:
#   scripts/ci.sh                 # default tier-1 run (slow sweeps excluded)
#   scripts/ci.sh -m slow         # opt into the slow interpret-mode sweeps
#   scripts/ci.sh --bench-smoke   # fusion + serving + cluster + chaos benchmark smokes (+ tier-1 run)
#   scripts/ci.sh --docs-smoke    # docs-and-examples smoke (+ tier-1 run)
#   scripts/ci.sh tests/test_registry.py -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--bench-smoke" ]]; then
  shift
  # CI-sized benchmark smokes: fusion asserts fused/unfused parity + traced-
  # program shrink; serving asserts multi-tenant parity + structural sharing
  # + coalescing, PLUS the continuous-batching gates — iteration-level
  # streams must meet or beat request-level round-trips on throughput at 8
  # tenants (identical finals), and under seeded open-loop Poisson overload
  # tier-1 p99 must beat tier-0 p99 with a non-empty, schema-valid
  # execution-pattern trace; cluster gates the wire path — exact per-transport parity
  # (tcp AND shm), the rpc-overhead-per-request budget, tolerant monotone
  # throughput across 1 -> 2 -> 4 workers (the seed wire path collapsed
  # here), warm-artifact shipping beating per-worker re-lowering on cold
  # start, AND the remote-bootstrap path: a `python -m repro.serving.worker`
  # subprocess over localhost TCP must serve with parity, hydrate the
  # shipped artifact (zero intern misses) and be reaped by the frontend's
  # shutdown RPC. The chaos smoke soaks the self-healing tier under a
  # seeded fault plan + mid-burst SIGKILL: every request must resolve
  # (result or typed error), the supervisor must respawn the slot warm
  # (zero intern misses, aot_served >= 1), recovered throughput must stay
  # within tolerance, and no worker pids or shm segments may leak.
  # Full runs: benchmarks.fusion / .serving / .cluster / .chaos
  python -m benchmarks.fusion --smoke --out /tmp/BENCH_fusion_smoke.json
  python -m benchmarks.serving --smoke --out /tmp/BENCH_serving_smoke.json
  python -m benchmarks.cluster --smoke --out /tmp/BENCH_cluster_smoke.json
  python -m benchmarks.chaos --smoke --out /tmp/BENCH_chaos_smoke.json
fi
if [[ "${1:-}" == "--docs-smoke" ]]; then
  shift
  # Docs-and-examples smoke: the quickstart must run end to end (it verifies
  # record/replay against jnp.linalg.cholesky), and every module path the
  # docs reference must exist.
  python -m examples.quickstart --n 64 --nb 4 --reps 1
  python scripts/check_docs.py
fi
exec python -m pytest -x -q "$@"
