#!/usr/bin/env bash
# Tier-1 verification — the single source of truth for the test invocation,
# so local runs and CI cannot drift. Usage:
#   scripts/ci.sh                 # default tier-1 run (slow sweeps excluded)
#   scripts/ci.sh -m slow         # opt into the slow interpret-mode sweeps
#   scripts/ci.sh --bench-smoke   # fusion benchmark smoke (+ tier-1 run)
#   scripts/ci.sh tests/test_registry.py -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--bench-smoke" ]]; then
  shift
  # CI-sized wave-fusion benchmark: asserts fused/unfused parity and that
  # the fused lowering shrinks the traced program (full run: benchmarks.fusion)
  python -m benchmarks.fusion --smoke --out /tmp/BENCH_fusion_smoke.json
fi
exec python -m pytest -x -q "$@"
