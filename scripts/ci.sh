#!/usr/bin/env bash
# Tier-1 verification — the single source of truth for the test invocation,
# so local runs and CI cannot drift. Usage:
#   scripts/ci.sh               # default tier-1 run (slow sweeps excluded)
#   scripts/ci.sh -m slow       # opt into the slow interpret-mode sweeps
#   scripts/ci.sh tests/test_registry.py -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
