#!/usr/bin/env bash
# Tier-1 verification — the single source of truth for the test invocation,
# so local runs and CI cannot drift. Usage:
#   scripts/ci.sh                 # tier-1 + 8-device mesh leg (slow sweeps excluded)
#   scripts/ci.sh -m slow         # opt into the slow interpret-mode sweeps
#   scripts/ci.sh --bench-smoke   # fusion + serving + cluster + chaos benchmark smokes (+ tier-1 run)
#   scripts/ci.sh --docs-smoke    # docs-and-examples smoke (+ tier-1 run)
#   scripts/ci.sh tests/test_registry.py -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--bench-smoke" ]]; then
  shift
  # CI-sized benchmark smokes: fusion asserts fused/unfused parity + traced-
  # program shrink; serving asserts multi-tenant parity + structural sharing
  # + coalescing, PLUS the continuous-batching gates — iteration-level
  # streams must meet or beat request-level round-trips on throughput at 8
  # tenants (identical finals), and under seeded open-loop Poisson overload
  # tier-1 p99 must beat tier-0 p99 with a non-empty, schema-valid
  # execution-pattern trace; cluster gates the wire path — exact per-transport parity
  # (tcp AND shm), the rpc-overhead-per-request budget, tolerant monotone
  # throughput across 1 -> 2 -> 4 workers (the seed wire path collapsed
  # here), warm-artifact shipping beating per-worker re-lowering on cold
  # start, AND the remote-bootstrap path: a `python -m repro.serving.worker`
  # subprocess over localhost TCP must serve with parity, hydrate the
  # shipped artifact (zero intern misses) and be reaped by the frontend's
  # shutdown RPC. The chaos smoke soaks the self-healing tier under a
  # seeded fault plan + mid-burst SIGKILL: every request must resolve
  # (result or typed error), the supervisor must respawn the slot warm
  # (zero intern misses, aot_served >= 1), recovered throughput must stay
  # within tolerance, and no worker pids or shm segments may leak.
  # Full runs: benchmarks.fusion / .serving / .cluster / .chaos
  python -m benchmarks.fusion --smoke --out /tmp/BENCH_fusion_smoke.json
  # Sharded-replay sweep: same smoke grid fused under 1/2/4/8 faked host
  # devices — gates on parity_max_abs_diff == 0.0 at every device count
  # (sharding the stacked batch axis moves lanes between devices, never
  # values).
  python -m benchmarks.fusion --smoke --devices 8 \
    --out /tmp/BENCH_fusion_devices_smoke.json
  python -m benchmarks.serving --smoke --out /tmp/BENCH_serving_smoke.json
  python -m benchmarks.cluster --smoke --out /tmp/BENCH_cluster_smoke.json
  python -m benchmarks.chaos --smoke --out /tmp/BENCH_chaos_smoke.json
  # Granularity-stability smoke (paper Fig. 10 + adaptive fusion): gates
  # bit-exact adaptive-vs-static parity (max_abs_diff == 0.0) at every
  # grain, adaptive <= static within tolerance everywhere, replay's
  # fine/coarse degradation ratio beating eager's, and at least one
  # cost-model map decision so the adaptive path is actually exercised.
  python -m benchmarks.granularity_stability --smoke \
    --out /tmp/BENCH_granularity_smoke.json
fi
if [[ "${1:-}" == "--docs-smoke" ]]; then
  shift
  # Docs-and-examples smoke: the quickstart must run end to end (it verifies
  # record/replay against jnp.linalg.cholesky), and every module path the
  # docs reference must exist.
  python -m examples.quickstart --n 64 --nb 4 --reps 1
  python scripts/check_docs.py
fi
# Mesh leg: the multi-device differential harness under 8 faked host
# devices (the flag must be set before jax initializes, hence a separate
# interpreter). In the plain tier-1 run below these tests skip themselves
# on the single real CPU device; here every sharded-vs-single-device case
# goes live.
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
  python -m pytest -x -q tests/test_mesh_replay.py tests/test_partition.py
exec python -m pytest -x -q "$@"
