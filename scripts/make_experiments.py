"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dryrun_artifacts/ JSONs. Run after the sweep:

    PYTHONPATH=src python scripts/make_experiments.py
"""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

ART = ROOT / "dryrun_artifacts"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["llama4-scout-17b-a16e", "qwen3-moe-30b-a3b", "qwen2.5-3b",
              "glm4-9b", "minitron-8b", "minicpm-2b", "mamba2-370m",
              "whisper-small", "hymba-1.5b", "chameleon-34b"]


def fmt(x, nd=4):
    return f"{x:.{nd}f}" if isinstance(x, (int, float)) else str(x)


def main():
    arts = {}
    for f in sorted(ART.glob("*.json")):
        a = json.loads(f.read_text())
        if "skipped" in a:
            continue
        tag = "+".join(f"{k}={v}" for k, v in sorted(a.get("opts", {}).items()))
        arts[(a["arch"], a["shape"], a["mesh"], tag)] = a

    lines = []
    lines.append("### Dry-run matrix (generated)\n")
    lines.append("| arch | shape | mesh | compile(s) | cost-mode | temp GB/dev | collectives (ag/ar/rs/aa/cp) |")
    lines.append("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                a = arts.get((arch, shape, mesh, ""))
                if a is None:
                    continue
                t = sum(v["compile_s"] for v in a["timings"].values())
                coll = a["collectives"]
                if "scan_mode" in coll:
                    coll = coll["scan_mode"]
                cs = "/".join(str(coll[k]["count"]) for k in
                              ("all-gather", "all-reduce", "reduce-scatter",
                               "all-to-all", "collective-permute"))
                mem = a["memory"].get("temp_size_in_bytes", 0) / 1e9
                lines.append(f"| {arch} | {shape} | {mesh} | {t:.0f} | "
                             f"{a['cost_mode']} | {mem:.1f} | {cs} |")
    lines.append("")
    lines.append("### Roofline table (generated; single-pod 16x16; seconds/step/device)\n")
    lines.append("| arch | shape | compute_s | memory_s | collective_s | dominant | MODEL/HLO flops | one-line bottleneck note |")
    lines.append("|---|---|---|---|---|---|---|---|")
    notes = {
        "compute_s": "matmul-bound; larger per-device batch or lower remat would help",
        "memory_s": "HBM-bound; fuse/shard the dominant tensor traffic",
        "collective_s": "ICI-bound; reshard or restructure the dominant collective",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            a = arts.get((arch, shape, "16x16", ""))
            if a is None:
                continue
            rl = a["roofline"]
            ratio = rl["useful_flop_ratio"]
            lines.append(
                f"| {arch} | {shape} | {fmt(rl['compute_s'])} | "
                f"{fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} | "
                f"{rl['dominant'].replace('_s','')} | "
                f"{fmt(ratio, 3) if ratio else 'n/a'} | "
                f"{notes[rl['dominant']]} |")
    lines.append("")
    lines.append("### Perf-iteration artifacts (opt-tagged cells)\n")
    lines.append("| arch | shape | opts | compute_s | memory_s | collective_s | dominant |")
    lines.append("|---|---|---|---|---|---|---|")
    for (arch, shape, mesh, tag), a in sorted(arts.items()):
        if not tag or mesh != "16x16":
            continue
        rl = a["roofline"]
        lines.append(f"| {arch} | {shape} | {tag} | {fmt(rl['compute_s'])} | "
                     f"{fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} | "
                     f"{rl['dominant'].replace('_s','')} |")
    out = "\n".join(lines) + "\n"
    (ROOT / "EXPERIMENTS_TABLES.md").write_text(out)
    print(out[:2000])
    print(f"... written to EXPERIMENTS_TABLES.md ({len(lines)} lines)")


if __name__ == "__main__":
    main()
