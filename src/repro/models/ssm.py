"""Mamba-2 mixer (SSD) — sequence path via the chunked-SSD kernel, decode
path via the O(1) single-step recurrence on a carried state.

Layout: in_proj -> [z | xBC | dt]; causal conv over xBC; SSD over heads;
gated RMSNorm; out_proj (follows the Mamba-2 reference architecture).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding import partition as P_
from . import layers as L

Params = dict


def ssm_dims(cfg: ModelConfig) -> dict:
    di = cfg.ssm_inner
    H = cfg.ssm_heads
    G, N, K = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    conv_ch = di + 2 * G * N
    return dict(d_inner=di, heads=H, P=cfg.ssm_headdim, groups=G, N=N,
                K=K, conv_ch=conv_ch,
                in_dim=2 * di + 2 * G * N + H)


def ssm_init(key, cfg: ModelConfig) -> Params:
    dm = cfg.d_model
    dd = ssm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    H = dd["heads"]
    p = {
        "A_log": jnp.zeros((H,), dt),                 # A = -exp(A_log) = -1
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.full((H,), -2.0, dt),          # softplus(-2) ~ 0.12
        "norm": L.rmsnorm_init(dd["d_inner"], dt),
        "out_proj": {"w": L._init_dense(L.key_for(key, "out"), (dd["d_inner"], dm), dt)},
    }
    if cfg.ssm_split_proj:
        # shard-boundary-aligned layout (§Perf iteration 3): z/x projections
        # TP-sharded on their own, B/C/dt small and replicated; depthwise
        # conv splits likewise (mathematically identical to the fused conv).
        gn = dd["groups"] * dd["N"]
        di = dd["d_inner"]
        p.update({
            "z_proj": {"w": L._init_dense(L.key_for(key, "z"), (dm, di), dt)},
            "x_proj": {"w": L._init_dense(L.key_for(key, "x"), (dm, di), dt)},
            "b_proj": {"w": L._init_dense(L.key_for(key, "b"), (dm, gn), dt)},
            "c_proj": {"w": L._init_dense(L.key_for(key, "c"), (dm, gn), dt)},
            "dt_proj": {"w": L._init_dense(L.key_for(key, "dt"), (dm, H), dt)},
            "xconv": {"w": L._init_dense(L.key_for(key, "xc"), (dd["K"], di), dt),
                      "b": jnp.zeros((di,), dt)},
            "bconv": {"w": L._init_dense(L.key_for(key, "bc"), (dd["K"], gn), dt),
                      "b": jnp.zeros((gn,), dt)},
            "cconv": {"w": L._init_dense(L.key_for(key, "cc"), (dd["K"], gn), dt),
                      "b": jnp.zeros((gn,), dt)},
        })
    else:
        p.update({
            "in_proj": {"w": L._init_dense(L.key_for(key, "in"),
                                           (dm, dd["in_dim"]), dt)},
            "conv": {"w": L._init_dense(L.key_for(key, "conv"),
                                        (dd["K"], dd["conv_ch"]), dt),
                     "b": jnp.zeros((dd["conv_ch"],), dt)},
        })
    return p


def _split_in(cfg: ModelConfig, proj: jax.Array):
    dd = ssm_dims(cfg)
    di, gn = dd["d_inner"], dd["groups"] * dd["N"]
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * gn]
    dt = proj[..., di + di + 2 * gn:]
    return z, xBC, dt


def _causal_conv(w: jax.Array, b: jax.Array, xBC: jax.Array,
                 state: jax.Array | None):
    """Depthwise causal conv1d, kernel K. state: (B, K-1, C) history."""
    K, C = w.shape
    Bz, S, _ = xBC.shape
    if state is None:
        hist = jnp.zeros((Bz, K - 1, C), xBC.dtype)
    else:
        hist = state.astype(xBC.dtype)
    full = jnp.concatenate([hist, xBC], axis=1)           # (B, S+K-1, C)
    out = jnp.zeros((Bz, S, C), jnp.float32)
    for k in range(K):
        out = out + full[:, k:k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = full[:, -(K - 1):] if K > 1 else jnp.zeros((Bz, 0, C), xBC.dtype)
    return jax.nn.silu(out).astype(xBC.dtype), new_state


def ssm_apply(p: Params, cfg: ModelConfig, x: jax.Array,
              state: dict | None = None) -> tuple[jax.Array, dict | None]:
    """x: (B, S, d_model). state (decode): {"conv": (B,K-1,C), "ssd": (B,H,P,N)}."""
    Bz, S, _ = x.shape
    dd = ssm_dims(cfg)
    H, Pd, G, N = dd["heads"], dd["P"], dd["groups"], dd["N"]
    cdt = cfg.compute_dtype

    if cfg.ssm_split_proj:
        z = L.linear(p["z_proj"], x, cdt)
        xr = L.linear(p["x_proj"], x, cdt)
        br = L.linear(p["b_proj"], x, cdt)
        cr = L.linear(p["c_proj"], x, cdt)
        dt_raw = L.linear(p["dt_proj"], x, cdt)
        cs = state["conv"] if state is not None else None
        di, gn = dd["d_inner"], G * N
        xcs = cs[..., :di] if cs is not None else None
        bcs = cs[..., di:di + gn] if cs is not None else None
        ccs = cs[..., di + gn:] if cs is not None else None
        xr, ncx = _causal_conv(p["xconv"]["w"], p["xconv"]["b"], xr, xcs)
        br, ncb = _causal_conv(p["bconv"]["w"], p["bconv"]["b"], br, bcs)
        cr, ncc = _causal_conv(p["cconv"]["w"], p["cconv"]["b"], cr, ccs)
        new_conv = jnp.concatenate([ncx, ncb, ncc], axis=-1)
        xin = xr.reshape(Bz, S, H, Pd)
        Bm = br.reshape(Bz, S, G, N)
        Cm = cr.reshape(Bz, S, G, N)
    else:
        proj = L.linear(p["in_proj"], x, cdt)
        proj = P_.constrain(proj, ("batch", None, "ssm_inner"))
        z, xBC, dt_raw = _split_in(cfg, proj)

        conv_state = state["conv"] if state is not None else None
        xBC, new_conv = _causal_conv(p["conv"]["w"], p["conv"]["b"], xBC,
                                     conv_state)

        xin = xBC[..., :dd["d_inner"]].reshape(Bz, S, H, Pd)
        Bm = xBC[..., dd["d_inner"]:dd["d_inner"] + G * N].reshape(Bz, S, G, N)
        Cm = xBC[..., dd["d_inner"] + G * N:].reshape(Bz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))        # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    init = state["ssd"] if state is not None else None
    if S == 1 and state is not None:
        # decode: single-step recurrence, no scan
        dA = jnp.exp(dt[:, 0, :] * A[None, :])                      # (B,H)
        Brep = jnp.repeat(Bm[:, 0], H // G, axis=1).astype(jnp.float32)  # (B,H,N)
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, 0], Brep,
                         xin[:, 0].astype(jnp.float32))
        h = dA[:, :, None, None] * init.astype(jnp.float32) + dBx
        Crep = jnp.repeat(Cm[:, 0], H // G, axis=1).astype(jnp.float32)  # (B,H,N)
        y = jnp.einsum("bhpn,bhn->bhp", h, Crep)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xin[:, 0].astype(jnp.float32)
        y = y.reshape(Bz, 1, dd["d_inner"]).astype(cdt)
        new_ssd = h
    else:
        y, new_ssd = ops.ssd(xin, dt.astype(cdt), A,
                             Bm.astype(cdt), Cm.astype(cdt),
                             D=p["D"].astype(jnp.float32),
                             init_state=init, chunk=cfg.ssm_chunk)
        y = y.reshape(Bz, S, dd["d_inner"]).astype(cdt)

    # gated norm + out projection
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm(p["norm"], y.astype(cdt))
    out = L.linear(p["out_proj"], y, cdt)
    new_state = ({"conv": new_conv, "ssd": new_ssd.astype(jnp.float32)}
                 if state is not None else None)
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int) -> dict:
    dd = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, dd["K"] - 1, dd["conv_ch"]), cfg.compute_dtype),
        "ssd": jnp.zeros((batch, dd["heads"], dd["P"], dd["N"]), jnp.float32),
    }
