"""Mixture-of-Experts layer: top-k router + capacity-based dispatch +
grouped expert GEMMs (Pallas kernel on TPU), EP-shardable over "experts".

Dispatch is static-shape (capacity factor) so the whole MoE layer is a
fixed wave of per-expert tasks in the TDG — the scheduler round-robins
experts across the EP axis exactly like the paper round-robins root tasks
across worker queues. Dropped tokens (over capacity) pass through the
residual, standard for capacity-based MoE.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding import partition as P_
from . import layers as L

Params = dict


def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, E = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "router": {"w": L._init_dense(L.key_for(key, "router"), (d, E), dt)},
        "experts": {
            "up": {"w": L._init_dense(L.key_for(key, "eup"), (E, d, f), dt, 1)},
            "gate": {"w": L._init_dense(L.key_for(key, "egate"), (E, d, f), dt, 1)},
            "down": {"w": L._init_dense(L.key_for(key, "edown"), (E, f, d), dt, 1)},
        },
    }
    for i in range(cfg.num_shared_experts):
        p[f"shared{i}"] = L.mlp_init(L.key_for(key, "shared", i), cfg, d_ff=f)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, min(n_tokens, math.ceil(c / 8) * 8))


def moe_apply(p: Params, cfg: ModelConfig, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Dispatches on cfg.moe_impl."""
    if cfg.moe_impl == "shard_map" and P_.active_mesh() is not None \
            and "model" in P_.active_mesh().axis_names:
        return moe_apply_shard_map(p, cfg, x)
    return moe_apply_gspmd(p, cfg, x)


def moe_apply_gspmd(p: Params, cfg: ModelConfig, x: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Baseline: global scatter/gather dispatch, GSPMD-propagated.

    Correct everywhere, but at pod scale the global-index scatter forces the
    partitioner to all-gather the token stream per layer (measured: the
    dominant collective term for 128-expert configs — see EXPERIMENTS.md
    §Perf iteration 1)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    cdt = cfg.compute_dtype
    T = B * S
    xt = x.reshape(T, d)

    logits = jax.lax.dot_general(
        xt.astype(cdt), p["router"]["w"].astype(cdt),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                   # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    # capacity-based positions via stable sort (O(T·K) memory — the one-hot
    # cumsum alternative is O(T·K·E) and unusable at 128 experts)
    C = capacity(cfg, T)
    flat_expert = expert_idx.reshape(-1)                           # (T*K,)
    TK = flat_expert.shape[0]
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[sort_idx]
    counts = jnp.bincount(flat_expert, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    ranks = jnp.arange(TK, dtype=jnp.int32) - offsets[sorted_expert].astype(jnp.int32)
    pos = jnp.zeros((TK,), jnp.int32).at[sort_idx].set(ranks)
    keep = pos < C

    # dispatch: scatter tokens into (E, C, d)
    tok_ids = jnp.repeat(jnp.arange(T), K)
    safe_pos = jnp.where(keep, pos, C - 1)
    disp = jnp.zeros((E, C, d), cdt)
    contrib = jnp.where(keep[:, None], xt[tok_ids].astype(cdt), 0)
    disp = disp.at[flat_expert, safe_pos].add(contrib)
    disp = P_.constrain(disp, ("experts", None, None))

    # expert GEMMs (grouped matmul kernel)
    up = ops.grouped_matmul(disp, p["experts"]["up"]["w"].astype(cdt))
    gate = ops.grouped_matmul(disp, p["experts"]["gate"]["w"].astype(cdt))
    h = (jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)).astype(cdt)
    h = P_.constrain(h, ("experts", None, None))
    eout = ops.grouped_matmul(h, p["experts"]["down"]["w"].astype(cdt))  # (E,C,d)

    # combine: gather expert outputs back to tokens, weighted by gates
    gathered = eout[flat_expert, safe_pos]                          # (T*K, d)
    weights = jnp.where(keep, gate_vals.reshape(-1), 0.0)
    combined = jax.ops.segment_sum(
        gathered.astype(jnp.float32) * weights[:, None], tok_ids, num_segments=T)
    out = combined.astype(cdt).reshape(B, S, d)

    for i in range(cfg.num_shared_experts):
        out = out + L.mlp_apply(p[f"shared{i}"], cfg, x)
    return out, aux


# ---------------------------------------------------------------------------
# shard_map EP implementation (beyond-paper optimization, §Perf iteration 1)
# ---------------------------------------------------------------------------

def moe_apply_shard_map(p: Params, cfg: ModelConfig, x: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE with *local* dispatch.

    Activations are replicated across the "model" axis (batch-sharded over
    pod/data only), expert weights are sharded over "model". Each device:
      1. computes the (replicated) router for ITS token shard,
      2. builds dispatch buffers for ONLY its local experts — pure local
         gather, zero communication,
      3. runs its local expert GEMMs,
      4. contributes partial combined outputs; one psum over "model" joins.

    Per layer the only cross-device traffic is the (T_local, d) all-reduce —
    vs. the baseline's token-stream all-gathers. This is the paper's static
    root-task distribution applied to experts: placement decided once by the
    sharding, no runtime negotiation.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = P_.active_mesh()
    E, K = cfg.num_experts, cfg.top_k
    tp = mesh.shape["model"]
    assert E % tp == 0, (E, tp)
    E_loc = E // tp
    cdt = cfg.compute_dtype
    B, S, d = x.shape

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    batch_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    def local(xl, router_w, eup, egate, edown):
        # xl: (B_loc, S, d) — this data-row's tokens, replicated over model
        m = jax.lax.axis_index("model")
        Bl = xl.shape[0]
        T = Bl * S
        xt = xl.reshape(T, d)
        logits = jax.lax.dot_general(
            xt.astype(cdt), router_w.astype(cdt),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (T, E) replicated
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), 0)
        aux = E * jnp.sum(me * ce) * cfg.router_aux_weight
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)       # global load-balance loss

        C = capacity(cfg, T)
        flat_expert = expert_idx.reshape(-1)
        TK = flat_expert.shape[0]
        sort_idx = jnp.argsort(flat_expert, stable=True)
        counts = jnp.bincount(flat_expert, length=E)
        offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                   jnp.cumsum(counts)[:-1]])
        ranks = (jnp.arange(TK, dtype=jnp.int32)
                 - offsets[flat_expert[sort_idx]].astype(jnp.int32))
        pos = jnp.zeros((TK,), jnp.int32).at[sort_idx].set(ranks)
        keep = pos < C

        # local experts only: e in [m*E_loc, (m+1)*E_loc)
        local_e = flat_expert - m * E_loc
        mine = (local_e >= 0) & (local_e < E_loc) & keep
        safe_e = jnp.clip(local_e, 0, E_loc - 1)
        safe_pos = jnp.where(mine, pos, C - 1)
        tok_ids = jnp.repeat(jnp.arange(T), K)
        contrib = jnp.where(mine[:, None], xt[tok_ids].astype(cdt), 0)
        disp = jnp.zeros((E_loc, C, d), cdt).at[safe_e, safe_pos].add(contrib)

        up = ops.grouped_matmul(disp, eup.astype(cdt))
        gate = ops.grouped_matmul(disp, egate.astype(cdt))
        h = (jax.nn.silu(gate.astype(jnp.float32))
             * up.astype(jnp.float32)).astype(cdt)
        eout = ops.grouped_matmul(h, edown.astype(cdt))   # (E_loc, C, d)

        gathered = eout[safe_e, safe_pos]                 # (T*K, d)
        weights = jnp.where(mine, gate_vals.reshape(-1), 0.0)
        combined = jax.ops.segment_sum(
            gathered.astype(jnp.float32) * weights[:, None], tok_ids,
            num_segments=T)
        out = jax.lax.psum(combined, "model")             # join over experts
        return out.reshape(Bl, S, d).astype(cdt), aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_spec, None, None),              # x: batch-sharded
                  P(None, None),                          # router replicated
                  P("model", None, None),                 # expert shards
                  P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(batch_spec, None, None), P()),
        check_rep=False)
    out, aux = fn(x, p["router"]["w"],
                  p["experts"]["up"]["w"], p["experts"]["gate"]["w"],
                  p["experts"]["down"]["w"])
    for i in range(cfg.num_shared_experts):
        out = out + L.mlp_apply(p[f"shared{i}"], cfg, x)
    return out, aux
