"""Functional building blocks (no framework deps): params are plain pytrees.

Every ``*_init`` returns a params dict; every ``*_apply`` is a pure function.
Stacked-layer params carry a leading ``L`` dim (scanned or indexed).
Compute dtype = cfg.dtype (bf16 target), params = cfg.param_dtype (f32),
f32 accumulation in every matmul that matters.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding import partition as P_

Params = dict


def key_for(key: jax.Array, *path) -> jax.Array:
    for p in path:
        key = jax.random.fold_in(key, hash(str(p)) & 0x7FFFFFFF)
    return key


def _init_dense(key, shape, dtype, scale_axis: int = 0):
    fan_in = shape[scale_axis]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# Linear / norm / embedding
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32) -> Params:
    p = {"w": _init_dense(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    y = jax.lax.dot_general(
        x.astype(compute_dtype), p["w"].astype(compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(compute_dtype)


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return ops.rmsnorm(x, p["scale"], eps=eps)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": _init_dense(key, (vocab, d), dtype, scale_axis=1)}


def embed(p: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)


def unembed(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    """Logits in f32 (softmax stability)."""
    return jax.lax.dot_general(
        x.astype(compute_dtype), p["table"].astype(compute_dtype),
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32. Rotates the first
    ``fraction`` of head dims (GLM partial rotary)."""
    B, S, H, D = x.shape
    rot = int(D * fraction) // 2 * 2
    if rot == 0 or theta <= 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = jnp.power(theta, -jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA; full / sliding / chunked; optional KV cache)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": linear_init(key_for(key, "wq"), d, H * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": linear_init(key_for(key, "wk"), d, Hkv * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": linear_init(key_for(key, "wv"), d, Hkv * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": linear_init(key_for(key, "wo"), H * hd, d, dtype=dt),
    }
    if cfg.qk_norm and not cross:
        p["qnorm"] = rmsnorm_init(hd, dt)
        p["knorm"] = rmsnorm_init(hd, dt)
    return p


def layer_attn_pattern(cfg: ModelConfig, layer_idx: int) -> tuple[str, int]:
    """(pattern, span) for a layer: 'full' | ('sliding', w) | ('chunked', c)."""
    if cfg.attention == "sliding" and cfg.window:
        return "sliding", cfg.window
    if cfg.attention == "chunked" and cfg.attn_chunk:
        k = cfg.global_attn_every
        if k and (layer_idx + 1) % k == 0:
            return "full", 0       # iRoPE: every k-th layer is global
        return "chunked", cfg.attn_chunk
    return "full", 0


def attention_apply(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                      # (B, S, d)
    positions: jax.Array,              # (B, S)
    *,
    pattern: str = "full",
    span: int = 0,
    causal: bool = True,
    kv_x: jax.Array | None = None,     # cross-attention source
    kv_positions: jax.Array | None = None,
    cache: dict | None = None,         # decode: {"k","v","pos","idx"}
    use_rope: bool = True,
) -> tuple[jax.Array, dict | None]:
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cdt = cfg.compute_dtype

    q = linear(p["wq"], x, cdt).reshape(B, S, H, hd)
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    k = linear(p["wk"], src, cdt).reshape(B, Skv, Hkv, hd)
    v = linear(p["wv"], src, cdt).reshape(B, Skv, Hkv, hd)

    if "qnorm" in p:
        q = rmsnorm(p["qnorm"], q)
        k = rmsnorm(p["knorm"], k)
    if use_rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
        kpos = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kpos, theta=cfg.rope_theta, fraction=cfg.rope_fraction)

    if cache is not None:
        out, cache = _cached_attention(cfg, q, k, v, positions, cache,
                                       pattern=pattern, span=span)
    else:
        window = span if pattern == "sliding" else None
        chunk = span if pattern == "chunked" else None
        out = ops.attention(q, k, v, causal=causal and kv_x is None,
                            window=window, chunk=chunk,
                            q_chunk=cfg.attn_q_chunk)
    out = out.reshape(B, S, H * hd)
    return linear(p["wo"], out, cdt), cache


def cache_len_for(cfg: ModelConfig, layer_idx: int, max_len: int) -> int:
    pattern, span = layer_attn_pattern(cfg, layer_idx)
    if pattern in ("sliding", "chunked") and span:
        return min(max_len, span)
    return max_len


def init_attn_cache(cfg: ModelConfig, layer_idx: int, batch: int,
                    max_len: int) -> dict:
    L = cache_len_for(cfg, layer_idx, max_len)
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    cdt = cfg.compute_dtype
    return {
        "k": jnp.zeros((batch, L, Hkv, hd), cdt),
        "v": jnp.zeros((batch, L, Hkv, hd), cdt),
        "pos": jnp.full((batch, L), -1, jnp.int32),   # absolute pos per slot
    }


def _cached_attention(cfg, q, k_new, v_new, positions, cache, *,
                      pattern: str, span: int):
    """Decode/step attention against a (ring-buffered) KV cache.

    Slots are addressed ``pos % cache_len`` — a ring buffer for sliding/
    chunked layers (cache_len == span), plain indexed for full layers.
    Keys are cached post-RoPE; masking uses per-slot absolute positions.
    """
    B, S, Hkv, hd = k_new.shape
    L = cache["k"].shape[1]
    slots = positions % L                                   # (B, S)
    bidx = jnp.arange(B)[:, None]
    ck = cache["k"].at[bidx, slots].set(k_new)
    cv = cache["v"].at[bidx, slots].set(v_new)
    cpos = cache["pos"].at[bidx, slots].set(positions)
    new_cache = {"k": ck, "v": cv, "pos": cpos}

    group = cfg.num_heads // Hkv
    qg = q.reshape(q.shape[0], q.shape[1], Hkv, group, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck.astype(jnp.float32)) * (hd ** -0.5)
    qpos = positions[:, :, None]                            # (B, S, 1)
    kpos = cpos[:, None, :]                                 # (B, 1, L)
    mask = (kpos >= 0) & (kpos <= qpos)                     # filled & causal
    if pattern == "sliding" and span:
        mask &= (qpos - kpos) < span
    if pattern == "chunked" and span:
        mask &= (qpos // span) == (kpos // span)
    s = jnp.where(mask[:, None, None], s, -1e30)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", jax.nn.softmax(s, axis=-1),
                     cv.astype(jnp.float32)).astype(q.dtype)
    out = out.reshape(q.shape)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (swiglu / gelu / relu^2)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    p = {"up": linear_init(key_for(key, "up"), d, f, dtype=dt),
         "down": linear_init(key_for(key, "down"), f, d, dtype=dt)}
    if cfg.mlp == "swiglu":
        p["gate"] = linear_init(key_for(key, "gate"), d, f, dtype=dt)
    return p


def mlp_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    cdt = cfg.compute_dtype
    up = linear(p["up"], x, cdt)
    if cfg.mlp == "swiglu":
        act = jax.nn.silu(linear(p["gate"], x, cdt).astype(jnp.float32))
        h = (act * up.astype(jnp.float32)).astype(cdt)
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(cdt)
    else:  # relu2 (Nemotron)
        r = jnp.maximum(up.astype(jnp.float32), 0.0)
        h = (r * r).astype(cdt)
    h = P_.constrain(h, ("batch", None, "ff"))
    return linear(p["down"], h, cdt)
