"""Model API: init / forward / loss (training) and prefill / decode (serving).

The step functions here are the payloads that the Taskgraph runtime records
and replays: shape-stable, pure, repeatedly executed — exactly the paper's
"recurrent taskgraph" profile (§4.2.3).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import partition as P_
from . import layers as L
from . import ssm as S
from . import transformer as T

Params = dict


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    p: Params = {
        "embed": L.embedding_init(L.key_for(key, "embed"), cfg.padded_vocab,
                                  cfg.d_model, jnp.dtype(cfg.param_dtype)),
        "layers": T.stack_init(L.key_for(key, "layers"), cfg, cfg.num_layers,
                               T.block_init),
        "final_norm": T._norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.embedding_init(L.key_for(key, "head"), cfg.padded_vocab,
                                     cfg.d_model, jnp.dtype(cfg.param_dtype))
    if cfg.encoder_layers:
        p["encoder"] = T.stack_init(L.key_for(key, "enc"), cfg,
                                    cfg.encoder_layers, T.encoder_block_init)
        p["enc_norm"] = T._norm_init(cfg, cfg.d_model)
    return p


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) int positions -> (B, S, d) sinusoidal embeddings (traceable)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, Se, d)."""
    x = frames.astype(cfg.compute_dtype)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = T.encoder_stack(params["encoder"], cfg, x)
    return T._norm(cfg, params["enc_norm"], x)


def hidden_states(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  positions: jax.Array | None = None,
                  enc_out: jax.Array | None = None,
                  mode: str = "train", caches: list | None = None):
    B, Sq = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None],
                                     (B, Sq))
    x = L.embed(params["embed"], tokens, cfg.compute_dtype) * cfg.embed_scale
    if cfg.family == "encdec" and cfg.rope_theta <= 0:
        # absolute sinusoidal positions, computed from the (possibly traced)
        # position ids so decode steps get the right phase
        x = x + _sinusoidal_at(positions, cfg.d_model).astype(x.dtype)
    x = P_.constrain(x, ("batch", None, None))
    x, aux, caches = T.decoder_stack(params["layers"], cfg, x, positions,
                                     mode=mode, caches=caches, enc_out=enc_out)
    x = T._norm(cfg, params["final_norm"], x)
    return x, aux, caches


def _logits(params: Params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = L.unembed(table, hidden, cfg.compute_dtype) * cfg.logit_scale
    if cfg.padded_vocab != cfg.vocab_size:   # mask pad columns out of softmax
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return P_.constrain(logits, ("batch", None, "vocab"))


def forward(params: Params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Full logits (B, S, V) — use loss_fn for training (chunked CE)."""
    enc_out = (_encode(params, cfg, batch["frames"])
               if cfg.family == "encdec" else None)
    h, aux, _ = hidden_states(params, cfg, batch["tokens"], enc_out=enc_out)
    return _logits(params, cfg, h), aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _ce_chunk(params, cfg, hidden, labels, mask):
    logits = _logits(params, cfg, hidden)                 # (B, s, V) f32
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    return ce.sum(), mask.sum()


def loss_fn(params: Params, cfg: ModelConfig, batch: dict):
    """Next-token CE (+ MoE aux). Big-vocab safe: CE over sequence chunks."""
    tokens = batch["tokens"]
    enc_out = (_encode(params, cfg, batch["frames"])
               if cfg.family == "encdec" else None)
    h, aux, _ = hidden_states(params, cfg, tokens, enc_out=enc_out)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"].astype(jnp.float32)

    B, Sq = tokens.shape
    chunk = cfg.loss_chunk
    if chunk and Sq % chunk == 0 and Sq > chunk:
        # python loop (not lax.scan): full logits never materialize, each
        # chunk's logits are rematerialized in the backward pass, and the
        # dry-run cost analysis stays exact (scan bodies are counted once).
        nc = Sq // chunk
        tot, cnt = jnp.zeros(()), jnp.zeros(())
        ck = jax.checkpoint(
            lambda hc, lc, mc: _ce_chunk(params, cfg, hc, lc, mc))
        for i in range(nc):
            s, n = ck(h[:, i * chunk:(i + 1) * chunk],
                      labels[:, i * chunk:(i + 1) * chunk],
                      mask[:, i * chunk:(i + 1) * chunk])
            tot, cnt = tot + s, cnt + n
    else:
        tot, cnt = _ce_chunk(params, cfg, h, labels, mask)
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> list:
    caches = []
    for i in range(cfg.num_layers):
        c: dict[str, Any] = {}
        if cfg.family == "ssm":
            c["ssm"] = S.init_ssm_state(cfg, batch)
        else:
            c["attn"] = L.init_attn_cache(cfg, i, batch, max_len)
            if cfg.hybrid_ssm:
                c["ssm"] = S.init_ssm_state(cfg, batch)
            if cfg.family == "encdec":
                Hkv, hd = cfg.num_kv_heads, cfg.head_dim
                c["cross_kv"] = {
                    "k": jnp.zeros((batch, cfg.encoder_seq, Hkv, hd),
                                   cfg.compute_dtype),
                    "v": jnp.zeros((batch, cfg.encoder_seq, Hkv, hd),
                                   cfg.compute_dtype),
                }
        caches.append(c)
    return caches


def prefill(params: Params, cfg: ModelConfig, batch: dict, max_len: int):
    """Process the prompt; returns (last-token logits, caches, next_pos)."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    enc_out = (_encode(params, cfg, batch["frames"])
               if cfg.family == "encdec" else None)
    caches = init_caches(cfg, B, max_len)
    h, _, caches = hidden_states(params, cfg, tokens, enc_out=enc_out,
                                 mode="prefill", caches=caches)
    logits = _logits(params, cfg, h[:, -1:])
    return logits, caches, jnp.full((B,), Sq, jnp.int32)


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                pos: jax.Array, caches: list):
    """One token per sequence: tokens (B, 1), pos (B,). Returns
    (logits (B, 1, V), new_caches)."""
    positions = pos[:, None]
    h, _, caches = hidden_states(params, cfg, tokens, positions=positions,
                                 mode="decode", caches=caches)
    return _logits(params, cfg, h), caches


def greedy_decode(params: Params, cfg: ModelConfig, batch: dict,
                  steps: int, max_len: int):
    """Simple serving loop used by examples/tests (jit the inner step)."""
    logits, caches, pos = prefill(params, cfg, batch, max_len)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    outs = [tok]
    step = jax.jit(lambda p, t, ps, c: decode_step(p, cfg, t, ps, c))
    for _ in range(steps - 1):
        logits, caches = step(params, tok[:, None], pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = pos + 1
        outs.append(tok)
    return jnp.stack(outs, axis=1)
