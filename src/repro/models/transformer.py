"""Blocks and layer stacks for all assigned families.

One homogeneous ``block_init``/``block_apply`` per architecture family:
  dense/vlm : pre-norm GQA attention + pre-norm MLP
  moe       : pre-norm GQA attention + pre-norm MoE
  ssm       : pre-norm Mamba-2 mixer (no MLP — pure Mamba-2 stack)
  hybrid    : pre-norm (attention ∥ SSM heads, fused) + pre-norm MLP (Hymba)
  encdec    : whisper encoder blocks (bidir) + decoder blocks w/ cross-attn

Train/prefill run the sequence path; decode runs the single-step path
against per-layer caches. Layer params are stacked (leading L dim):
``lax.scan`` over layers for training (grouped by ``global_attn_every``
to keep heterogeneous attention patterns static), unrolled indexing for
decode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import partition as P_
from . import layers as L
from . import moe as M
from . import ssm as S

Params = dict


def _norm_init(cfg: ModelConfig, d: int):
    return (L.layernorm_init(d, jnp.dtype(cfg.param_dtype))
            if cfg.family == "encdec"
            else L.rmsnorm_init(d, jnp.dtype(cfg.param_dtype)))


def _norm(cfg: ModelConfig, p, x):
    return L.layernorm(p, x) if cfg.family == "encdec" else L.rmsnorm(p, x)


# ---------------------------------------------------------------------------
# Decoder block (all families)
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    p: Params = {"norm1": _norm_init(cfg, d)}
    if cfg.family == "ssm":
        p["ssm"] = S.ssm_init(L.key_for(key, "ssm"), cfg)
        return p
    p["attn"] = L.attention_init(L.key_for(key, "attn"), cfg)
    if cfg.hybrid_ssm:
        p["ssm"] = S.ssm_init(L.key_for(key, "ssm"), cfg)
        p["attn_out_norm"] = L.rmsnorm_init(d, jnp.dtype(cfg.param_dtype))
        p["ssm_out_norm"] = L.rmsnorm_init(d, jnp.dtype(cfg.param_dtype))
    p["norm2"] = _norm_init(cfg, d)
    if cfg.num_experts:
        p["moe"] = M.moe_init(L.key_for(key, "moe"), cfg)
    else:
        p["mlp"] = L.mlp_init(L.key_for(key, "mlp"), cfg)
    if cfg.family == "encdec":
        p["cross_norm"] = _norm_init(cfg, d)
        p["cross"] = L.attention_init(L.key_for(key, "cross"), cfg, cross=True)
    return p


def block_apply(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    layer_idx: int,
    mode: str = "train",                 # train | prefill | decode
    cache: dict | None = None,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, dict | None]:
    aux = jnp.zeros((), jnp.float32)
    rs = cfg.residual_scale
    new_cache: dict | None = dict(cache) if cache is not None else None

    h = _norm(cfg, p["norm1"], x)
    if cfg.family == "ssm":
        y, st = S.ssm_apply(p["ssm"], cfg, h,
                            state=cache["ssm"] if cache else None)
        if new_cache is not None:
            new_cache["ssm"] = st
        return x + rs * y, aux, new_cache

    pattern, span = L.layer_attn_pattern(cfg, layer_idx)
    if mode == "decode":
        attn_out, ac = L.attention_apply(
            p["attn"], cfg, h, positions, pattern=pattern, span=span,
            cache=cache["attn"])
        new_cache["attn"] = ac
    else:
        attn_out, _ = L.attention_apply(
            p["attn"], cfg, h, positions, pattern=pattern, span=span)
        if mode == "prefill":
            new_cache["attn"] = _write_prefill_cache(
                cfg, p["attn"], h, positions, cache["attn"])

    if cfg.hybrid_ssm:
        ssm_out, st = S.ssm_apply(p["ssm"], cfg, h,
                                  state=cache["ssm"] if cache else None)
        if new_cache is not None and mode != "train":
            new_cache["ssm"] = st
        fused = 0.5 * (L.rmsnorm(p["attn_out_norm"], attn_out)
                       + L.rmsnorm(p["ssm_out_norm"], ssm_out))
        x = x + rs * fused
    else:
        x = x + rs * attn_out

    if cfg.family == "encdec" and (
            enc_out is not None
            or (cache is not None and "cross_kv" in cache)):
        hc = _norm(cfg, p["cross_norm"], x)
        if mode == "decode" and cache is not None and "cross_kv" in cache:
            c_out = _cross_from_cache(p["cross"], cfg, hc, cache["cross_kv"])
        else:
            c_out, _ = L.attention_apply(
                p["cross"], cfg, hc, positions, causal=False, kv_x=enc_out,
                kv_positions=jnp.zeros(enc_out.shape[:2], jnp.int32),
                use_rope=False)
            if new_cache is not None:
                new_cache["cross_kv"] = _make_cross_cache(p["cross"], cfg, enc_out)
        x = x + rs * c_out

    h2 = _norm(cfg, p["norm2"], x)
    if cfg.num_experts:
        mlp_out, aux = M.moe_apply(p["moe"], cfg, h2)
    else:
        mlp_out = L.mlp_apply(p["mlp"], cfg, h2)
    return x + rs * mlp_out, aux, new_cache


def _write_prefill_cache(cfg, pa, h, positions, cache):
    """Recompute K/V for the tail of the sequence and fill the ring cache."""
    B, Sq, _ = h.shape
    Lc = cache["k"].shape[1]
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    cdt = cfg.compute_dtype
    k = L.linear(pa["wk"], h, cdt).reshape(B, Sq, Hkv, hd)
    v = L.linear(pa["wv"], h, cdt).reshape(B, Sq, Hkv, hd)
    if "knorm" in pa:
        k = L.rmsnorm(pa["knorm"], k)
    if cfg.rope_theta > 0:
        k = L.apply_rope(k, positions, theta=cfg.rope_theta,
                         fraction=cfg.rope_fraction)
    take = min(Sq, Lc)
    k, v, pos = k[:, -take:], v[:, -take:], positions[:, -take:]
    slots = pos % Lc
    bidx = jnp.arange(B)[:, None]
    return {"k": cache["k"].at[bidx, slots].set(k),
            "v": cache["v"].at[bidx, slots].set(v),
            "pos": cache["pos"].at[bidx, slots].set(pos)}


def _make_cross_cache(pa, cfg, enc_out):
    B, Se, _ = enc_out.shape
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    cdt = cfg.compute_dtype
    return {"k": L.linear(pa["wk"], enc_out, cdt).reshape(B, Se, Hkv, hd),
            "v": L.linear(pa["wv"], enc_out, cdt).reshape(B, Se, Hkv, hd)}


def _cross_from_cache(pa, cfg, h, kv):
    B, Sq, _ = h.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cdt = cfg.compute_dtype
    q = L.linear(pa["wq"], h, cdt).reshape(B, Sq, H, hd)
    group = H // Hkv
    kf = jnp.repeat(kv["k"], group, axis=2)
    vf = jnp.repeat(kv["v"], group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * (hd ** -0.5)
    out = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1),
                     vf.astype(jnp.float32)).astype(cdt)
    return L.linear(pa["wo"], out.reshape(B, Sq, H * hd), cdt)


# ---------------------------------------------------------------------------
# Encoder block (whisper; bidirectional, no rope)
# ---------------------------------------------------------------------------

def encoder_block_init(key, cfg: ModelConfig) -> Params:
    return {
        "norm1": _norm_init(cfg, cfg.d_model),
        "attn": L.attention_init(L.key_for(key, "attn"), cfg),
        "norm2": _norm_init(cfg, cfg.d_model),
        "mlp": L.mlp_init(L.key_for(key, "mlp"), cfg),
    }


def encoder_block_apply(p, cfg, x, positions):
    h = _norm(cfg, p["norm1"], x)
    a, _ = L.attention_apply(p["attn"], cfg, h, positions, causal=False,
                             use_rope=False)
    x = x + a
    x = x + L.mlp_apply(p["mlp"], cfg, _norm(cfg, p["norm2"], x))
    return x


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def stack_init(key, cfg: ModelConfig, n_layers: int, init_fn) -> Params:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: init_fn(k, cfg))(keys)


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def decoder_stack(params_layers: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, *, mode: str = "train",
                  caches: list | None = None, enc_out: jax.Array | None = None):
    """Run all decoder blocks. Returns (x, total_aux, new_caches)."""
    n = cfg.num_layers
    if cfg.scan_layers and caches is None and cfg.family != "encdec":
        g = cfg.global_attn_every if cfg.global_attn_every else 1
        assert n % g == 0

        def group_body(carry, lp):
            xx, aux = carry
            for j in range(g):
                pj = jax.tree_util.tree_map(lambda a: a[j], lp) if g > 1 else lp
                xx, a, _ = block_apply(pj, cfg, xx, positions,
                                       layer_idx=j, mode=mode)
                aux = aux + a
            xx = P_.constrain(xx, ("batch", None, None))
            return (xx, aux), None

        body = _remat_wrap(cfg, group_body)
        stacked = params_layers
        if g > 1:
            stacked = jax.tree_util.tree_map(
                lambda a: a.reshape((n // g, g) + a.shape[1:]), stacked)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   stacked)
        return x, aux, None

    # unrolled (decode / prefill / encdec / smoke)
    aux = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None
    for i in range(n):
        pi = jax.tree_util.tree_map(lambda a: a[i], params_layers)
        ci = caches[i] if caches is not None else None

        def run_block(pi_, x_, pos_, ci_, enc_, _i=i):
            return block_apply(pi_, cfg, x_, pos_, layer_idx=_i, mode=mode,
                               cache=ci_, enc_out=enc_)

        if cfg.remat != "none" and mode == "train":
            if cfg.remat == "dots":
                run_block = jax.checkpoint(
                    run_block,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            else:
                run_block = jax.checkpoint(run_block)
        x, a, nc = run_block(pi, x, positions, ci, enc_out)
        aux = aux + a
        if new_caches is not None:
            new_caches.append(nc)
    return x, aux, new_caches


def encoder_stack(params_layers: Params, cfg: ModelConfig, x: jax.Array):
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    if cfg.scan_layers:
        def body(xx, lp):
            xx = encoder_block_apply(lp, cfg, xx, positions)
            return xx, None
        x, _ = jax.lax.scan(_remat_wrap(cfg, body) if cfg.remat != "none"
                            else body, x, params_layers)
        return x
    for i in range(cfg.encoder_layers):
        pi = jax.tree_util.tree_map(lambda a: a[i], params_layers)
        x = encoder_block_apply(pi, cfg, x, positions)
    return x
