"""Model zoo: functional layers, blocks and the model API."""
from . import layers, model, moe, ssm, transformer
from .model import (decode_step, forward, greedy_decode, hidden_states,
                    init_caches, init_params, loss_fn, param_count, prefill)

__all__ = ["layers", "model", "moe", "ssm", "transformer",
           "init_params", "forward", "loss_fn", "hidden_states",
           "init_caches", "prefill", "decode_step", "greedy_decode",
           "param_count"]
