"""Train/serve step construction.

Two granularities, per DESIGN.md §4:

* ``make_train_step`` — the production path: one pure function
  (fwd + bwd + clip + AdamW), replay-compiled once and re-executed every
  step. This is the whole-region TDG replay (the paper's execute_TDG) at
  step granularity; XLA owns overlap/fusion inside.

* ``make_tdg_train_region`` — the paper-faithful fine-grained path: the
  step expressed as a TaskGraphRegion whose tasks are embed / per-layer
  fwd / per-layer bwd (recompute-style VJP) / loss / grad-accumulate /
  optimizer update. Used by the paper-mirror benchmarks (eager-vs-replay)
  and the examples; numerically equal to the fused step.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import TaskGraphRegion, taskgraph
from ..models import layers as L
from ..models import model as M
from ..models import transformer as T
from ..optim import adamw as _adamw_mod  # noqa: F401
from ..optim.adamw import Optimizer, apply_updates


def make_train_step(cfg: ModelConfig, optimizer: Optimizer):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        updates, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """(params, tokens (B,1), pos (B,), caches) -> (next_tokens, new_caches)."""

    def serve_step(params, tokens, pos, caches):
        logits, caches = M.decode_step(params, cfg, tokens, pos, caches)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, caches

    return serve_step


# ---------------------------------------------------------------------------
# Fine-grained TDG step (per-layer fwd/bwd tasks)
# ---------------------------------------------------------------------------

def make_tdg_train_region(cfg: ModelConfig, optimizer: Optimizer,
                          name: str = "tdg_train_step") -> TaskGraphRegion:
    """Build the per-layer task region. Buffers:
    in : params (pytree slot), opt_state, tokens
    out: params, opt_state, loss
    """
    n = cfg.num_layers

    def build(g, params, opt_state, tokens):
        # embed task
        def embed_fn(p, toks):
            B, Sq = toks.shape
            pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
            x = L.embed(p["embed"], toks, cfg.compute_dtype) * cfg.embed_scale
            return x, pos
        g.task(embed_fn, ins=["params", "tokens"], outs=["x0", "positions"],
               name="embed")

        # forward chain
        for i in range(n):
            def fwd(p, x, positions, _i=i):
                lp = jax.tree_util.tree_map(lambda a: a[_i], p["layers"])
                y, aux, _ = T.block_apply(lp, cfg, x, positions, layer_idx=_i)
                return y, aux
            g.task(fwd, ins=["params", f"x{i}", "positions"],
                   outs=[f"x{i + 1}", f"aux{i}"], name=f"fwd_L{i}")

        # loss head (+ grad wrt final hidden) as one task
        def head_loss(p, xn, toks, *auxes):
            def f(xn_):
                h = T._norm(cfg, p["final_norm"], xn_)
                table = p["embed"] if cfg.tie_embeddings else p["head"]
                logits = L.unembed(table, h, cfg.compute_dtype) * cfg.logit_scale
                labels = jnp.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
                mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
                lse = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
                return ((lse - gold) * mask).sum() / mask.sum()
            ce, gxn = jax.value_and_grad(f)(xn)
            loss = ce + sum(auxes)
            return loss, gxn
        g.task(head_loss,
               ins=["params", f"x{n}", "tokens"] + [f"aux{i}" for i in range(n)],
               outs=["loss", f"gx{n}"], name="head_loss")

        # head/embed/final_norm param grads (recompute VJP)
        def head_bwd(p, xn, toks):
            def f(fn_, tab_):
                h = L.rmsnorm(fn_, xn) if cfg.family != "encdec" else L.layernorm(fn_, xn)
                logits = L.unembed(tab_, h, cfg.compute_dtype) * cfg.logit_scale
                labels = jnp.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
                mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
                lse = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
                return ((lse - gold) * mask).sum() / mask.sum()
            table = p["embed"] if cfg.tie_embeddings else p["head"]
            _, vjp = jax.vjp(f, p["final_norm"], table)
            gfn, gtab = vjp(jnp.ones(()))
            return gfn, gtab
        g.task(head_bwd, ins=["params", f"x{n}", "tokens"],
               outs=["g_final_norm", "g_table"], name="head_bwd")

        # backward chain (one task per layer; recompute inside)
        for i in reversed(range(n)):
            def bwd(p, x, positions, gy, _i=i):
                lp = jax.tree_util.tree_map(lambda a: a[_i], p["layers"])
                def f(lp_, x_):
                    y, aux, _ = T.block_apply(lp_, cfg, x_, positions, layer_idx=_i)
                    return y, aux
                _, vjp = jax.vjp(f, lp, x)
                glp, gx = vjp((gy, jnp.ones((), jnp.float32)))
                return gx, glp
            g.task(bwd, ins=["params", f"x{i}", "positions", f"gx{i + 1}"],
                   outs=[f"gx{i}", f"glayer{i}"], name=f"bwd_L{i}")

        # embedding grad from gx0 + head grads
        def embed_bwd(p, toks, gx0, gtab, gfn):
            def f(emb_):
                return (L.embed(emb_, toks, cfg.compute_dtype)
                        * cfg.embed_scale).astype(jnp.float32)
            _, vjp = jax.vjp(f, p["embed"])
            (gemb,) = vjp(gx0.astype(jnp.float32))
            if cfg.tie_embeddings:
                gemb = jax.tree_util.tree_map(
                    lambda a, b: a + b, gemb, gtab)
                ghead = None
            else:
                ghead = gtab
            return gemb, ghead, gfn
        g.task(embed_bwd, ins=["params", "tokens", "gx0", "g_table",
                               "g_final_norm"],
               outs=["g_embed", "g_head", "g_final_norm2"], name="embed_bwd")

        # assemble grads + optimizer update
        def opt_update(p, s, gemb, ghead, gfn, *glayers):
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *glayers)
            grads = {"embed": gemb, "layers": stacked, "final_norm": gfn}
            if not cfg.tie_embeddings:
                grads["head"] = ghead
            updates, s2, _m = optimizer.update(grads, s, p)
            p2 = apply_updates(p, updates)
            return p2, s2
        g.task(opt_update,
               ins=["params", "opt_state", "g_embed", "g_head",
                    "g_final_norm2"] + [f"glayer{i}" for i in range(n)],
               outs=["params", "opt_state"], name="opt_update")

    return TaskGraphRegion(build, name=name,
                           donate_slots=("params", "opt_state"),
                           outputs=("params", "opt_state", "loss"))
