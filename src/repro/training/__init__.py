"""Training steps: plain fused step + TDG-granular step (record/replay)."""
from .step import make_train_step, make_tdg_train_region, make_serve_step

__all__ = ["make_train_step", "make_tdg_train_region", "make_serve_step"]
