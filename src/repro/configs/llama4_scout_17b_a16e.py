"""Llama-4 Scout 17B-active / 16 experts (early-fusion MoE).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048, 16e top-1,
one shared expert; iRoPE-style chunked-local attention with a full-attention
layer every 4 (global layers keep the TDG shape static; chunk=8192).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    attention="chunked",
    attn_chunk=8192,
    global_attn_every=4,
    num_experts=16,
    top_k=1,
    moe_d_ff=8192,
    num_shared_experts=1,
    rope_theta=500000.0,
    loss_chunk=2048,
)
