"""MiniCPM-2B. [arXiv:2404.06395; hf]

40L d_model=2304 36H (MHA: kv=36) d_ff=5760 vocab=122753; llama-like
architecture with mu-parametrization scaling (scale_emb=12,
scale_depth=1.4 -> residual_scale = 1.4/sqrt(40)) and tied embeddings;
trained with the WSD schedule (see repro.optim.schedule.wsd).
"""
import math

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    embed_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logit_scale=1.0 / (2304 / 256),
    rope_theta=10000.0,
    loss_chunk=2048,
)
