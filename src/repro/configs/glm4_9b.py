"""GLM-4 9B. [hf:THUDM/glm-4-9b; hf]

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552; QKV bias,
partial rotary (half of head_dim).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    qkv_bias=True,
    rope_fraction=0.5,
    rope_theta=10000.0,
    loss_chunk=2048,
)
