"""Hymba-1.5B (hybrid attention + mamba heads in parallel). [arXiv:2411.13676; hf]

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504, ssm_state=16,
vocab=32001. Each block runs attention heads and SSM heads in PARALLEL on
the same input and fuses (mean of per-path RMSNorm) — per the paper.
Sliding-window attention (w=1024) on all layers (the released model keeps
3 full-attention layers; we use SWA uniformly and note the deviation in
DESIGN.md) -> sub-quadratic, long_500k applicable.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention="sliding",
    window=1024,
    hybrid_ssm=True,
    ssm_state=16,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=128,
    rope_theta=10000.0,
    loss_chunk=2048,
)
