"""Qwen3-MoE 30B-A3B. [hf:Qwen/Qwen3-30B-A3B; hf]

48L d_model=2048 32H (GQA kv=4, head_dim=128, qk-norm) expert d_ff=768,
vocab=151936, MoE 128 experts top-8 (no shared expert).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    rope_theta=1000000.0,
    loss_chunk=2048,
)
