"""Whisper-small (encoder-decoder). [arXiv:2212.04356; unverified]

12L encoder + 12L decoder, d_model=768 12H (MHA) d_ff=3072 GELU,
vocab=51865. The conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, 1500, d). Assigned shapes apply to the
decoder; the encoder keeps Whisper's native 1500 frames.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp="gelu",
    encoder_layers=12,
    encoder_seq=1500,
    rope_theta=0.0,      # whisper uses absolute (sinusoidal) positions
    loss_chunk=2048,
)
