"""Mamba2-370m (SSD, attention-free). [arXiv:2405.21060; unverified]

48L d_model=1024, ssm_state=128, headdim=64 (expand=2 -> d_inner=2048,
32 ssm heads), vocab=50280. No attention, no MLP (pure Mamba-2 stack).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=1,        # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=128,
    tie_embeddings=True,
    loss_chunk=2048,
)
