"""Config system: model architecture + input-shape configurations.

Every assigned architecture is a ``ModelConfig`` in its own module
(``repro/configs/<id>.py``); shapes are the four assigned input-shape sets.
Configs are plain frozen dataclasses — hashable, printable, serializable —
and every derived quantity (param counts, per-token FLOPs) lives here so the
roofline analysis and the benchmarks share one source of truth.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                       # 0 -> d_model // num_heads

    # attention flavor
    attention: Literal["full", "sliding", "chunked"] = "full"
    window: int = 0                         # sliding-window size
    attn_chunk: int = 0                     # chunked-local chunk size
    global_attn_every: int = 0              # every k-th layer is full attn
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0              # GLM partial rotary

    # MLP
    mlp: Literal["swiglu", "gelu", "relu2"] = "swiglu"

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                       # per-expert hidden (0 -> d_ff)
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (Hymba): SSM runs in parallel with attention inside each block
    hybrid_ssm: bool = False

    # encoder-decoder (Whisper): stub conv frontend supplies frame embeddings
    encoder_layers: int = 0
    encoder_seq: int = 1500

    # embeddings / scaling (MiniCPM mu-parametrization)
    tie_embeddings: bool = False
    embed_scale: float = 1.0
    residual_scale: float = 1.0             # applied per-block output
    logit_scale: float = 1.0

    # numerics / lowering
    dtype: str = "bfloat16"                 # activation/compute dtype
    param_dtype: str = "float32"
    attn_q_chunk: int = 2048                # q-chunking of full attention

    # ---- beyond-paper perf knobs (see EXPERIMENTS.md §Perf) ----
    moe_impl: str = "gspmd"                 # "gspmd" | "shard_map" (EP-local
    #                                         dispatch + psum combine)
    shard_kv_seq: bool = False              # decode: shard cache length over
    #                                         "model" (MHA-style archs)
    ssm_split_proj: bool = False            # separate z/xBC/dt projections
    #                                         (shard-boundary aligned)
    scan_layers: bool = True                # lax.scan over stacked layers
    remat: Literal["none", "full", "dots"] = "full"
    loss_chunk: int = 0                     # CE in chunks of tokens (0 = off)

    # ---- derived ----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a TP-friendly multiple (MaxText-style)."""
        mult = 256
        return (self.vocab_size + mult - 1) // mult * mult

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def mlp_params(self, d_ff: int) -> int:
        per = 3 if self.mlp == "swiglu" else 2
        return per * self.d_model * d_ff

    def attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        return d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d

    def ssm_params(self) -> int:
        di, n, g = self.ssm_inner, self.ssm_state, self.ssm_groups
        in_proj = self.d_model * (2 * di + 2 * g * n + self.ssm_heads)
        out_proj = di * self.d_model
        conv = self.ssm_conv * (di + 2 * g * n)
        return in_proj + out_proj + conv + 2 * self.ssm_heads

    def block_params(self) -> int:
        """Parameters of one decoder block (norms excluded, negligible)."""
        p = 0
        if self.family == "ssm":
            return self.ssm_params()
        p += self.attn_params()
        if self.hybrid_ssm:
            p += self.ssm_params()
        if self.num_experts:
            p += self.num_experts * self.mlp_params(self.expert_d_ff)
            p += self.num_shared_experts * self.mlp_params(self.expert_d_ff)
            p += self.d_model * self.num_experts          # router
        else:
            p += self.mlp_params(self.d_ff)
        return p

    def active_block_params(self) -> int:
        p = 0
        if self.family == "ssm":
            return self.ssm_params()
        p += self.attn_params()
        if self.hybrid_ssm:
            p += self.ssm_params()
        if self.num_experts:
            p += (self.top_k + self.num_shared_experts) * self.mlp_params(self.expert_d_ff)
            p += self.d_model * self.num_experts
        else:
            p += self.mlp_params(self.d_ff)
        return p

    def param_count(self) -> int:
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        body = self.num_layers * self.block_params()
        if self.encoder_layers:
            body += self.encoder_layers * (self.attn_params() + self.mlp_params(self.d_ff))
            body += self.num_layers * self.attn_params()  # cross-attention
        return emb + body

    def active_param_count(self) -> int:
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        body = self.num_layers * self.active_block_params()
        if self.encoder_layers:
            body += self.encoder_layers * (self.attn_params() + self.mlp_params(self.d_ff))
            body += self.num_layers * self.attn_params()
        return emb + body

    def model_flops_per_token(self, seq_len: int, training: bool = True,
                              decode: bool = False) -> float:
        """6·N_active·D convention (fwd 2N + bwd 4N; MoE: active params),
        plus the attention O(S·d) term. ``decode``: one token against a
        seq_len-long context."""
        n = self.active_param_count()
        mult = 6.0 if training else 2.0
        flops = mult * n
        # effective kv context seen per token
        if self.family != "ssm":
            if decode:
                eff = seq_len
                if self.attention == "sliding" and self.window:
                    eff = min(eff, self.window)
                if self.attention == "chunked" and self.attn_chunk:
                    eff = min(eff, self.attn_chunk)
            else:
                eff = seq_len / 2  # causal average
                if self.attention == "sliding" and self.window:
                    eff = min(eff, self.window)
                if self.attention == "chunked" and self.attn_chunk:
                    eff = min(eff, self.attn_chunk / 2)
            # qk^T and pv matmuls: 2 * 2 * H * hd * eff each fwd
            att = 4.0 * self.num_heads * self.head_dim * eff
            flops += (mult / 2) * self.num_layers * att
        if self.family == "ssm" or self.hybrid_ssm:
            # SSD state update + readout per token ~ 6 * d_inner * N
            flops += (mult / 2) * self.num_layers * 6.0 * self.ssm_inner * self.ssm_state
        return flops


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch           # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(config: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell is assigned (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        subquadratic = (config.family == "ssm"
                        or config.hybrid_ssm
                        or (config.attention == "sliding" and config.window > 0))
        if not subquadratic:
            return False, "full-attention arch: long_500k skipped (quadratic)"
    return True, ""


def reduced(config: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(config.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        window=min(config.window, 32) if config.window else 0,
        attn_chunk=min(config.attn_chunk, 32) if config.attn_chunk else 0,
        num_experts=min(config.num_experts, 4),
        top_k=min(config.top_k, 2),
        moe_d_ff=96 if config.num_experts else 0,
        # drop-free capacity: keeps smoke tests deterministic across
        # different token counts (prefill vs teacher-forced forward)
        capacity_factor=float(max(4, config.num_experts and 4)),
        ssm_state=min(config.ssm_state, 16) if config.ssm_state else 0,
        ssm_headdim=16,
        ssm_chunk=16,
        encoder_layers=2 if config.encoder_layers else 0,
        encoder_seq=24 if config.encoder_layers else 1500,
        scan_layers=False,
        remat="none",
        dtype="float32",
        loss_chunk=0,
        name=config.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(config, **small)
