"""Chameleon-34B (early-fusion VLM). [arXiv:2405.09818; unverified]

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. Early fusion:
image patches arrive as VQ token ids in the SAME token stream (the VQ-GAN
tokenizer is a STUB — ``input_specs`` provides token ids directly).
QK-norm per the paper's training-stability recipe.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    rope_theta=10000.0,
    loss_chunk=2048,
)
