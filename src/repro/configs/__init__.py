"""Architecture registry: ``get_config(arch_id)`` + the assigned shape sets."""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeConfig, reduced, shape_applicable

_ARCH_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2.5-3b": "qwen2_5_3b",
    "glm4-9b": "glm4_9b",
    "minitron-8b": "minitron_8b",
    "minicpm-2b": "minicpm_2b",
    "mamba2-370m": "mamba2_370m",
    "whisper-small": "whisper_small",
    "hymba-1.5b": "hymba_1_5b",
    "chameleon-34b": "chameleon_34b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "all_configs", "reduced", "shape_applicable"]
