"""AdamW with global-norm clipping — sharding-transparent (moments inherit
the parameter partition specs) and fully functional."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params], tuple[Params, Any]]  # (g, state, p)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), n


def adamw(lr: float | Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          grad_clip: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        step = state["step"] + 1
        stepf = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        c1 = 1.0 - jnp.power(b1, stepf)
        c2 = 1.0 - jnp.power(b2, stepf)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mh = m / c1
            vh = v / c2
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr_t * step_).astype(p.dtype), m, v

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state["mu"])
        flat_v = tdef.flatten_up_to(state["nu"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        new_state = {
            "mu": tdef.unflatten([o[1] for o in out]),
            "nu": tdef.unflatten([o[2] for o in out]),
            "step": step,
        }
        return updates, new_state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)
