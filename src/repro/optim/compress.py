"""Gradient compression (int8 + error feedback) — a distributed-optimization
building block for bandwidth-constrained cross-pod gradient sync.

``compress``/``decompress`` quantize per-leaf to int8 with a per-leaf scale;
``ef_step`` wraps a gradient tree with error feedback (residual carried in
the optimizer-adjacent state) so the quantization error is re-injected on
the next step — the standard convergence-preserving trick (1-bit Adam /
EF-SGD lineage). On a real multi-pod run this halves-to-quarters the
inter-pod reduce bytes; on CPU we validate numerics + convergence only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def ef_step(grads, ef_state):
    """Returns (decompressed grads actually applied, new ef_state)."""

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_leaf(corrected)
        dq = decompress_leaf(q, s)
        return dq.astype(g.dtype), corrected - dq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
