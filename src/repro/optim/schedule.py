"""LR schedules: linear-warmup cosine and WSD (warmup-stable-decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def wsd(peak_lr: float, warmup_steps: int, stable_steps: int,
        decay_steps: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): linear warmup,
    long constant plateau, short exponential-ish decay tail."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps - stable_steps) / jnp.maximum(decay_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        decay = peak_lr * jnp.power(final_frac, t)
        return jnp.where(step < warmup_steps, warm,
                         jnp.where(step < warmup_steps + stable_steps,
                                   peak_lr, decay))
    return lr
