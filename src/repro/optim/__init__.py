"""Optimizers, schedules, gradient compression."""
from . import compress, schedule
from .adamw import Optimizer, adamw, apply_updates, clip_by_global_norm, global_norm
from .schedule import warmup_cosine, wsd

__all__ = ["Optimizer", "adamw", "apply_updates", "clip_by_global_norm",
           "global_norm", "warmup_cosine", "wsd", "schedule", "compress"]
