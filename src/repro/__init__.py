"""repro: Taskgraph — a low-contention tasking framework for JAX/TPU.

Reproduction + production framework for Yu, Royuela & Quiñones,
"Taskgraph: A Low Contention OpenMP Tasking Framework" (2022), adapted to
the TPU/JAX execution model. See DESIGN.md.
"""

__version__ = "0.1.0"
