"""Task Dependency Graph (TDG) — the paper's core data structure.

A TDG is a DAG whose nodes are *task instances* (pure JAX callables bound to
named buffer slots) and whose edges are data dependencies among them,
materialized once (at record/static-build time) from OpenMP-style
``depend(in/out/inout)`` clauses via a last-writer/readers table — the
JAX analogue of the runtime dependency-tracking hash table that vanilla
OpenMP consults on *every* task creation (and that this framework consults
exactly once per region).

Edges are RAW (read-after-write), WAR (write-after-read) and WAW
(write-after-write), matching OpenMP 5.x depend-clause semantics.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterable, Mapping, Sequence


class DepKind(enum.Enum):
    IN = "in"
    OUT = "out"
    INOUT = "inout"


class EdgeKind(enum.Enum):
    RAW = "raw"  # true (flow) dependence
    WAR = "war"  # anti dependence
    WAW = "waw"  # output dependence


@dataclasses.dataclass(frozen=True)
class Edge:
    src: int
    dst: int
    kind: EdgeKind
    slot: str


@dataclasses.dataclass
class Task:
    """One task instance.

    ``fn`` is a pure function taking the values of ``ins`` (in order) and
    returning the values of ``outs`` (a single value if ``len(outs) == 1``,
    else a tuple in order). Constants ("known data", paper Fig. 4d) are
    simply closed over in ``fn``.
    """

    tid: int
    fn: Callable[..., Any]
    ins: tuple[str, ...]
    outs: tuple[str, ...]
    name: str = ""
    cost_hint: float = 1.0
    metadata: dict = dataclasses.field(default_factory=dict)

    def label(self) -> str:
        return self.name or getattr(self.fn, "__name__", f"task{self.tid}")


class DependencyTable:
    """Last-writer/readers table — the record-time 'dependency hash table'.

    The vanilla runtime pays an exclusive-access lookup here per depend
    clause on every execution; the Taskgraph framework pays it once, while
    recording, and never again (paper §4.3.2: entries are never freed so
    edges to already-finished tasks can still be established).
    """

    def __init__(self) -> None:
        self._last_writer: dict[str, int] = {}
        self._readers: dict[str, list[int]] = {}
        self.lookups = 0  # instrumentation: how many clause resolutions

    def resolve(self, tid: int, ins: Sequence[str], outs: Sequence[str]) -> list[Edge]:
        edges: list[Edge] = []
        seen: set[tuple[int, int]] = set()

        def _add(src: int, kind: EdgeKind, slot: str) -> None:
            if src == tid:
                return
            key = (src, tid)
            if key in seen:
                return
            seen.add(key)
            edges.append(Edge(src, tid, kind, slot))

        for slot in ins:
            self.lookups += 1
            w = self._last_writer.get(slot)
            if w is not None:
                _add(w, EdgeKind.RAW, slot)
            self._readers.setdefault(slot, []).append(tid)
        for slot in outs:
            self.lookups += 1
            w = self._last_writer.get(slot)
            if w is not None:
                _add(w, EdgeKind.WAW, slot)
            for r in self._readers.get(slot, ()):  # anti deps
                _add(r, EdgeKind.WAR, slot)
            self._last_writer[slot] = tid
            self._readers[slot] = []
        return edges


class TDG:
    """The task dependency graph for one region instance."""

    def __init__(self, region: str = "<anonymous>") -> None:
        self.region = region
        self.tasks: list[Task] = []
        self.edges: list[Edge] = []
        self.preds: dict[int, set[int]] = {}
        self.succs: dict[int, set[int]] = {}
        self._dep_table = DependencyTable()
        # slots read before ever written inside the region = region inputs;
        # slots written = region outputs (its externally visible effect).
        self._written: set[str] = set()
        self.input_slots: list[str] = []
        self.output_slots: list[str] = []

    # -- construction -----------------------------------------------------
    def add_task(
        self,
        fn: Callable[..., Any],
        ins: Sequence[str] = (),
        outs: Sequence[str] = (),
        inouts: Sequence[str] = (),
        name: str = "",
        cost_hint: float = 1.0,
        **metadata: Any,
    ) -> Task:
        ins = tuple(ins) + tuple(inouts)
        outs = tuple(outs) + tuple(inouts)
        tid = len(self.tasks)
        task = Task(tid, fn, tuple(ins), tuple(outs), name=name,
                    cost_hint=cost_hint, metadata=dict(metadata))
        self.tasks.append(task)
        self.preds[tid] = set()
        self.succs[tid] = set()
        for slot in ins:
            if slot not in self._written and slot not in self.input_slots:
                self.input_slots.append(slot)
        for slot in outs:
            self._written.add(slot)
            if slot not in self.output_slots:
                self.output_slots.append(slot)
        for e in self._dep_table.resolve(tid, task.ins, task.outs):
            self.edges.append(e)
            self.preds[tid].add(e.src)
            self.succs[e.src].add(tid)
        return task

    # -- queries -----------------------------------------------------------
    @property
    def num_tasks(self) -> int:
        return len(self.tasks)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def roots(self) -> list[int]:
        """Tasks without input dependencies (paper §4.3.1)."""
        return [t.tid for t in self.tasks if not self.preds[t.tid]]

    def leaves(self) -> list[int]:
        return [t.tid for t in self.tasks if not self.succs[t.tid]]

    def is_acyclic(self) -> bool:
        # By construction every edge goes from a lower tid to a higher tid
        # (record order), so the graph is acyclic; verify anyway.
        return all(e.src < e.dst for e in self.edges)

    def validate(self) -> None:
        if not self.is_acyclic():
            raise ValueError(f"TDG {self.region!r} has a cycle")
        for e in self.edges:
            if not (0 <= e.src < self.num_tasks and 0 <= e.dst < self.num_tasks):
                raise ValueError(f"dangling edge {e}")

    def dep_lookups(self) -> int:
        return self._dep_table.lookups

    # -- pretty -------------------------------------------------------------
    def summary(self) -> str:
        kinds: dict[EdgeKind, int] = {}
        for e in self.edges:
            kinds[e.kind] = kinds.get(e.kind, 0) + 1
        kind_s = ", ".join(f"{k.value}={v}" for k, v in sorted(kinds.items(), key=lambda kv: kv[0].value))
        return (f"TDG({self.region!r}: {self.num_tasks} tasks, {self.num_edges} edges"
                f"{' [' + kind_s + ']' if kind_s else ''}, {len(self.roots())} roots)")

    def to_dot(self) -> str:
        lines = [f'digraph "{self.region}" {{']
        for t in self.tasks:
            lines.append(f'  t{t.tid} [label="{t.label()}"];')
        for e in self.edges:
            style = {"raw": "solid", "war": "dashed", "waw": "dotted"}[e.kind.value]
            lines.append(f'  t{e.src} -> t{e.dst} [style={style}, label="{e.slot}"];')
        lines.append("}")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return self.summary()


def chain_series(tdg: TDG, fns: Iterable[Callable], slot: str = "x") -> None:
    """Helper: a linear chain of tasks over one slot (paper Listing 1 column)."""
    for i, fn in enumerate(fns):
        tdg.add_task(fn, inouts=[slot], name=f"{slot}.{i}")


def abstract_leaf(v: Any):
    """One value leaf -> ``jax.ShapeDtypeStruct`` (no data touched).

    The single source of truth for value abstraction, shared by
    ``record._abstractify``, ``fuse`` and the AOT path in ``lower``.
    """
    import jax

    if isinstance(v, jax.ShapeDtypeStruct):
        return v
    if hasattr(v, "shape") and hasattr(v, "dtype"):
        return jax.ShapeDtypeStruct(v.shape, v.dtype)
    import numpy as np

    arr = np.asarray(v)
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def structure_signature(tdg: TDG, outputs: Sequence[str] | None = None
                        ) -> tuple[tuple, dict[str, str], tuple]:
    """Canonical structural signature of a TDG, for executable interning.

    Two TDGs with the same signature AND the same payload functions compute
    the same program modulo slot *names*: slots are renamed ``s0, s1, ...``
    by first appearance (scanning tasks in tid order, ins before outs) and
    payloads are numbered by first appearance, so structurally identical
    regions built at different source locations — or two instances of one
    region — canonicalize to one key.

    Returns ``(sig, slot_map, payloads)`` where ``sig`` is a hashable
    structure key (tasks, edges, canonical output order), ``slot_map`` maps
    actual slot name -> canonical name, and ``payloads`` is the tuple of
    distinct payload functions in first-appearance order. ``sig`` carries
    payload *indices* only; an interning cache must additionally key on the
    identities in ``payloads`` (and keep them alive) because two graphs of
    identical shape over different payloads are different programs.
    """
    slot_map: dict[str, str] = {}
    payload_index: dict[int, int] = {}
    payloads: list[Callable] = []

    def canon(slot: str) -> str:
        if slot not in slot_map:
            slot_map[slot] = f"s{len(slot_map)}"
        return slot_map[slot]

    task_rows = []
    for t in tdg.tasks:
        fid = id(t.fn)
        if fid not in payload_index:
            payload_index[fid] = len(payloads)
            payloads.append(t.fn)
        task_rows.append((payload_index[fid],
                          tuple(canon(s) for s in t.ins),
                          tuple(canon(s) for s in t.outs)))
    edge_rows = tuple(sorted(
        (e.src, e.dst, e.kind.value, slot_map[e.slot]) for e in tdg.edges))
    out_slots = list(outputs) if outputs is not None else list(tdg.output_slots)
    sig = ("tdg-structure-v1", len(tdg.tasks), tuple(task_rows), edge_rows,
           tuple(canon(s) for s in out_slots))
    return sig, slot_map, tuple(payloads)


def buffers_signature(buffers: Mapping[str, Any]) -> tuple:
    """Abstract signature of a buffer dict (for replay-cache keying)."""
    import jax

    sig = []
    for k in sorted(buffers):
        leaves, treedef = jax.tree_util.tree_flatten(buffers[k])
        sig.append((k, treedef, tuple((getattr(l, "shape", ()), str(getattr(l, "dtype", type(l)))) for l in leaves)))
    return tuple(sig)
