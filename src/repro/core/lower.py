"""Lower a TDG to a single fused JAX executable (the replay path).

The vanilla runtime walks the graph dynamically: per task it pays creation,
dependency resolution, queue locking and dispatch. Replay instead emits the
whole region as ONE pure function in a precomputed topological order and
compiles it once; XLA then owns instruction scheduling, buffer reuse
(donation) and overlap. This is the TPU-native equivalent of the paper's
"execute_TDG": zero per-task orchestration at run time.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Mapping, Sequence

import jax

from . import schedule as _schedule
from .tdg import TDG


def tdg_as_function(tdg: TDG, order: Sequence[int] | None = None,
                    outputs: Sequence[str] | None = None) -> Callable[[dict], dict]:
    """Return ``f(buffers) -> {slot: value}`` executing the TDG in ``order``.

    The returned function is pure and traceable: it can be jitted, vmapped,
    differentiated, pjit-sharded, or embedded as a task of an outer TDG
    (regions compose; the paper forbids *recursive* taskgraph directives and
    so do we — an inner region is inlined, not dynamically nested).
    """
    order = list(order) if order is not None else _schedule.topo_order(tdg)
    outputs = list(outputs) if outputs is not None else list(tdg.output_slots)
    pos = {tid: i for i, tid in enumerate(order)}
    if not _schedule.validate_execution_order(tdg, order):
        raise ValueError(f"order does not respect TDG edges for {tdg.region!r}")

    def run(buffers: Mapping[str, Any]) -> dict:
        env = dict(buffers)
        for tid in order:
            t = tdg.tasks[tid]
            try:
                args = [env[s] for s in t.ins]
            except KeyError as e:  # pragma: no cover - defensive
                raise KeyError(f"task {t.label()} reads unbound slot {e} "
                               f"(region inputs: {tdg.input_slots})") from None
            out = t.fn(*args)
            if len(t.outs) == 1:
                env[t.outs[0]] = out
            elif len(t.outs) > 1:
                if not isinstance(out, (tuple, list)) or len(out) != len(t.outs):
                    raise ValueError(
                        f"task {t.label()} declared {len(t.outs)} outputs, "
                        f"returned {type(out).__name__}")
                for s, v in zip(t.outs, out):
                    env[s] = v
        return {s: env[s] for s in outputs}

    run.__name__ = f"tdg_{tdg.region}"
    return run


def lower_tdg(
    tdg: TDG,
    order: Sequence[int] | None = None,
    outputs: Sequence[str] | None = None,
    donate_slots: Sequence[str] = (),
    jit: bool = True,
) -> Callable[[dict], dict]:
    """Lower + (optionally) jit the TDG.

    ``donate_slots`` are buffer slots whose input storage may be reused for
    outputs (e.g. optimizer state, KV caches): the paper's "no allocation
    during TDG execution" maps to XLA buffer donation.
    """
    fn = tdg_as_function(tdg, order=order, outputs=outputs)
    donate_slots = tuple(donate_slots)
    if not jit:
        return fn
    if not donate_slots:
        return jax.jit(fn)

    def split_fn(donated: dict, kept: dict) -> dict:
        return fn({**kept, **donated})

    jitted = jax.jit(split_fn, donate_argnums=0)

    @functools.wraps(fn)
    def wrapper(buffers: Mapping[str, Any]) -> dict:
        donated = {k: buffers[k] for k in donate_slots if k in buffers}
        kept = {k: v for k, v in buffers.items() if k not in donated}
        return jitted(donated, kept)

    return wrapper
