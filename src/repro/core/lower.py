"""Lower a TDG to a single fused JAX executable (the replay path).

The vanilla runtime walks the graph dynamically: per task it pays creation,
dependency resolution, queue locking and dispatch. Replay instead emits the
whole region as ONE pure function and compiles it once; XLA then owns
instruction scheduling, buffer reuse (donation) and overlap. This is the
TPU-native equivalent of the paper's "execute_TDG": zero per-task
orchestration at run time.

Three layers keep that compile-once story cheap at scale, and are the
extension points for future GPU/multi-host PRs:

* **Wave fusion** (``fuse.fused_tdg_as_function``, default on): each topo
  wave's isomorphic tasks lower as one ``vmap``-batched call, so the traced
  program is O(wave-classes), not O(tasks). ``fuse=False`` (or the
  ``REPRO_FUSE=0`` env var) restores the fully unrolled form; an explicit
  ``order`` implies unrolled, since fusion fixes wave order.
* **Structural interning** (``intern=True`` default under ``jit``): lowered
  executables are cached globally by the TDG's canonical structure
  (``tdg.structure_signature``) + payload identities + kernel substrate, so
  two regions — or two instances, or a region and a ``ReplayExecutor`` —
  with identical structure share ONE jitted callable (and therefore one
  XLA compilation per shape signature), instead of recompiling per source
  location. ``intern_stats()`` exposes the hit/miss counters.
* **AOT compilation** (:func:`aot_compile_tdg`): eagerly ``lower().compile()``
  for concrete buffer shapes, capturing XLA cost analysis and trace/compile
  wall times. The result is serializable via ``serialize.save_executable``
  so a TDG recorded in one process replays in another without retracing.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
import threading
import time
from typing import Any, Callable, Mapping, Sequence

import jax

from . import costmodel as _costmodel
from . import fuse as _fuse
from . import schedule as _schedule
# Canonical definition lives in costmodel (the consumer of the numbers);
# re-exported here because this module captures it on every AotExecutable
# and tests/serialize reach it as lower._capture_cost_analysis.
from .costmodel import capture_cost_analysis as _capture_cost_analysis
from .tdg import TDG, structure_signature
from ..sharding import replay as _shreplay

_FUSE_ENV = "REPRO_FUSE"


def tdg_as_function(tdg: TDG, order: Sequence[int] | None = None,
                    outputs: Sequence[str] | None = None) -> Callable[[dict], dict]:
    """Return ``f(buffers) -> {slot: value}`` executing the TDG in ``order``.

    The returned function is pure and traceable: it can be jitted, vmapped,
    differentiated, pjit-sharded, or embedded as a task of an outer TDG
    (regions compose; the paper forbids *recursive* taskgraph directives and
    so do we — an inner region is inlined, not dynamically nested). This is
    the fully *unrolled* form — one emitted call per task; see
    ``fuse.fused_tdg_as_function`` for the wave-batched form.
    """
    order = list(order) if order is not None else _schedule.topo_order(tdg)
    outputs = list(outputs) if outputs is not None else list(tdg.output_slots)
    if not _schedule.validate_execution_order(tdg, order):
        raise ValueError(f"order does not respect TDG edges for {tdg.region!r}")

    def run(buffers: Mapping[str, Any]) -> dict:
        env = dict(buffers)
        _fuse._run_unrolled(tdg, order, env)
        return {s: env[s] for s in outputs}

    run.__name__ = f"tdg_{tdg.region}"
    return run


def fuse_enabled(fuse: bool | str = "auto") -> bool:
    """Resolve a ``fuse`` argument (True | False | "auto") to a decision.

    "auto" honours the ``REPRO_FUSE`` env var (0/false/off disables) and
    otherwise fuses: classification happens per trace anyway, and
    heterogeneous graphs degrade to the unrolled form class by class.
    """
    if fuse is True or fuse is False:
        return fuse
    if fuse != "auto":
        raise ValueError(f"fuse must be True, False or 'auto', got {fuse!r}")
    env = os.environ.get(_FUSE_ENV)
    if env is not None:
        return env.strip().lower() not in ("0", "false", "off", "no")
    return True


def _base_function(tdg: TDG, outputs, fuse: bool, min_class_size: int,
                   batcher: str, mesh=None) -> Callable[[dict], dict]:
    if fuse:
        return _fuse.fused_tdg_as_function(tdg, outputs=outputs,
                                           min_class_size=min_class_size,
                                           batcher=batcher, mesh=mesh)
    # The unrolled form has no stacked batch axis to shard: mesh is a
    # fused-path feature, and unrolled lowering is the single-device
    # fallback by construction.
    return tdg_as_function(tdg, outputs=outputs)


def _jit_with_donation(fn: Callable[[dict], dict],
                       donate_slots: tuple[str, ...]) -> Callable[[dict], dict]:
    """jit ``fn`` donating the buffers named in ``donate_slots``.

    ``donate_slots`` are buffer slots whose input storage may be reused for
    outputs (e.g. optimizer state, KV caches): the paper's "no allocation
    during TDG execution" maps to XLA buffer donation.
    """
    if not donate_slots:
        return jax.jit(fn)

    def split_fn(donated: dict, kept: dict) -> dict:
        return fn({**kept, **donated})

    jitted = jax.jit(split_fn, donate_argnums=0)

    @functools.wraps(fn)
    def wrapper(buffers: Mapping[str, Any]) -> dict:
        donated = {k: buffers[k] for k in donate_slots if k in buffers}
        kept = {k: v for k, v in buffers.items() if k not in donated}
        return jitted(donated, kept)

    return wrapper


# ------------------------------------------------------------- interning

@dataclasses.dataclass
class _InternEntry:
    payloads: tuple            # strong refs: pins the id()s the key relies on
    fn: Callable[[dict], dict]  # jitted, canonical slot names


_intern_lock = threading.Lock()
# LRU-bounded: entries pin their payload closures (that's what makes id()
# keys sound), so an unbounded cache would leak in processes that rebuild
# TDGs with fresh closures forever. Oldest-used entries are evicted at the
# cap; jax.jit keeps its own per-callable compilation cache alive only as
# long as the entry does.
_INTERN_CAP = max(int(os.environ.get("REPRO_INTERN_CACHE_SIZE", "256")), 1)
_intern_cache: collections.OrderedDict[tuple, _InternEntry] = \
    collections.OrderedDict()
_intern_counters = {"hits": 0, "misses": 0, "evictions": 0}


def intern_stats() -> dict:
    """Hit/miss counters + size of the global structural executable cache."""
    with _intern_lock:
        return {**_intern_counters, "entries": len(_intern_cache)}


def clear_intern_cache() -> None:
    with _intern_lock:
        _intern_cache.clear()
        for k in _intern_counters:
            _intern_counters[k] = 0


def _kernel_registry():
    from ..kernels import registry as _kreg

    return _kreg


def _interned_lower(tdg: TDG, outputs, donate_slots: tuple[str, ...],
                    fuse: bool, min_class_size: int,
                    batcher: str, mesh=None) -> Callable[[dict], dict]:
    sig, slot_map, payloads = structure_signature(tdg, outputs)
    canon_donate = tuple(sorted(
        slot_map[s] for s in donate_slots if s in slot_map))
    # The kernel substrate is baked into the trace, so it must key the cache:
    # two executors pinned to different substrates over one structure must
    # not share an executable. The keyed mode is re-entered around every
    # call of the shared executable (jit traces lazily at first call), so a
    # caller invoking the lowered fn under a *different* ambient mode cannot
    # poison the cache with a wrong-substrate trace. The mesh fingerprint
    # keys the cache for the same reason: sharding constraints are baked
    # into the trace, so a 1-device and an N-device lowering of one
    # structure must never share an executable.
    # The batcher component is the *plan* key, not the raw argument:
    # "vmap"/"map" literals for pinned plans, "auto/<thresholds>" for the
    # adaptive policy (costmodel.plan_key). Two lowerings of one structure
    # under different plans bake different dispatch into the trace and must
    # never share an executable; under REPRO_ADAPTIVE=0, "auto" resolves to
    # "vmap" and deliberately SHARES the static entry — the kill switch
    # restores pre-adaptive behaviour including its cache hits.
    kreg = _kernel_registry()
    mode = kreg.resolved_mode()
    key = (sig, tuple(id(p) for p in payloads), canon_donate, fuse,
           min_class_size, _costmodel.plan_key(batcher), mode,
           _shreplay.mesh_fingerprint(mesh))

    with _intern_lock:
        entry = _intern_cache.get(key)
        if entry is not None:
            _intern_counters["hits"] += 1
            _intern_cache.move_to_end(key)
        else:
            _intern_counters["misses"] += 1
    if entry is None:
        actual_outputs = (list(outputs) if outputs is not None
                          else list(tdg.output_slots))
        base = _base_function(tdg, actual_outputs, fuse, min_class_size,
                              batcher, mesh=mesh)
        from_canon = {c: a for a, c in slot_map.items()}

        def canon_run(cbuffers: dict) -> dict:
            out = base({from_canon[c]: v for c, v in cbuffers.items()})
            return {slot_map[s]: v for s, v in out.items()}

        canon_run.__name__ = f"tdg_interned_{tdg.region}"
        entry = _InternEntry(payloads, _jit_with_donation(canon_run,
                                                          canon_donate))
        with _intern_lock:
            entry = _intern_cache.setdefault(key, entry)
            _intern_cache.move_to_end(key)
            while len(_intern_cache) > _INTERN_CAP:
                _intern_cache.popitem(last=False)
                _intern_counters["evictions"] += 1

    to_canon = dict(slot_map)
    from_canon = {c: a for a, c in slot_map.items()}
    shared = entry.fn

    def run(buffers: Mapping[str, Any]) -> dict:
        # Slots unknown to the structure (extra keys) are dropped — they
        # cannot influence the program.
        with kreg.kernel_mode_scope(mode):
            out = shared({to_canon[k]: v for k, v in buffers.items()
                          if k in to_canon})
        return {from_canon[c]: v for c, v in out.items()}

    run.__name__ = f"tdg_{tdg.region}"
    return run


# -------------------------------------------------------------- entry point

def lower_tdg(
    tdg: TDG,
    order: Sequence[int] | None = None,
    outputs: Sequence[str] | None = None,
    donate_slots: Sequence[str] = (),
    jit: bool = True,
    fuse: bool | str = "auto",
    intern: bool | str = "auto",
    min_class_size: int = 2,
    batcher: str = "auto",
    mesh: Any = "auto",
) -> Callable[[dict], dict]:
    """Lower + (optionally) jit the TDG.

    ``fuse`` selects wave-fused lowering (see module docstring); an explicit
    ``order`` forces the unrolled form. ``intern="auto"`` shares the jitted
    executable globally across structurally identical TDGs whenever
    ``jit=True`` and no custom ``order`` is given; an explicit
    ``intern=True`` raises if those preconditions don't hold rather than
    silently skipping the cache.

    ``batcher`` picks how each fused wave class dispatches: ``"vmap"`` /
    ``"map"`` pin one batcher for every class (the pre-cost-model
    behaviour), ``"auto"`` (default) selects per class from probe-measured
    flops/bytes — see ``core.costmodel``; ``REPRO_ADAPTIVE=0`` collapses
    ``"auto"`` back to ``"vmap"``. The resolved *plan* (not the raw
    argument) keys the intern cache so different plans never collide.

    ``mesh`` shards every fused class's stacked batch axis across devices:
    a concrete ``jax.sharding.Mesh``, ``None`` (single-device), or
    ``"auto"`` (honour an ambient ``sharding.partition.use_mesh`` scope,
    then the ``REPRO_MESH`` env knob — see ``sharding.replay.resolve_mesh``).
    The resolved mesh's fingerprint keys the intern cache, so 1-device and
    N-device executables of one structure never collide.
    """
    donate_slots = tuple(donate_slots)
    do_fuse = fuse_enabled(fuse) and order is None
    mesh = _shreplay.resolve_mesh(mesh) if do_fuse else None
    if intern == "auto":
        intern = jit and order is None
    elif intern and (not jit or order is not None):
        raise ValueError("intern=True requires jit=True and order=None "
                         "(interned executables are jitted and wave-ordered)")
    if intern and jit and order is None:
        return _interned_lower(tdg, outputs, donate_slots, do_fuse,
                               min_class_size, batcher, mesh=mesh)
    fn = _base_function(tdg, outputs, do_fuse, min_class_size, batcher,
                        mesh=mesh) \
        if order is None else tdg_as_function(tdg, order=order, outputs=outputs)
    if not jit:
        return fn
    return _jit_with_donation(fn, donate_slots)


# ------------------------------------------------------------------ AOT path

@dataclasses.dataclass
class AotExecutable:
    """An ahead-of-time compiled replay executable for fixed buffer shapes.

    ``compiled`` is the underlying ``jax.stages.Compiled`` (or loaded
    deserialized executable); calling the object runs it on a buffer dict
    (extra keys are dropped). ``cost_analysis`` is XLA's flops/bytes
    estimate captured at compile time, when available.
    """

    compiled: Any
    input_specs: dict
    fused: bool
    donate_slots: tuple[str, ...] = ()
    cost_analysis: dict | None = None
    trace_seconds: float = 0.0
    compile_seconds: float = 0.0
    #: ``sharding.replay.mesh_fingerprint`` of the mesh this executable was
    #: compiled under (``None`` = single-device). Rides the artifact's
    #: topology fingerprint so an 8-device binary is rejected loudly on a
    #: worker whose replay mesh differs.
    mesh_fp: str | None = None

    @property
    def flops(self) -> float | None:
        return (self.cost_analysis or {}).get("flops")

    @property
    def bytes_accessed(self) -> float | None:
        return (self.cost_analysis or {}).get("bytes accessed")

    def __call__(self, buffers: Mapping[str, Any]) -> dict:
        args = {k: buffers[k] for k in self.input_specs}
        if self.donate_slots:
            donated = {k: args.pop(k) for k in self.donate_slots if k in args}
            return self.compiled(donated, args)
        return self.compiled(args)


def aot_compile_tdg(
    tdg: TDG,
    buffers: Mapping[str, Any],
    outputs: Sequence[str] | None = None,
    donate_slots: Sequence[str] = (),
    fuse: bool | str = "auto",
    min_class_size: int = 2,
    batcher: str = "auto",
    mesh: Any = "auto",
) -> AotExecutable:
    """Eagerly trace + compile the replay executable for ``buffers``' shapes.

    ``buffers`` may hold concrete arrays or ``ShapeDtypeStruct`` trees — no
    data is touched. Unlike the lazy ``jax.jit`` path, compilation happens
    here and now, so a warmup step (or another process, via
    ``serialize.save_executable``) can pay it off the critical path; XLA's
    cost analysis and the trace/compile wall times are captured on the
    result for benchmark and placement decisions. ``donate_slots`` buffers
    are donated exactly as in the lazy path.
    """
    from .tdg import abstract_leaf

    do_fuse = fuse_enabled(fuse)
    mesh = _shreplay.resolve_mesh(mesh) if do_fuse else None
    fn = _base_function(tdg, outputs, do_fuse, min_class_size, batcher,
                        mesh=mesh)
    specs = {k: jax.tree_util.tree_map(abstract_leaf, v)
             for k, v in buffers.items()}
    donate_slots = tuple(k for k in donate_slots if k in specs)
    t0 = time.perf_counter()
    if donate_slots:
        def split_fn(donated: dict, kept: dict) -> dict:
            return fn({**kept, **donated})

        donated_specs = {k: specs[k] for k in donate_slots}
        kept_specs = {k: v for k, v in specs.items() if k not in donated_specs}
        lowered = jax.jit(split_fn, donate_argnums=0).lower(donated_specs,
                                                            kept_specs)
    else:
        lowered = jax.jit(fn).lower(specs)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return AotExecutable(compiled=compiled, input_specs=specs, fused=do_fuse,
                         donate_slots=donate_slots,
                         cost_analysis=_capture_cost_analysis(compiled),
                         trace_seconds=t1 - t0, compile_seconds=t2 - t1,
                         mesh_fp=_shreplay.mesh_fingerprint(mesh))
