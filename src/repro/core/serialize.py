"""TDG serialization — the compiler→runtime handoff artifact.

In the paper, the compile-time path EMITS a TDG that the runtime later
loads and executes (Fig. 3: "reading the TDG built by the compiler").
Here the equivalent artifact is a JSON description of the graph —
tasks (by *registered payload name*), depend clauses, edges, slots,
metadata — that can be saved at record time and loaded in a different
process, re-binding payloads through a task-function registry.

Payload code itself is not serialized (same as the paper: the TDG file
references outlined functions by symbol); the registry plays the linker.
Round-tripping preserves the graph exactly (same edges, same schedule, and
a rebuilt dependency table so ``add_task`` after a load keeps resolving
correctly), which the tests assert via topo-wave equality and replay
equivalence.

Beyond the graph, the opt-in **warmup artifact** persists the *compiled*
replay executable: :func:`save_executable` pickles the XLA binary produced
by ``lower.aot_compile_tdg`` (via ``jax.experimental.serialize_executable``
when available — see :func:`executable_serialization_available`), and
:func:`warmup_and_save` writes it as a ``<path>.aot`` sidecar next to the
TDG JSON, so a TDG recorded in one process replays in another without
retracing or recompiling anything.
"""
from __future__ import annotations

import json
import pickle
from typing import Any, Callable

import jax

from .tdg import TDG, Edge, EdgeKind


class TaskFnRegistry:
    """Name -> payload function registry (the 'symbol table')."""

    def __init__(self) -> None:
        self._fns: dict[str, Callable] = {}

    def register(self, name: str | None = None):
        def deco(fn: Callable) -> Callable:
            key = name or fn.__name__
            if key in self._fns and self._fns[key] is not fn:
                raise ValueError(f"payload {key!r} already registered")
            self._fns[key] = fn
            fn.__taskfn_name__ = key
            return fn
        return deco

    def get(self, name: str) -> Callable:
        if name not in self._fns:
            raise KeyError(f"unknown task payload {name!r}; "
                           f"registered: {sorted(self._fns)}")
        return self._fns[name]

    def name_of(self, fn: Callable) -> str:
        key = getattr(fn, "__taskfn_name__", None)
        if key is None:
            raise ValueError(
                f"payload {fn!r} is not registered (decorate with "
                "@registry.register()) — cannot serialize this TDG")
        return key


def tdg_to_dict(tdg: TDG, registry: TaskFnRegistry) -> dict:
    return {
        "version": 1,
        "region": tdg.region,
        "tasks": [
            {"tid": t.tid, "fn": registry.name_of(t.fn),
             "ins": list(t.ins), "outs": list(t.outs), "name": t.name,
             "cost_hint": t.cost_hint, "metadata": t.metadata}
            for t in tdg.tasks
        ],
        "edges": [
            {"src": e.src, "dst": e.dst, "kind": e.kind.value, "slot": e.slot}
            for e in tdg.edges
        ],
        "input_slots": list(tdg.input_slots),
        "output_slots": list(tdg.output_slots),
    }


def tdg_from_dict(data: dict, registry: TaskFnRegistry) -> TDG:
    if data.get("version") != 1:
        raise ValueError(f"unsupported TDG version {data.get('version')}")
    tdg = TDG(region=data["region"])
    # rebuild tasks WITHOUT re-resolving deps (edges are authoritative)
    from .tdg import Task

    for td in data["tasks"]:
        t = Task(td["tid"], registry.get(td["fn"]), tuple(td["ins"]),
                 tuple(td["outs"]), name=td["name"],
                 cost_hint=td["cost_hint"], metadata=dict(td["metadata"]))
        tdg.tasks.append(t)
        tdg.preds[t.tid] = set()
        tdg.succs[t.tid] = set()
    for ed in data["edges"]:
        e = Edge(ed["src"], ed["dst"], EdgeKind(ed["kind"]), ed["slot"])
        tdg.edges.append(e)
        tdg.preds[e.dst].add(e.src)
        tdg.succs[e.src].add(e.dst)
    tdg.input_slots = list(data["input_slots"])
    tdg.output_slots = list(data["output_slots"])
    tdg._written = set(tdg.output_slots)
    # Rebuild the last-writer/readers table by replaying the depend clauses
    # (resolution is deterministic, so this reproduces the record-time table
    # exactly); without it, add_task on a loaded TDG would silently
    # mis-resolve every dependency against an empty table.
    for t in tdg.tasks:
        tdg._dep_table.resolve(t.tid, t.ins, t.outs)
    tdg._dep_table.lookups = 0  # instrumentation counts post-load use only
    tdg.validate()
    return tdg


def save_tdg(tdg: TDG, path, registry: TaskFnRegistry) -> None:
    with open(path, "w") as f:
        json.dump(tdg_to_dict(tdg, registry), f, indent=1)


def load_tdg(path, registry: TaskFnRegistry) -> TDG:
    with open(path) as f:
        return tdg_from_dict(json.load(f), registry)


# ---------------------------------------------------------------------------
# AOT executable persistence (opt-in warmup artifact)
# ---------------------------------------------------------------------------

class TopologyMismatch(RuntimeError):
    """The artifact was compiled for a different device topology.

    Raised by :func:`executable_from_bytes` BEFORE any XLA deserialization
    is attempted, so a cross-platform artifact (e.g. a TPU binary shipped
    to a CPU worker) fails with a clear, catchable error instead of
    whatever the runtime's deserializer throws — callers (the cluster
    tier's register path, ``load_warm``) count it and fall back to
    re-lowering.
    """


def topology_fingerprint(mesh: Any = "auto") -> dict:
    """The device-topology identity a compiled executable is bound to.

    A serialized XLA binary only loads on a matching runtime; this is the
    cheap, comparable summary shipped inside every artifact
    (:func:`executable_to_bytes`) and checked at hydrate time: platform
    (cpu/gpu/tpu), device kind, visible device count, the jax version
    (serialized executables are not stable across jax releases), and the
    replay-mesh fingerprint — an executable compiled with its batch axis
    sharded over an 8-device mesh must not silently hydrate on a worker
    replaying single-device.

    ``mesh`` follows ``sharding.replay.resolve_mesh`` (``"auto"`` = the
    ambient/env mesh of THIS process); producers pass the fingerprint
    *string* the executable was actually compiled under
    (``AotExecutable.mesh_fp``), which is used verbatim. Every value is
    JSON-stable: the fingerprint crosses the cluster tier's JSON wire.
    """
    from ..sharding import replay as _shreplay

    if mesh is None or isinstance(mesh, str) and mesh != "auto":
        mesh_fp = mesh
    else:
        mesh_fp = _shreplay.mesh_fingerprint(_shreplay.resolve_mesh(mesh))
    devices = jax.devices()
    return {
        "platform": devices[0].platform,
        "device_kind": devices[0].device_kind,
        "device_count": len(devices),
        "jax": jax.__version__,
        "mesh": mesh_fp,
    }


def _serialize_executable_module():
    try:
        from jax.experimental import serialize_executable as se
        return se
    except ImportError:  # pragma: no cover - version-dependent
        return None


def executable_serialization_available() -> bool:
    """True iff this jax build can pickle compiled executables."""
    return _serialize_executable_module() is not None


def executable_to_bytes(aot) -> bytes:
    """Frame an ``lower.AotExecutable`` as self-contained artifact bytes.

    This is the in-band shipping format of the cluster tier (the frontend
    sends these bytes to a cold worker instead of making it re-lower) as
    well as the on-disk ``.aot`` sidecar payload. The compiled binary is
    device/topology-specific (same constraint as the paper's
    compiler-emitted TDG object code): hydrate it on a matching platform.
    """
    se = _serialize_executable_module()
    if se is None:
        raise RuntimeError(
            "this jax build lacks jax.experimental.serialize_executable; "
            "cannot persist compiled executables "
            "(check executable_serialization_available() first)")
    payload, in_tree, out_tree = se.serialize(aot.compiled)
    blob = {
        "version": 1,
        # The artifact's topology carries the mesh the executable was
        # COMPILED under (aot.mesh_fp), not this process's ambient mesh —
        # the two can differ (e.g. warming a single-device artifact from a
        # mesh-enabled frontend).
        "topology": topology_fingerprint(mesh=aot.mesh_fp),
        "payload": payload,
        "in_tree": in_tree,
        "out_tree": out_tree,
        "input_specs": {k: jax.tree_util.tree_map(
            lambda s: (tuple(s.shape), str(s.dtype)), v)
            for k, v in aot.input_specs.items()},
        "fused": aot.fused,
        "donate_slots": list(aot.donate_slots),
        "cost_analysis": aot.cost_analysis,
    }
    return pickle.dumps(blob)


def save_executable(aot, path) -> None:
    """Persist an ``lower.AotExecutable`` to ``path`` (:func:`executable_to_bytes`)."""
    data = executable_to_bytes(aot)
    with open(path, "wb") as f:
        f.write(data)


def executable_from_bytes(data: bytes, mesh: Any = "auto"):
    """Hydrate an ``lower.AotExecutable`` from :func:`executable_to_bytes` output.

    Returns an executable ready to call on a buffer dict with the shapes it
    was compiled for — no retracing, no recompilation. Raises on any
    corruption/version mismatch — and :class:`TopologyMismatch` when the
    embedded device-topology fingerprint disagrees with this process
    (checked BEFORE touching XLA's deserializer, so a cross-platform ship
    is a clean rejection, not a runtime crash). ``mesh`` declares the
    replay mesh THIS consumer will run the executable under (``"auto"`` =
    ambient/env; a ``RegionServer`` passes its own ``mesh_fp``): an
    artifact whose batch axis was sharded differently is a mismatch, not a
    silent wrong-topology hydrate. Soft-fallback policy belongs to the
    callers (``load_warm``, the serving tiers), which must *count* the
    failure rather than silently masquerading as warm.
    """
    se = _serialize_executable_module()
    if se is None:
        raise RuntimeError(
            "this jax build lacks jax.experimental.serialize_executable; "
            "cannot load compiled executables")
    from . import lower as _lower

    blob = pickle.loads(data)
    if blob.get("version") != 1:
        raise ValueError(f"unsupported executable version {blob.get('version')}")
    shipped = blob.get("topology")
    if shipped is not None:
        here = topology_fingerprint(mesh=mesh)
        if shipped != here:
            raise TopologyMismatch(
                f"artifact was compiled for {shipped} but this process runs "
                f"{here}; re-lower instead of hydrating")
    compiled = se.deserialize_and_load(blob["payload"], blob["in_tree"],
                                       blob["out_tree"])
    specs = {k: jax.tree_util.tree_map(
        lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]), v,
        is_leaf=lambda x: isinstance(x, (tuple, list)) and len(x) == 2
        and isinstance(x[1], str))
        for k, v in blob["input_specs"].items()}
    return _lower.AotExecutable(compiled=compiled, input_specs=specs,
                                fused=blob["fused"],
                                donate_slots=tuple(blob["donate_slots"]),
                                cost_analysis=blob["cost_analysis"],
                                mesh_fp=(shipped or {}).get("mesh"))


def load_executable(path, mesh: Any = "auto"):
    """Load a compiled replay executable saved by :func:`save_executable`."""
    with open(path, "rb") as f:
        data = f.read()
    return executable_from_bytes(data, mesh=mesh)


def warmup_and_save(tdg: TDG, buffers, path, registry: TaskFnRegistry,
                    fuse: bool | str = "auto", mesh: Any = "auto") -> dict:
    """Save the TDG JSON *and* AOT-compile + persist its replay executable.

    The graph goes to ``path`` (portable, payloads by symbol) and the
    compiled binary to ``path + ".aot"`` (platform-specific fast path).
    Returns an info dict with both paths, the captured cost analysis and
    trace/compile seconds. The consumer side is :func:`load_warm`.
    """
    from . import lower as _lower

    if not executable_serialization_available():
        # fail BEFORE writing anything or paying trace+compile, not after
        raise RuntimeError(
            "this jax build lacks jax.experimental.serialize_executable; "
            "use save_tdg() for the graph-only artifact")
    save_tdg(tdg, path, registry)
    aot = _lower.aot_compile_tdg(tdg, buffers, fuse=fuse, mesh=mesh)
    aot_path = str(path) + ".aot"
    save_executable(aot, aot_path)
    return {
        "tdg_path": str(path),
        "aot_path": aot_path,
        "fused": aot.fused,
        "cost_analysis": aot.cost_analysis,
        "trace_seconds": aot.trace_seconds,
        "compile_seconds": aot.compile_seconds,
    }


def load_warm(path, registry: TaskFnRegistry, mesh: Any = "auto"):
    """Load ``(tdg, aot_executable | None)`` saved by :func:`warmup_and_save`.

    The executable comes back ``None`` when the sidecar is missing or this
    jax build cannot deserialize it — callers fall back to the ordinary
    (lazily traced) replay path in that case. ``mesh`` is the consumer's
    replay mesh, matched against the artifact exactly as in
    :func:`executable_from_bytes`.
    """
    import os

    tdg = load_tdg(path, registry)
    aot_path = str(path) + ".aot"
    aot = None
    if os.path.exists(aot_path) and executable_serialization_available():
        try:
            aot = load_executable(aot_path, mesh=mesh)
        except Exception:  # incompatible platform / jax version: soft-fail
            aot = None
    return tdg, aot
