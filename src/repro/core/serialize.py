"""TDG serialization — the compiler→runtime handoff artifact.

In the paper, the compile-time path EMITS a TDG that the runtime later
loads and executes (Fig. 3: "reading the TDG built by the compiler").
Here the equivalent artifact is a JSON description of the graph —
tasks (by *registered payload name*), depend clauses, edges, slots,
metadata — that can be saved at record time and loaded in a different
process, re-binding payloads through a task-function registry.

Payload code itself is not serialized (same as the paper: the TDG file
references outlined functions by symbol); the registry plays the linker.
Round-tripping preserves the graph exactly (same edges, same schedule),
which the tests assert via topo-wave equality and replay equivalence.
"""
from __future__ import annotations

import json
from typing import Any, Callable

from .tdg import TDG, Edge, EdgeKind


class TaskFnRegistry:
    """Name -> payload function registry (the 'symbol table')."""

    def __init__(self) -> None:
        self._fns: dict[str, Callable] = {}

    def register(self, name: str | None = None):
        def deco(fn: Callable) -> Callable:
            key = name or fn.__name__
            if key in self._fns and self._fns[key] is not fn:
                raise ValueError(f"payload {key!r} already registered")
            self._fns[key] = fn
            fn.__taskfn_name__ = key
            return fn
        return deco

    def get(self, name: str) -> Callable:
        if name not in self._fns:
            raise KeyError(f"unknown task payload {name!r}; "
                           f"registered: {sorted(self._fns)}")
        return self._fns[name]

    def name_of(self, fn: Callable) -> str:
        key = getattr(fn, "__taskfn_name__", None)
        if key is None:
            raise ValueError(
                f"payload {fn!r} is not registered (decorate with "
                "@registry.register()) — cannot serialize this TDG")
        return key


def tdg_to_dict(tdg: TDG, registry: TaskFnRegistry) -> dict:
    return {
        "version": 1,
        "region": tdg.region,
        "tasks": [
            {"tid": t.tid, "fn": registry.name_of(t.fn),
             "ins": list(t.ins), "outs": list(t.outs), "name": t.name,
             "cost_hint": t.cost_hint, "metadata": t.metadata}
            for t in tdg.tasks
        ],
        "edges": [
            {"src": e.src, "dst": e.dst, "kind": e.kind.value, "slot": e.slot}
            for e in tdg.edges
        ],
        "input_slots": list(tdg.input_slots),
        "output_slots": list(tdg.output_slots),
    }


def tdg_from_dict(data: dict, registry: TaskFnRegistry) -> TDG:
    if data.get("version") != 1:
        raise ValueError(f"unsupported TDG version {data.get('version')}")
    tdg = TDG(region=data["region"])
    # rebuild tasks WITHOUT re-resolving deps (edges are authoritative)
    from .tdg import Task

    for td in data["tasks"]:
        t = Task(td["tid"], registry.get(td["fn"]), tuple(td["ins"]),
                 tuple(td["outs"]), name=td["name"],
                 cost_hint=td["cost_hint"], metadata=dict(td["metadata"]))
        tdg.tasks.append(t)
        tdg.preds[t.tid] = set()
        tdg.succs[t.tid] = set()
    for ed in data["edges"]:
        e = Edge(ed["src"], ed["dst"], EdgeKind(ed["kind"]), ed["slot"])
        tdg.edges.append(e)
        tdg.preds[e.dst].add(e.src)
        tdg.succs[e.src].add(e.dst)
    tdg.input_slots = list(data["input_slots"])
    tdg.output_slots = list(data["output_slots"])
    tdg._written = set(tdg.output_slots)
    tdg.validate()
    return tdg


def save_tdg(tdg: TDG, path, registry: TaskFnRegistry) -> None:
    with open(path, "w") as f:
        json.dump(tdg_to_dict(tdg, registry), f, indent=1)


def load_tdg(path, registry: TaskFnRegistry) -> TDG:
    with open(path) as f:
        return tdg_from_dict(json.load(f), registry)
