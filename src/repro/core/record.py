"""Record-and-replay + static TDG construction (the `taskgraph` directive).

``@taskgraph`` marks a *fully-taskified region*: a Python builder function
``fn(g, **buffers)`` whose only effects are ``g.task(...)`` spawns over named
buffer slots (plus deterministic, task-free control flow — the paper's
conformance requirements §4.1). The framework then chooses, exactly like
Algorithm 4.1 of the paper:

  * **static TDG** (``build_static``): if the region's control flow is
    computable from configuration alone, the TDG is built ahead of time by
    abstract evaluation (``jax.eval_shape`` stand-ins; no data touched) —
    the compile-time TDG of paper Fig. 4b/4d. Constants already bound in the
    tasks' closures play the role of "known data" (4d); everything else is
    ``fill_data`` at call time (4b).
  * **record** (first call): the region executes eagerly *while being
    recorded* — every task spawn resolves its depend clauses against the
    last-writer/readers table once, and runs.
  * **replay** (subsequent calls): the cached TDG is lowered to one fused
    executable and re-executed with zero per-task orchestration.

Regions are registered by *source location* (file, line) exactly as the
paper keys TDGs (§4.3.3). Instances of one region are sequentialized unless
``nowait=True`` (the paper's default semantics).

Replay executables are produced by ``lower.lower_tdg`` with wave fusion on
by default (``fuse`` parameter; see ``fuse.py``) and are *interned by
structure*: two regions with identical task/edge/payload structure share
one compiled executable via the global cache in ``lower.py``, so the
source-location registry keys region *identity* (instance sequencing,
stats) but no longer implies per-location recompilation. The per-region
``_replay_cache`` is keyed by ``(buffers_signature, resolved kernel
mode)`` — flipping ``REPRO_KERNELS`` between replays re-lowers instead of
returning a stale-substrate executable. ``warmup()`` AOT-compiles a
signature off the critical path (and is what ``serialize.save_executable``
persists for cross-process no-retrace replay).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Mapping

import jax

from . import costmodel as _costmodel
from . import lower as _lower
from . import schedule as _schedule
from .tdg import TDG, Task, buffers_signature
from ..kernels import registry as _kreg
from ..sharding import replay as _shreplay

_REGISTRY: dict[tuple, "TaskGraphRegion"] = {}
_registry_lock = threading.Lock()


def registry() -> dict[tuple, "TaskGraphRegion"]:
    return dict(_REGISTRY)


def reset_registry() -> None:
    with _registry_lock:
        _REGISTRY.clear()


class GraphBuilder:
    """The ``g`` handle passed to region builder functions."""

    def __init__(self, tdg: TDG, env: dict | None, abstract: bool):
        self._tdg = tdg
        self._env = env
        self._abstract = abstract

    @property
    def tdg(self) -> TDG:
        return self._tdg

    def task(self, fn: Callable, ins=(), outs=(), inouts=(), name: str = "",
             cost_hint: float = 1.0, **metadata) -> Task:
        """Spawn a task (``#pragma omp task depend(...)``)."""
        task = self._tdg.add_task(fn, ins=ins, outs=outs, inouts=inouts,
                                  name=name, cost_hint=cost_hint, **metadata)
        if self._env is not None:
            args = [self._env[s] for s in task.ins]
            if self._abstract:
                out = jax.eval_shape(fn, *args)
            else:
                out = fn(*args)
            if len(task.outs) == 1:
                self._env[task.outs[0]] = out
            elif len(task.outs) > 1:
                for s, v in zip(task.outs, out):
                    self._env[s] = v
        return task

    def slots(self) -> list[str]:
        return list(self._env) if self._env is not None else []


class TaskGraphRegion:
    """A taskgraph region: static-or-recorded TDG + replay cache."""

    def __init__(self, build_fn: Callable, name: str | None = None,
                 nowait: bool = False, donate_slots: tuple[str, ...] = (),
                 recurrent: bool = True, outputs: tuple[str, ...] | None = None,
                 fuse: bool | str = "auto", batcher: str = "auto",
                 mesh: Any = "auto"):
        code = build_fn.__code__
        self.build_fn = build_fn
        self.outputs = tuple(outputs) if outputs is not None else None
        self.fuse = fuse
        # Like mesh below, kept unresolved: "auto" re-reads REPRO_ADAPTIVE
        # per replay via costmodel.plan_key, which keys the replay cache.
        self.batcher = batcher
        # Kept UNresolved ("auto" stays "auto"): regions are typically
        # constructed at import time by the decorator, and resolving an env
        # mesh builds device meshes — replay resolves per call instead
        # (mirroring resolved_mode below), keyed into the replay cache.
        self.mesh = mesh
        self.name = name or build_fn.__name__
        # paper §4.3.3: TDGs are identified by source location
        self.source_location = (code.co_filename, code.co_firstlineno, self.name)
        self.nowait = nowait
        self.donate_slots = tuple(donate_slots)
        self.recurrent = recurrent
        self.tdg: TDG | None = None
        self.static = False
        self._replay_cache: dict[tuple, Callable] = {}
        self.records = 0
        self.replays = 0
        with _registry_lock:
            if self.source_location in _REGISTRY:
                raise ValueError(
                    f"taskgraph region already registered at {self.source_location} "
                    "(the directive cannot be declared recursively, paper §4.1)")
            _REGISTRY[self.source_location] = self

    # -- TDG construction ---------------------------------------------------
    def build_static(self, **buffer_specs) -> TDG:
        """Compile-time TDG from abstract buffer shapes (paper Fig. 4b/4d)."""
        tdg = TDG(region=self.name)
        env = {k: _abstractify(v) for k, v in buffer_specs.items()}
        self.build_fn(GraphBuilder(tdg, env, abstract=True), **buffer_specs)
        tdg.validate()
        self.tdg = tdg
        self.static = True
        return tdg

    def record(self, **buffers) -> dict:
        """First execution: run eagerly while recording (paper §4.3.2)."""
        tdg = TDG(region=self.name)
        env = dict(buffers)
        self.build_fn(GraphBuilder(tdg, env, abstract=False), **buffers)
        tdg.validate()
        self.tdg = tdg
        self.static = False
        self.records += 1
        out = {s: env[s] for s in (self.outputs or tdg.output_slots)}
        if not self.nowait:
            jax.block_until_ready(out)
        return out

    # -- execution ------------------------------------------------------------
    def replay(self, **buffers) -> dict:
        if self.tdg is None:
            raise RuntimeError(f"region {self.name!r} has no TDG yet")
        # Pin the kernel substrate per executable: the cache key carries the
        # resolved mode (like ReplayExecutor), so flipping REPRO_KERNELS
        # between replays re-lowers instead of serving a stale substrate.
        # The replay mesh resolves (and keys) the same way, so flipping
        # REPRO_MESH between replays re-lowers too.
        mode = _kreg.resolved_mode()
        mesh = _shreplay.resolve_mesh(self.mesh)
        sig = (buffers_signature(buffers), mode,
               _shreplay.mesh_fingerprint(mesh),
               _costmodel.plan_key(self.batcher))
        fn = self._replay_cache.get(sig)
        with _kreg.kernel_mode_scope(mode):
            if fn is None:
                fn = _lower.lower_tdg(self.tdg, donate_slots=self.donate_slots,
                                      outputs=self.outputs, fuse=self.fuse,
                                      batcher=self.batcher, mesh=mesh)
                self._replay_cache[sig] = fn
            out = fn(buffers)
        self.replays += 1
        if not self.nowait:
            jax.block_until_ready(out)
        return out

    def warmup(self, **buffers) -> _lower.AotExecutable:
        """AOT-compile the replay executable for these buffer shapes.

        ``buffers`` may be real arrays or ``ShapeDtypeStruct`` specs (pair
        with ``build_static`` for a fully data-free warmup). The compiled
        executable is installed in the replay cache, so the next matching
        call replays without tracing or compiling anything — and the
        returned ``AotExecutable`` can be persisted for other processes via
        ``serialize.save_executable``.
        """
        if self.tdg is None:
            raise RuntimeError(
                f"region {self.name!r} has no TDG yet — call build_static() "
                "or record once before warming up")
        mode = _kreg.resolved_mode()
        mesh = _shreplay.resolve_mesh(self.mesh)
        with _kreg.kernel_mode_scope(mode):
            aot = _lower.aot_compile_tdg(self.tdg, buffers,
                                         outputs=self.outputs,
                                         donate_slots=self.donate_slots,
                                         fuse=self.fuse, batcher=self.batcher,
                                         mesh=mesh)
        self._replay_cache[(buffers_signature(buffers), mode,
                            _shreplay.mesh_fingerprint(mesh),
                            _costmodel.plan_key(self.batcher))] = aot
        return aot

    def __call__(self, **buffers) -> dict:
        if self.tdg is None:
            if self.recurrent:
                return self.record(**buffers)
            # non-recurrent region: no point building a TDG (Algorithm 4.1
            # line 23: fall back to plain task instantiation) — run eagerly.
            tdg = TDG(region=self.name)
            env = dict(buffers)
            self.build_fn(GraphBuilder(tdg, env, abstract=False), **buffers)
            return {s: env[s] for s in (self.outputs or tdg.output_slots)}
        return self.replay(**buffers)

    # -- introspection ----------------------------------------------------------
    def as_function(self) -> Callable[[dict], dict]:
        """The replayable pure function (for grad / pjit / outer-TDG embedding)."""
        if self.tdg is None:
            raise RuntimeError(f"region {self.name!r} has no TDG yet")
        return _lower.tdg_as_function(self.tdg, outputs=self.outputs)

    def schedule_summary(self, n_workers: int = 8) -> dict:
        assert self.tdg is not None
        from . import fuse as _fuse

        waves = _schedule.topo_waves(self.tdg)
        return {
            "fusion": _fuse.plan(self.tdg).summary(),
            "tasks": self.tdg.num_tasks,
            "edges": self.tdg.num_edges,
            "roots": len(self.tdg.roots()),
            "waves": len(waves),
            "max_wave_width": max((len(w) for w in waves), default=0),
            "parallelism": _schedule.parallelism(self.tdg),
            "dep_lookups_at_record": self.tdg.dep_lookups(),
        }


def taskgraph(fn: Callable | None = None, *, name: str | None = None,
              nowait: bool = False, donate_slots: tuple[str, ...] = (),
              recurrent: bool = True, outputs: tuple[str, ...] | None = None,
              fuse: bool | str = "auto", batcher: str = "auto",
              mesh: Any = "auto"):
    """Decorator form: ``@taskgraph`` / ``@taskgraph(nowait=True)``."""

    def wrap(f: Callable) -> TaskGraphRegion:
        return TaskGraphRegion(f, name=name, nowait=nowait,
                               donate_slots=donate_slots, recurrent=recurrent,
                               outputs=outputs, fuse=fuse, batcher=batcher,
                               mesh=mesh)

    if fn is not None:
        return wrap(fn)
    return wrap


def _abstractify(x: Any):
    from .tdg import abstract_leaf

    return jax.tree_util.tree_map(abstract_leaf, x)
