"""Record-and-replay + static TDG construction (the `taskgraph` directive).

``@taskgraph`` marks a *fully-taskified region*: a Python builder function
``fn(g, **buffers)`` whose only effects are ``g.task(...)`` spawns over named
buffer slots (plus deterministic, task-free control flow — the paper's
conformance requirements §4.1). The framework then chooses, exactly like
Algorithm 4.1 of the paper:

  * **static TDG** (``build_static``): if the region's control flow is
    computable from configuration alone, the TDG is built ahead of time by
    abstract evaluation (``jax.eval_shape`` stand-ins; no data touched) —
    the compile-time TDG of paper Fig. 4b/4d. Constants already bound in the
    tasks' closures play the role of "known data" (4d); everything else is
    ``fill_data`` at call time (4b).
  * **record** (first call): the region executes eagerly *while being
    recorded* — every task spawn resolves its depend clauses against the
    last-writer/readers table once, and runs.
  * **replay** (subsequent calls): the cached TDG is lowered to one fused
    executable and re-executed with zero per-task orchestration.

Regions are registered by *source location* (file, line) exactly as the
paper keys TDGs (§4.3.3). Instances of one region are sequentialized unless
``nowait=True`` (the paper's default semantics).
"""
from __future__ import annotations

import functools
import threading
from typing import Any, Callable, Mapping

import jax

from . import lower as _lower
from . import schedule as _schedule
from .tdg import TDG, Task, buffers_signature

_REGISTRY: dict[tuple, "TaskGraphRegion"] = {}
_registry_lock = threading.Lock()


def registry() -> dict[tuple, "TaskGraphRegion"]:
    return dict(_REGISTRY)


def reset_registry() -> None:
    with _registry_lock:
        _REGISTRY.clear()


class GraphBuilder:
    """The ``g`` handle passed to region builder functions."""

    def __init__(self, tdg: TDG, env: dict | None, abstract: bool):
        self._tdg = tdg
        self._env = env
        self._abstract = abstract

    @property
    def tdg(self) -> TDG:
        return self._tdg

    def task(self, fn: Callable, ins=(), outs=(), inouts=(), name: str = "",
             cost_hint: float = 1.0, **metadata) -> Task:
        """Spawn a task (``#pragma omp task depend(...)``)."""
        task = self._tdg.add_task(fn, ins=ins, outs=outs, inouts=inouts,
                                  name=name, cost_hint=cost_hint, **metadata)
        if self._env is not None:
            args = [self._env[s] for s in task.ins]
            if self._abstract:
                out = jax.eval_shape(fn, *args)
            else:
                out = fn(*args)
            if len(task.outs) == 1:
                self._env[task.outs[0]] = out
            elif len(task.outs) > 1:
                for s, v in zip(task.outs, out):
                    self._env[s] = v
        return task

    def slots(self) -> list[str]:
        return list(self._env) if self._env is not None else []


class TaskGraphRegion:
    """A taskgraph region: static-or-recorded TDG + replay cache."""

    def __init__(self, build_fn: Callable, name: str | None = None,
                 nowait: bool = False, donate_slots: tuple[str, ...] = (),
                 recurrent: bool = True, outputs: tuple[str, ...] | None = None):
        code = build_fn.__code__
        self.build_fn = build_fn
        self.outputs = tuple(outputs) if outputs is not None else None
        self.name = name or build_fn.__name__
        # paper §4.3.3: TDGs are identified by source location
        self.source_location = (code.co_filename, code.co_firstlineno, self.name)
        self.nowait = nowait
        self.donate_slots = tuple(donate_slots)
        self.recurrent = recurrent
        self.tdg: TDG | None = None
        self.static = False
        self._replay_cache: dict[tuple, Callable] = {}
        self.records = 0
        self.replays = 0
        with _registry_lock:
            if self.source_location in _REGISTRY:
                raise ValueError(
                    f"taskgraph region already registered at {self.source_location} "
                    "(the directive cannot be declared recursively, paper §4.1)")
            _REGISTRY[self.source_location] = self

    # -- TDG construction ---------------------------------------------------
    def build_static(self, **buffer_specs) -> TDG:
        """Compile-time TDG from abstract buffer shapes (paper Fig. 4b/4d)."""
        tdg = TDG(region=self.name)
        env = {k: _abstractify(v) for k, v in buffer_specs.items()}
        self.build_fn(GraphBuilder(tdg, env, abstract=True), **buffer_specs)
        tdg.validate()
        self.tdg = tdg
        self.static = True
        return tdg

    def record(self, **buffers) -> dict:
        """First execution: run eagerly while recording (paper §4.3.2)."""
        tdg = TDG(region=self.name)
        env = dict(buffers)
        self.build_fn(GraphBuilder(tdg, env, abstract=False), **buffers)
        tdg.validate()
        self.tdg = tdg
        self.static = False
        self.records += 1
        out = {s: env[s] for s in (self.outputs or tdg.output_slots)}
        if not self.nowait:
            jax.block_until_ready(out)
        return out

    # -- execution ------------------------------------------------------------
    def replay(self, **buffers) -> dict:
        if self.tdg is None:
            raise RuntimeError(f"region {self.name!r} has no TDG yet")
        sig = buffers_signature(buffers)
        fn = self._replay_cache.get(sig)
        if fn is None:
            fn = _lower.lower_tdg(self.tdg, donate_slots=self.donate_slots,
                                  outputs=self.outputs)
            self._replay_cache[sig] = fn
        out = fn(buffers)
        self.replays += 1
        if not self.nowait:
            jax.block_until_ready(out)
        return out

    def __call__(self, **buffers) -> dict:
        if self.tdg is None:
            if self.recurrent:
                return self.record(**buffers)
            # non-recurrent region: no point building a TDG (Algorithm 4.1
            # line 23: fall back to plain task instantiation) — run eagerly.
            tdg = TDG(region=self.name)
            env = dict(buffers)
            self.build_fn(GraphBuilder(tdg, env, abstract=False), **buffers)
            return {s: env[s] for s in (self.outputs or tdg.output_slots)}
        return self.replay(**buffers)

    # -- introspection ----------------------------------------------------------
    def as_function(self) -> Callable[[dict], dict]:
        """The replayable pure function (for grad / pjit / outer-TDG embedding)."""
        if self.tdg is None:
            raise RuntimeError(f"region {self.name!r} has no TDG yet")
        return _lower.tdg_as_function(self.tdg, outputs=self.outputs)

    def schedule_summary(self, n_workers: int = 8) -> dict:
        assert self.tdg is not None
        waves = _schedule.topo_waves(self.tdg)
        return {
            "tasks": self.tdg.num_tasks,
            "edges": self.tdg.num_edges,
            "roots": len(self.tdg.roots()),
            "waves": len(waves),
            "max_wave_width": max((len(w) for w in waves), default=0),
            "parallelism": _schedule.parallelism(self.tdg),
            "dep_lookups_at_record": self.tdg.dep_lookups(),
        }


def taskgraph(fn: Callable | None = None, *, name: str | None = None,
              nowait: bool = False, donate_slots: tuple[str, ...] = (),
              recurrent: bool = True, outputs: tuple[str, ...] | None = None):
    """Decorator form: ``@taskgraph`` / ``@taskgraph(nowait=True)``."""

    def wrap(f: Callable) -> TaskGraphRegion:
        return TaskGraphRegion(f, name=name, nowait=nowait,
                               donate_slots=donate_slots, recurrent=recurrent,
                               outputs=outputs)

    if fn is not None:
        return wrap(fn)
    return wrap


def _abstractify(x: Any):
    def leaf(v):
        if isinstance(v, jax.ShapeDtypeStruct):
            return v
        if hasattr(v, "shape") and hasattr(v, "dtype"):
            return jax.ShapeDtypeStruct(v.shape, v.dtype)
        import numpy as np

        arr = np.asarray(v)
        return jax.ShapeDtypeStruct(arr.shape, arr.dtype)

    return jax.tree_util.tree_map(leaf, x)
