"""Pipeline parallelism lowered from the pipeline TDG.

``schedule.pipeline_tdg`` / ``one_f_one_b_order`` define the *logical*
schedule (the static taskgraph). This module executes it on a mesh axis:
a GPipe-style rotation where stage s holds its layer shard and microbatches
flow s -> s+1 via ``ppermute`` (the TPU-native edge: a collective-permute
per TDG activation edge). The wave structure of the shard_map loop is
exactly ``topo_waves(pipeline_tdg(S, M, include_backward=False))`` —
asserted by tests, which is the point: the paper's "schedule once, replay"
applied to pipeline orchestration.

Backward is obtained by differentiating through the rotation (ppermute
transposes to the reverse permute), which reproduces the reverse schedule
without hand-writing it.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,            # (stage_params, x) -> y  (one stage)
    stage_params,                  # pytree, leaves stacked on leading S dim
    x_microbatches: jax.Array,     # (M, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Forward pipeline: returns (M, mb, ...) outputs of the LAST stage.

    Steady-state utilization M/(M+S-1) — the classic GPipe bubble; the
    1F1B variant reorders backward into the bubble (see
    ``schedule.one_f_one_b_order``), with identical wave count.
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    T = M + S - 1                   # total waves (pipeline TDG depth)

    def per_stage(params, xs):
        # params sliced per stage (leading block dim 1); xs replicated (full)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        carry_in = jnp.zeros(mb_shape, xs.dtype)   # rotating activation
        outs = jnp.zeros_like(xs)

        def wave(t, state):
            carry, outs = state
            # stage 0 injects microbatch t; others take the rotated carry
            mb_idx = jnp.clip(t, 0, M - 1)
            my_in = jnp.where(sid == 0, xs[mb_idx], carry)
            active = (t - sid >= 0) & (t - sid < M)
            y = stage_fn(params, my_in)
            y = jnp.where(active, y, carry)
            # last stage records its finished microbatch
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            record = active & (sid == S - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, y, outs[out_idx]), out_idx, 0)
            # rotate activations to the next stage
            carry = jax.lax.ppermute(
                y, axis, perm=[(i, (i + 1) % S) for i in range(S)])
            return carry, outs

        _, outs = jax.lax.fori_loop(0, T, wave, (carry_in, outs))
        # only stage S-1 holds real outputs; broadcast via masked psum
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs[None]

    from jax.experimental.shard_map import shard_map

    spec_p = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_p, P(None)),   # microbatches replicated
                   out_specs=P(axis),
                   check_rep=False)
    # feed every stage the full microbatch tensor; stage 0 uses it
    outs = fn(stage_params, x_microbatches)    # (S, M, mb, ...) stacked
    return outs[0]                             # identical post-broadcast


def pipeline_waves(n_stages: int, n_microbatches: int) -> int:
    """Forward wave count = TDG depth (checked against topo_waves in tests)."""
    return n_microbatches + n_stages - 1


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
