"""Cost-model-driven grain decisions: measured flops/bytes pick the batcher.

The paper's thesis is that a recorded TDG lets the *runtime* absorb task
management cost; Worksharing Tasks (arXiv 2004.03258) extends the argument
to grain size — how work is chunked should be a runtime decision, made from
observation, not a call-site constant. Until this module, the repo still
decided grain statically in two places: ``core/fuse.py`` batched every
fused wave class with ``vmap`` (or a caller-chosen ``lax.map``), and
``serving/server.py`` bucketed batch occupancy to fixed powers of two.
Meanwhile ``lower.aot_compile_tdg`` was already *capturing* XLA cost
analysis that nothing consumed.

This module closes the loop with two decision engines:

* :class:`CostModel` — per-wave-class batcher selection. Each fused class's
  payload is probed once (``jit(fn).lower(specs).compile()``) for XLA's
  ``flops`` / ``"bytes accessed"``; their ratio (arithmetic intensity,
  flops/byte) classifies the class:

  - **compute-bound** (intensity >= ``ridge``): ``vmap`` — one batched
    kernel amortizes fixed cost and exposes the batch dim to the compiler
    (and to mesh sharding).
  - **memory-bound** (intensity < ``ridge``) with a *cache-resident member
    but cache-overflowing batch* (``bytes <= map_member_bytes`` and
    ``size * bytes >= map_total_bytes``): ``lax.map`` — streaming lanes
    sequentially keeps the working set one member deep instead of
    materializing the whole stacked batch. Members too large to ever be
    cache-resident gain nothing from streaming (the scan's per-lane
    slice-in/slice-out copies only add traffic) and stay ``vmap``.
  - **below the fused-overhead break-even** (``size * flops <
    unroll_flops``): ``unrolled`` — for near-free bodies the stack/unstack
    machinery costs more than just inlining the handful of ops.

  Unmeasurable payloads (no ``cost_analysis`` on this backend, probe
  failure, or XLA's ``-1`` "unknown flops" sentinel — CPU triangular solve
  reports this) fall back to ``vmap``, the static heuristic this model
  replaces, so adaptivity never makes an *unmeasured* bet.

* :class:`BucketTuner` — adaptive occupancy buckets for the serving tier.
  Observed batch occupancies accumulate into a histogram; every ``window``
  observations (or earlier, when the recent pad fraction drifts past
  ``drift_pad_fraction``) the tuner refits up to ``max_buckets`` bucket
  boundaries minimizing total pad lanes (exact small DP), replacing the
  fixed pow-2 ladder. Every *new* boundary value is one more jit
  specialization of the pooled batched executable, so a lifetime
  ``max_new_buckets`` budget bounds retracing; when it is spent, the
  boundaries freeze.

``REPRO_ADAPTIVE=0`` is the kill switch for BOTH engines: batcher
selection resolves back to static ``vmap`` and the tuner pins the pow-2
ladder. :func:`plan_key` fingerprints the active policy (thresholds and
all) for the intern/replay caches, so executables lowered under different
plans never collide — flipping the switch (or a threshold) re-lowers
instead of serving a stale plan.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
from typing import Any, Callable, Iterable, Mapping, Sequence

ADAPTIVE_ENV = "REPRO_ADAPTIVE"

#: Arithmetic-intensity ridge (flops/byte) separating compute-bound from
#: memory-bound classes. Deliberately modest: anything with real arithmetic
#: reuse (blocked matmul at >= 32x32) clears it, elementwise/stencil/BLAS-1
#: bodies (0.1-0.3 flops/byte) fall below.
DEFAULT_RIDGE = 1.0
#: ``lax.map`` upper bound on one member's bytes accessed: past this a
#: member can't be cache-resident, so streaming lanes buys nothing.
DEFAULT_MAP_MEMBER_BYTES = 512 * 1024
#: ``lax.map`` lower bound on the stacked class's total bytes: below this
#: the whole batch is cache-resident and one fused vmap kernel wins.
DEFAULT_MAP_TOTAL_BYTES = 128 * 1024
#: Unrolled break-even: classes whose TOTAL measured flops fall below this
#: are cheaper inlined than stacked/unstacked.
DEFAULT_UNROLL_FLOPS = 256.0


def adaptive_enabled(arg: bool | str = "auto") -> bool:
    """Resolve an ``adaptive`` argument (True | False | "auto").

    "auto" honours ``REPRO_ADAPTIVE`` (0/false/off/no disables) and
    otherwise enables cost-model-driven decisions.
    """
    if arg is True or arg is False:
        return arg
    if arg != "auto":
        raise ValueError(f"adaptive must be True, False or 'auto', got {arg!r}")
    env = os.environ.get(ADAPTIVE_ENV)
    if env is not None:
        return env.strip().lower() not in ("0", "false", "off", "no")
    return True


def capture_cost_analysis(compiled: Any) -> dict | None:
    """Best-effort ``compiled.cost_analysis()`` -> plain dict, else None.

    jax has returned ``[dict]``, ``dict`` and dict-likes across versions,
    and backends without an analysis raise — every shape degrades to None
    here rather than poisoning callers (also exported as
    ``lower._capture_cost_analysis``).
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    try:
        return dict(ca) if ca else None
    except Exception:
        return None


@dataclasses.dataclass(frozen=True)
class ClassCost:
    """Measured per-member cost of one wave class's payload.

    ``flops`` / ``bytes_accessed`` are None when the backend offered no
    (usable) analysis — XLA's ``-1`` "unknown" sentinel is normalized to
    None here so downstream math never divides by a lie.
    """

    flops: float | None
    bytes_accessed: float | None
    source: str = "measured"        # "measured" | "unavailable"

    @property
    def intensity(self) -> float | None:
        """Arithmetic intensity in flops/byte, or None if unmeasured."""
        if self.flops is None or not self.bytes_accessed:
            return None
        return self.flops / self.bytes_accessed


UNMEASURED = ClassCost(flops=None, bytes_accessed=None, source="unavailable")


@dataclasses.dataclass(frozen=True)
class BatcherDecision:
    """One batcher choice plus the numbers that drove it."""

    batcher: str                    # "vmap" | "map" | "unrolled"
    reason: str                     # human-auditable, names the inputs
    cost: ClassCost
    size: int

    def describe(self) -> dict:
        """JSON-safe record for plan summaries and the cost report."""
        inten = self.cost.intensity
        return {
            "batcher": self.batcher,
            "size": self.size,
            "flops": self.cost.flops,
            "bytes": self.cost.bytes_accessed,
            "intensity": None if inten is None else round(inten, 4),
            "reason": self.reason,
        }


class CostModel:
    """Measured flops/bytes -> per-class batcher decisions (see module doc).

    Probe results are cached per (payload identity, arg signature, kernel
    mode) — a payload's cost is paid once per shape, not once per trace —
    with strong references pinning the payload exactly like the intern
    cache, so ``id()`` keys stay sound.
    """

    def __init__(self, ridge: float = DEFAULT_RIDGE,
                 map_member_bytes: int = DEFAULT_MAP_MEMBER_BYTES,
                 map_total_bytes: int = DEFAULT_MAP_TOTAL_BYTES,
                 unroll_flops: float = DEFAULT_UNROLL_FLOPS,
                 cache_size: int = 512):
        self.ridge = float(ridge)
        self.map_member_bytes = int(map_member_bytes)
        self.map_total_bytes = int(map_total_bytes)
        self.unroll_flops = float(unroll_flops)
        self._lock = threading.Lock()
        self._cache_size = max(1, int(cache_size))
        # key -> (payload strong ref, ClassCost)
        self._cache: collections.OrderedDict[tuple, tuple] = \
            collections.OrderedDict()
        self.probes = 0
        self.probe_failures = 0

    def fingerprint(self) -> str:
        """Threshold fingerprint — part of the adaptive plan's cache key."""
        return (f"r{self.ridge:g}-m{self.map_member_bytes}"
                f"-t{self.map_total_bytes}-u{self.unroll_flops:g}")

    # -- measurement -------------------------------------------------------
    def measure(self, fn: Callable, arg_specs: Sequence[Any]) -> ClassCost:
        """Probe-compile ``fn`` for ``arg_specs`` and read XLA's analysis.

        ``arg_specs`` are ShapeDtypeStruct trees (ONE member's arguments,
        not the stacked batch). Probing is a real, tiny, independent
        compile — legal mid-trace because only abstract shapes cross into
        it — and every failure degrades to :data:`UNMEASURED`.
        """
        try:
            sig = tuple(_spec_signature(s) for s in arg_specs)
        except Exception:
            return UNMEASURED
        key = (id(fn), sig, _ambient_kernel_mode())
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                return hit[1]
        cost = self._probe(fn, arg_specs)
        with self._lock:
            self._cache[key] = (fn, cost)
            self._cache.move_to_end(key)
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return cost

    def _probe(self, fn: Callable, arg_specs: Sequence[Any]) -> ClassCost:
        import jax

        self.probes += 1
        try:
            compiled = jax.jit(fn).lower(*arg_specs).compile()
        except Exception:
            self.probe_failures += 1
            return UNMEASURED
        ca = capture_cost_analysis(compiled) or {}
        flops = ca.get("flops")
        bytes_accessed = ca.get("bytes accessed")
        # XLA reports -1 for ops it cannot count (CPU triangular solve):
        # that is "unknown", not "free" — normalize to unmeasured.
        if flops is None or flops < 0:
            flops = None
        if bytes_accessed is None or bytes_accessed < 0:
            bytes_accessed = None
        if flops is None and bytes_accessed is None:
            return UNMEASURED
        return ClassCost(flops=flops, bytes_accessed=bytes_accessed)

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()

    # -- decision ----------------------------------------------------------
    def decide(self, cost: ClassCost, size: int) -> BatcherDecision:
        """Pick vmap | map | unrolled for a class of ``size`` members."""
        size = max(1, int(size))
        flops, nbytes, inten = cost.flops, cost.bytes_accessed, cost.intensity
        if flops is None and nbytes is None:
            return BatcherDecision(
                "vmap", "unmeasured payload: static fallback", cost, size)
        if flops is not None and size * flops < self.unroll_flops:
            return BatcherDecision(
                "unrolled",
                f"{size}x{flops:g} flops < break-even {self.unroll_flops:g}",
                cost, size)
        if inten is not None and inten < self.ridge and nbytes is not None:
            if (nbytes <= self.map_member_bytes
                    and size * nbytes >= self.map_total_bytes):
                return BatcherDecision(
                    "map",
                    f"memory-bound ({inten:.3g} flops/B < ridge "
                    f"{self.ridge:g}), member {nbytes:g}B cache-resident, "
                    f"batch {size * nbytes:g}B is not",
                    cost, size)
            return BatcherDecision(
                "vmap",
                f"memory-bound ({inten:.3g} flops/B) but "
                f"{'member too large to stream' if nbytes > self.map_member_bytes else 'whole batch cache-resident'}",
                cost, size)
        shown = "unknown" if inten is None else f"{inten:.3g}"
        return BatcherDecision(
            "vmap", f"compute-bound ({shown} flops/B >= ridge "
            f"{self.ridge:g})", cost, size)

    def decide_for(self, fn: Callable, arg_specs: Sequence[Any],
                   size: int) -> BatcherDecision:
        return self.decide(self.measure(fn, arg_specs), size)


_default_model = CostModel()


def default_model() -> CostModel:
    """The process-wide cost model (what ``batcher="auto"`` consults)."""
    return _default_model


def _spec_signature(spec: Any) -> tuple:
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(spec)
    return (str(treedef), tuple((tuple(l.shape), str(l.dtype))
                                for l in leaves))


def _ambient_kernel_mode() -> str | None:
    try:
        from ..kernels import registry as _kreg

        return _kreg.resolved_mode()
    except Exception:  # pragma: no cover - kernels layer optional here
        return None


# -------------------------------------------------------- batcher resolution

_BATCHERS = ("vmap", "map", "auto")


def resolve_batcher(batcher: str) -> str:
    """Resolve a ``batcher`` argument to the active policy.

    ``"auto"`` stays ``"auto"`` when adaptivity is on and collapses to
    ``"vmap"`` (the static heuristic the model replaces) under
    ``REPRO_ADAPTIVE=0`` — the kill switch restores pre-adaptive behaviour
    exactly. Static policies pass through.
    """
    if batcher not in _BATCHERS:
        raise ValueError(f"batcher must be one of {_BATCHERS}, got {batcher!r}")
    if batcher == "auto" and not adaptive_enabled():
        return "vmap"
    return batcher


def plan_key(batcher: str) -> str:
    """Cache-key component naming the batcher *plan*, not just the arg.

    Two lowerings of one structure under different plans (static vmap vs
    adaptive, or adaptive under different thresholds) must never share an
    executable: the decisions are baked into the trace. The adaptive key
    carries the model's threshold fingerprint so even a threshold change
    re-lowers.
    """
    resolved = resolve_batcher(batcher)
    if resolved == "auto":
        return f"auto/{default_model().fingerprint()}"
    return resolved


# ------------------------------------------------------------ bucket fitting

def pow2_boundaries(max_batch: int) -> list[int]:
    """The static ladder: 2, 4, 8, ... up to (at least) ``max_batch``."""
    bounds = [2]
    while bounds[-1] < max(2, int(max_batch)):
        bounds.append(bounds[-1] * 2)
    return bounds


def fit_boundaries(histogram: Mapping[int, int], max_buckets: int,
                   floor: int = 2) -> list[int]:
    """Choose <= ``max_buckets`` bucket boundaries minimizing pad lanes.

    ``histogram`` maps observed occupancy -> count (occupancies below
    ``floor`` are ignored: a group of one never takes the batched path).
    Boundaries are drawn from the observed occupancies themselves — any
    other value only adds padding — and always include the maximum, so
    every observed occupancy has a bucket. Exact DP over the (small,
    <= max_batch) distinct-occupancy domain; deterministic.
    """
    vals = sorted(v for v, c in histogram.items() if v >= floor and c > 0)
    if not vals:
        return []
    cnts = [histogram[v] for v in vals]
    d = len(vals)
    k_max = max(1, min(int(max_buckets), d))

    def seg_cost(i: int, j: int) -> int:
        # members in vals[i..j] all pad up to vals[j]
        return sum(cnts[t] * (vals[j] - vals[t]) for t in range(i, j + 1))

    INF = float("inf")
    dp = [[INF] * d for _ in range(k_max + 1)]
    back: list[list[int]] = [[-1] * d for _ in range(k_max + 1)]
    for j in range(d):
        dp[1][j] = seg_cost(0, j)
    for k in range(2, k_max + 1):
        for j in range(k - 1, d):
            for i in range(k - 2, j):
                cand = dp[k - 1][i] + seg_cost(i + 1, j)
                if cand < dp[k][j]:
                    dp[k][j] = cand
                    back[k][j] = i
    best_k = min(range(1, k_max + 1), key=lambda k: dp[k][d - 1])
    bounds = []
    j, k = d - 1, best_k
    while j >= 0 and k >= 1:
        bounds.append(vals[j])
        j = back[k][j]
        k -= 1
    return sorted(bounds)


class BucketTuner:
    """Occupancy buckets fitted from the live histogram (serving tier).

    Starts on the pow-2 ladder (identical to the static server), observes
    every batched occupancy, and — when adaptive — refits boundaries every
    ``window`` observations, or early when the recent pad fraction drifts
    past ``drift_pad_fraction``. Each *new* boundary value is a fresh jit
    specialization of the pooled batched executable, so a lifetime
    ``max_new_buckets`` retrace budget bounds tuning; once spent, the
    boundaries freeze. Thread-safe (the server's scheduler thread and
    stats() callers race).
    """

    def __init__(self, max_batch: int, adaptive: bool | str = "auto",
                 window: int = 64, max_buckets: int = 8,
                 max_new_buckets: int = 16,
                 drift_pad_fraction: float = 0.35):
        self.max_batch = max(1, int(max_batch))
        self.adaptive = adaptive_enabled(adaptive)
        self.window = max(1, int(window))
        self.max_buckets = max(1, int(max_buckets))
        self.max_new_buckets = max(0, int(max_new_buckets))
        self.drift_pad_fraction = float(drift_pad_fraction)
        self._lock = threading.Lock()
        self.boundaries: list[int] = pow2_boundaries(self.max_batch)
        self._histogram: collections.Counter = collections.Counter()
        self._recent: collections.deque = collections.deque(maxlen=self.window)
        self.observations = 0
        self.retunes = 0
        self.new_buckets_spent = 0
        self.pad_lanes = 0
        self.lanes = 0

    def bucket_for(self, occupancy: int) -> int:
        """Smallest boundary >= occupancy (pow-2-extended past the ladder)."""
        n = max(1, int(occupancy))
        if n <= 1:
            return 1
        with self._lock:
            for b in self.boundaries:
                if b >= n:
                    return b
            top = self.boundaries[-1] if self.boundaries else 2
        while top < n:
            top *= 2
        return top

    def observe(self, occupancy: int) -> bool:
        """Record one batched occupancy; True iff boundaries just changed.

        The caller (the server) treats True as "stale pooled executables":
        old bucket sizes' specializations are dead weight and new ones
        would accrete beside them, so it invalidates the pooled batched
        entries and lets the next step rebuild against the new ladder.
        """
        n = int(occupancy)
        if n < 2:
            return False
        pad = self.bucket_for(n) - n
        with self._lock:
            self._histogram[n] += 1
            self._recent.append((n, pad))
            self.observations += 1
            self.pad_lanes += pad
            self.lanes += n + pad
            if not self.adaptive or self.new_buckets_spent >= self.max_new_buckets:
                return False
            due = self.observations % self.window == 0
            if not due and len(self._recent) >= self.window:
                recent_lanes = sum(o + p for o, p in self._recent)
                recent_pad = sum(p for _, p in self._recent)
                due = (recent_lanes > 0
                       and recent_pad / recent_lanes > self.drift_pad_fraction)
            if not due:
                return False
            fitted = fit_boundaries(self._histogram, self.max_buckets)
            if not fitted or fitted == self.boundaries:
                return False
            new = [b for b in fitted if b not in self.boundaries]
            budget_left = self.max_new_buckets - self.new_buckets_spent
            if len(new) > budget_left:
                # Keep the most frequent new boundaries within budget; the
                # rest of the fit is discarded rather than half-applied.
                new = sorted(new, key=lambda b: -self._histogram[b])[:budget_left]
                fitted = sorted(set(new) | {max(self._histogram)})
                if not new:
                    return False
            self.new_buckets_spent += len(new)
            self.boundaries = fitted
            self.retunes += 1
            self._recent.clear()
            return True

    def summary(self) -> dict:
        with self._lock:
            return {
                "adaptive": self.adaptive,
                "boundaries": list(self.boundaries),
                "observations": self.observations,
                "retunes": self.retunes,
                "new_buckets_spent": self.new_buckets_spent,
                "retrace_budget": self.max_new_buckets,
                "pad_lanes": self.pad_lanes,
                "pad_fraction": round(self.pad_lanes / self.lanes, 4)
                if self.lanes else 0.0,
                "histogram": {str(k): v for k, v in
                              sorted(self._histogram.items())},
            }
