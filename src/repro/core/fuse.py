"""Wave-fused lowering: worksharing-style batching of isomorphic tasks.

The unrolled replay path (``lower.tdg_as_function``) emits every task body
into the traced program one call at a time, so trace+compile cost — and
jaxpr size — scale with *task count* even when the graph is just a few
waves of isomorphic work (a 16x64 pipeline TDG traces 2048 bodies for ~80
distinct waves). That re-introduces, at the tracing layer, exactly the
per-task fixed cost the paper eliminates at the orchestration layer.

Following Worksharing Tasks (Maroñas et al., 2020), this module batches
fine-grained tasks back into coarse dispatches:

* :func:`classify_wave` groups one topo-wave's tasks into **isomorphism
  classes** — same payload function (by identity), same input arity/shapes/
  dtypes, same output arity. Tasks in one wave are mutually independent by
  construction, so any class can execute as a single batched call.
* :func:`fused_tdg_as_function` lowers each class of size >=
  ``min_class_size`` as ONE ``jax.vmap``-batched call (or a sequential
  ``lax.map`` for memory-bound cases, ``batcher="map"``) over arguments
  stacked along axis 0, with argument positions whose slot is shared by
  every member broadcast instead of stacked. The traced program shrinks
  from O(tasks) body instances to O(wave-classes).

Fusion is semantics-preserving and *best-effort*: heterogeneous waves
degrade to per-task unrolled calls, and any class whose batched trace
fails (a payload without a batching rule, say) falls back to the unrolled
form for that class only. Classification happens at trace time, where
argument shapes are known from the tracers, so one lowered function stays
shape-polymorphic exactly like the unrolled path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from . import costmodel as _costmodel
from . import schedule as _schedule
from .tdg import TDG, abstract_leaf as _as_spec
from ..sharding import replay as _shreplay

STACK_AXIS = 0


# ------------------------------------------------------------------ analysis

def value_signature(v: Any) -> tuple:
    """Abstract (treedef, per-leaf shape/dtype) signature of one value."""
    leaves, treedef = jax.tree_util.tree_flatten(v)
    return (treedef,
            tuple((tuple(getattr(l, "shape", ())),
                   str(getattr(l, "dtype", type(l).__name__))) for l in leaves))


@dataclasses.dataclass(frozen=True)
class WaveClass:
    """One isomorphism class inside one wave.

    ``batcher``/``reason``/``flops``/``bytes_accessed`` record how the
    class was (or would be) dispatched and the measured numbers behind the
    choice — "static" reason means a caller-pinned batcher, no cost model
    consulted. ``padded`` counts mesh-alignment pad lanes actually added
    at trace time (repeating the last member; computed, never read back).
    """

    wave: int
    tids: tuple[int, ...]
    fused: bool                      # lowered as one batched call?
    shared: tuple[bool, ...]         # arg position uses one slot for all tids
    batcher: str = "vmap"            # "vmap" | "map" | "unrolled"
    reason: str = "static"           # what drove the batcher choice
    flops: float | None = None       # measured per-member flops (if probed)
    bytes_accessed: float | None = None  # measured per-member bytes accessed
    padded: int = 0                  # pad lanes added for mesh alignment

    @property
    def size(self) -> int:
        return len(self.tids)

    def decision(self) -> dict:
        """JSON-safe audit record (plan summaries / the cost report)."""
        inten = (self.flops / self.bytes_accessed
                 if self.flops is not None and self.bytes_accessed else None)
        return {
            "wave": self.wave,
            "size": self.size,
            "fused": self.fused,
            "batcher": self.batcher,
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "intensity": None if inten is None else round(inten, 4),
            "padded": self.padded,
            "reason": self.reason,
        }


@dataclasses.dataclass
class FusionPlan:
    """Result of the wave analysis pass over a whole TDG."""

    region: str
    num_tasks: int
    classes: list[WaveClass]
    min_class_size: int

    @property
    def num_waves(self) -> int:
        return 1 + max((c.wave for c in self.classes), default=-1)

    @property
    def num_classes(self) -> int:
        return len(self.classes)

    @property
    def fused_classes(self) -> int:
        return sum(1 for c in self.classes if c.fused)

    @property
    def fused_tasks(self) -> int:
        return sum(c.size for c in self.classes if c.fused)

    @property
    def fused_fraction(self) -> float:
        return self.fused_tasks / max(self.num_tasks, 1)

    @property
    def padded_lanes(self) -> int:
        return sum(c.padded for c in self.classes)

    @property
    def pad_fraction(self) -> float:
        """Pad lanes over total batched lanes (real + pad) — idle-work rate."""
        lanes = sum(c.size + c.padded for c in self.classes if c.fused)
        return self.padded_lanes / lanes if lanes else 0.0

    def summary(self) -> dict:
        batchers: dict[str, int] = {}
        for c in self.classes:
            if c.fused:
                batchers[c.batcher] = batchers.get(c.batcher, 0) + 1
        return {
            "region": self.region,
            "tasks": self.num_tasks,
            "waves": self.num_waves,
            "classes": self.num_classes,
            "fused_classes": self.fused_classes,
            "fused_tasks": self.fused_tasks,
            "fused_fraction": round(self.fused_fraction, 4),
            "batchers": batchers,
            "padded_lanes": self.padded_lanes,
            "pad_fraction": round(self.pad_fraction, 4),
            "decisions": [c.decision() for c in self.classes],
        }


def classify_wave(tdg: TDG, wave_index: int, wave: Sequence[int],
                  sig_of: Callable[[str], Any] | None,
                  min_class_size: int = 2) -> list[WaveClass]:
    """Group one wave's tasks into isomorphism classes.

    ``sig_of`` maps a slot name to an abstract value signature (or ``None``
    for purely structural grouping by payload identity + arity, used when no
    shape information is available yet). Classes are returned in order of
    first member, members in tid order — deterministic for a given TDG.
    """
    groups: dict[tuple, list[int]] = {}
    for tid in sorted(wave):
        t = tdg.tasks[tid]
        key: tuple = (id(t.fn), len(t.ins), len(t.outs))
        if sig_of is not None:
            key += tuple(sig_of(s) for s in t.ins)
        groups.setdefault(key, []).append(tid)
    classes = []
    for tids in groups.values():
        arity = len(tdg.tasks[tids[0]].ins)
        shared = tuple(
            all(tdg.tasks[t].ins[i] == tdg.tasks[tids[0]].ins[i] for t in tids)
            for i in range(arity))
        classes.append(WaveClass(wave=wave_index, tids=tuple(tids),
                                 fused=len(tids) >= min_class_size,
                                 shared=shared))
    return classes


def _decide_class(tdg: TDG, cls: WaveClass, batcher: str,
                  spec_of: Callable[[str], Any] | None) -> WaveClass:
    """Attach a batcher decision (and the numbers behind it) to one class.

    ``batcher="auto"`` consults the process cost model: the class payload
    is probe-compiled for ONE member's argument specs and the measured
    flops/bytes pick vmap vs ``lax.map`` vs unrolled (see ``costmodel``).
    A static batcher passes through untouched — no probe, reason "static".
    """
    if not cls.fused:
        return dataclasses.replace(
            cls, batcher="unrolled",
            reason=f"class size {cls.size} below min_class_size")
    if batcher != "auto":
        return dataclasses.replace(cls, batcher=batcher, reason="static")
    model = _costmodel.default_model()
    t = tdg.tasks[cls.tids[0]]
    arg_specs = None
    if spec_of is not None:
        try:
            arg_specs = [spec_of(s) for s in t.ins]
        except Exception:
            arg_specs = None
    if arg_specs is None:
        d = model.decide(_costmodel.UNMEASURED, cls.size)
    else:
        d = model.decide_for(t.fn, arg_specs, cls.size)
    return dataclasses.replace(
        cls, batcher=d.batcher, fused=d.batcher != "unrolled",
        reason=d.reason, flops=d.cost.flops,
        bytes_accessed=d.cost.bytes_accessed)


def plan(tdg: TDG, buffers: Mapping[str, Any] | None = None,
         min_class_size: int = 2, batcher: str = "vmap") -> FusionPlan:
    """Offline wave analysis (for stats, tests and benchmark reporting).

    With ``buffers`` (arrays or ``ShapeDtypeStruct`` trees for the region's
    input slots), slot shapes are propagated through the graph by abstract
    evaluation so classes match exactly what trace-time fusion will do;
    without them, grouping is structural (payload identity + arity) — an
    upper bound on fusion opportunity. ``batcher="auto"`` additionally runs
    the cost model over each class (requires ``buffers`` for measured
    numbers; without them every class is "unmeasured" -> vmap fallback).
    """
    batcher = _costmodel.resolve_batcher(batcher)
    sig_of = spec_of = None
    if buffers is not None:
        env: dict[str, Any] = {
            k: jax.tree_util.tree_map(_as_spec, v) for k, v in buffers.items()}
        for tid in _schedule.topo_order(tdg):
            t = tdg.tasks[tid]
            out = jax.eval_shape(t.fn, *[env[s] for s in t.ins])
            _bind_outs(t, out, env)
        sig_of = lambda s: value_signature(env[s])  # noqa: E731
        spec_of = lambda s: env[s]  # noqa: E731 (already abstract specs)
    classes: list[WaveClass] = []
    for wi, wave in enumerate(_schedule.topo_waves(tdg)):
        classes.extend(
            _decide_class(tdg, c, batcher, spec_of)
            for c in classify_wave(tdg, wi, wave, sig_of, min_class_size))
    return FusionPlan(region=tdg.region, num_tasks=tdg.num_tasks,
                      classes=classes, min_class_size=min_class_size)


# ----------------------------------------------------------------- execution

def _bind_outs(task, out, env: dict) -> None:
    """Write one task's return value into the env (same rules as lower)."""
    if len(task.outs) == 1:
        env[task.outs[0]] = out
    elif len(task.outs) > 1:
        if not isinstance(out, (tuple, list)) or len(out) != len(task.outs):
            raise ValueError(
                f"task {task.label()} declared {len(task.outs)} outputs, "
                f"returned {type(out).__name__}")
        for s, v in zip(task.outs, out):
            env[s] = v


def _run_unrolled(tdg: TDG, tids: Sequence[int], env: dict) -> None:
    for tid in tids:
        t = tdg.tasks[tid]
        try:
            args = [env[s] for s in t.ins]
        except KeyError as e:  # pragma: no cover - defensive
            raise KeyError(f"task {t.label()} reads unbound slot {e} "
                           f"(region inputs: {tdg.input_slots})") from None
        _bind_outs(t, t.fn(*args), env)


def _run_fused_class(tdg: TDG, cls: WaveClass, env: dict, batcher: str,
                     mesh=None) -> int:
    """Execute one isomorphism class as a single batched call; return #pads.

    With a ``mesh``, the vmap-batched form pads the class to a multiple of
    the mesh's batch-axis size (repeating the last member — padded lanes
    are computed and dropped, never read) and constrains the stacked
    arguments over the mesh so GSPMD splits the batch across devices.
    ``batcher="map"`` is deliberately single-device: ``lax.map`` is a
    sequential scan, so sharding its carried axis buys nothing. The return
    value is the pad-lane count actually added (0 without a mesh), surfaced
    through ``FusionPlan.summary()`` as ``padded_lanes``/``pad_fraction``.
    """
    tasks = [tdg.tasks[t] for t in cls.tids]
    fn = tasks[0].fn
    arity = len(tasks[0].ins)
    varying = [i for i in range(arity) if not cls.shared[i]]

    if not varying:
        # Every member reads identical slots: one evaluation serves all
        # (distinct out slots are guaranteed — a WAW pair cannot share a wave).
        out = fn(*[env[tasks[0].ins[i]] for i in range(arity)])
        for t in tasks:
            _bind_outs(t, out, env)
        return 0

    if batcher != "vmap":
        mesh = None
    shared_args = {i: env[tasks[0].ins[i]] for i in range(arity)
                   if cls.shared[i]}
    members = {i: [env[t.ins[i]] for t in tasks] for i in varying}
    padded = 0
    for i in varying:
        padded = _shreplay.pad_group(members[i], mesh)
    stacked = {
        i: jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, STACK_AXIS), *members[i])
        for i in varying}
    if mesh is not None:
        stacked = {i: _shreplay.shard_leading(v, mesh)
                   for i, v in stacked.items()}

    if batcher == "vmap":
        in_axes = tuple(None if cls.shared[i] else STACK_AXIS
                        for i in range(arity))
        args = [shared_args[i] if cls.shared[i] else stacked[i]
                for i in range(arity)]
        out = jax.vmap(fn, in_axes=in_axes)(*args)
    elif batcher == "map":
        def body(var_args):
            it = iter(var_args)
            return fn(*[shared_args[i] if cls.shared[i] else next(it)
                        for i in range(arity)])
        out = jax.lax.map(body, tuple(stacked[i] for i in varying))
    else:
        raise ValueError(f"unknown batcher {batcher!r} (vmap | map)")

    n_outs = len(tasks[0].outs)
    for j, t in enumerate(tasks):
        take = lambda x: jax.lax.index_in_dim(  # noqa: E731
            x, j, axis=STACK_AXIS, keepdims=False)
        if n_outs == 1:
            env[t.outs[0]] = jax.tree_util.tree_map(take, out)
        else:
            if not isinstance(out, (tuple, list)) or len(out) != n_outs:
                raise ValueError(
                    f"task {t.label()} declared {n_outs} outputs, "
                    f"returned {type(out).__name__}")
            for oi, s in enumerate(t.outs):
                env[s] = jax.tree_util.tree_map(take, out[oi])
    return padded


def fused_tdg_as_function(tdg: TDG, outputs: Sequence[str] | None = None,
                          min_class_size: int = 2,
                          batcher: str = "vmap",
                          mesh=None) -> Callable[[dict], dict]:
    """Return ``f(buffers) -> {slot: value}`` with wave-fused task dispatch.

    Drop-in replacement for ``lower.tdg_as_function`` (pure, traceable,
    jittable, differentiable); tasks execute in wave order, which refines
    the same partial order as any topological order. After each call (or
    trace), ``f.last_plan`` holds the :class:`FusionPlan` actually applied,
    including trace-time fallbacks.

    ``batcher`` is ``"vmap"`` / ``"map"`` (one pinned dispatch for every
    fused class, no cost model) or ``"auto"`` — per-class cost-model
    selection from probe-measured flops/bytes (``core.costmodel``). The
    ``REPRO_ADAPTIVE=0`` kill switch is resolved *here* so a function built
    before the flag flip still honours it at trace time.

    ``mesh`` (a concrete :class:`jax.sharding.Mesh` or ``None``; resolution
    of ``"auto"`` happens in ``lower.lower_tdg``) shards every fused
    class's stacked batch axis across devices — see
    :func:`_run_fused_class`. Classes that fall back to the unrolled form
    stay single-device, which is the per-class fallback for unbatchable
    payloads.
    """
    waves = _schedule.topo_waves(tdg)
    outputs = list(outputs) if outputs is not None else list(tdg.output_slots)

    def run(buffers: Mapping[str, Any]) -> dict:
        env = dict(buffers)
        resolved = _costmodel.resolve_batcher(batcher)
        applied: list[WaveClass] = []
        for wi, wave in enumerate(waves):
            def sig_of(s):
                try:
                    return value_signature(env[s])
                except KeyError:
                    raise KeyError(
                        f"unbound slot {s!r} (region inputs: "
                        f"{tdg.input_slots})") from None
            def spec_of(s):
                return jax.tree_util.tree_map(_as_spec, env[s])
            for cls in classify_wave(tdg, wi, wave, sig_of, min_class_size):
                cls = _decide_class(tdg, cls, resolved, spec_of)
                if not cls.fused:
                    _run_unrolled(tdg, cls.tids, env)
                    applied.append(cls)
                    continue
                try:
                    padded = _run_fused_class(tdg, cls, env, cls.batcher,
                                              mesh=mesh)
                    applied.append(dataclasses.replace(cls, padded=padded))
                except Exception:
                    # Payload not batchable (no vmap rule, data-dependent
                    # control flow, ...): this class only degrades to the
                    # unrolled form. A payload broken under tracing per se
                    # re-raises from here with its real error.
                    _run_unrolled(tdg, cls.tids, env)
                    applied.append(dataclasses.replace(
                        cls, fused=False, batcher="unrolled",
                        reason="trace fallback: payload not batchable"))
        run.last_plan = FusionPlan(region=tdg.region, num_tasks=tdg.num_tasks,
                                   classes=applied,
                                   min_class_size=min_class_size)
        return {s: env[s] for s in outputs}

    run.last_plan = None
    run.__name__ = f"tdg_fused_{tdg.region}"
    return run
