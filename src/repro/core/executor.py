"""Executors for a TDG.

``EagerExecutor`` is the *vanilla-runtime analogue*: a real dynamic task
scheduler with per-worker deques, round-robin root placement, optional work
stealing and join counters, dispatching one (jitted) XLA call per task. Every
per-task cost it pays — Python bookkeeping, ready-queue operations, dispatch
— is the measured stand-in for the task creation/contention overheads of
vanilla GCC/LLVM OpenMP runtimes. ``central_queue=True`` reproduces the
GOMP-like single-shared-queue regime (highest contention); the default
per-worker-deque mode reproduces LLVM libomp's distributed queues.

``ReplayExecutor`` runs the single fused executable produced by
``lower.lower_tdg`` (the paper's execute_TDG) with per-signature caching.
The kernel *substrate* (pallas | ref | interpret, see
``repro.kernels.registry``) is resolved once at construction and pinned for
every lowering/trace: a replayed executable never flips substrate mid-flight
even if the global kernel mode changes between calls.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Mapping

import jax

from . import costmodel as _costmodel
from . import lower as _lower
from . import schedule as _schedule
from .tdg import TDG, buffers_signature
from ..kernels import registry as _kreg
from ..sharding import replay as _shreplay


@dataclasses.dataclass
class ExecStats:
    tasks_executed: int = 0
    queue_ops: int = 0          # pushes+pops on ready queues (contention proxy)
    steals: int = 0
    dep_resolutions: int = 0    # join-counter decrements (runtime dep tracking)
    dispatch_seconds: float = 0.0
    wall_seconds: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class EagerExecutor:
    """Dynamic scheduler over per-worker deques (the 'vanilla' baseline)."""

    def __init__(self, tdg: TDG, n_workers: int = 4, central_queue: bool = False,
                 steal: bool = True, jit_tasks: bool = True,
                 round_robin_roots: bool = True):
        tdg.validate()
        self.tdg = tdg
        self.n_workers = max(1, n_workers)
        self.central_queue = central_queue
        self.steal = steal
        self.round_robin_roots = round_robin_roots
        self._jit_tasks = jit_tasks
        self._compiled: dict[int, Callable] = {}
        if jit_tasks:
            # one executable per task instance = per-task "creation" cost paid
            # at first execution, mirroring vanilla task instantiation.
            for t in tdg.tasks:
                self._compiled[t.tid] = jax.jit(t.fn)
        self.stats = ExecStats()

    def _fn(self, tid: int) -> Callable:
        return self._compiled.get(tid, self.tdg.tasks[tid].fn)

    def run(self, buffers: Mapping[str, Any],
            outputs: list[str] | None = None) -> dict:
        tdg = self.tdg
        stats = self.stats
        t0 = time.perf_counter()
        env = dict(buffers)
        join = {t.tid: len(tdg.preds[t.tid]) for t in tdg.tasks}

        nq = 1 if self.central_queue else self.n_workers
        queues: list[collections.deque[int]] = [collections.deque() for _ in range(nq)]

        roots = tdg.roots()
        if self.round_robin_roots and not self.central_queue:
            for w, tids in enumerate(_schedule.round_robin_assign(roots, nq)):
                for tid in tids:
                    queues[w].append(tid)
                    stats.queue_ops += 1
        else:
            for tid in roots:  # vanilla: the spawning thread owns all roots
                queues[0].append(tid)
                stats.queue_ops += 1

        executed = 0
        w = 0
        while executed < tdg.num_tasks:
            # pick a task: own queue first, then steal (FIFO from victim)
            tid = None
            if queues[w % nq]:
                tid = queues[w % nq].popleft()
                stats.queue_ops += 1
            elif self.steal:
                for off in range(1, nq):
                    victim = (w + off) % nq
                    if queues[victim]:
                        tid = queues[victim].popleft()
                        stats.queue_ops += 1
                        stats.steals += 1
                        break
            if tid is None:
                w += 1
                continue

            task = tdg.tasks[tid]
            args = [env[s] for s in task.ins]
            d0 = time.perf_counter()
            out = self._fn(tid)(*args)
            stats.dispatch_seconds += time.perf_counter() - d0
            if len(task.outs) == 1:
                env[task.outs[0]] = out
            elif len(task.outs) > 1:
                for s, v in zip(task.outs, out):
                    env[s] = v
            executed += 1
            stats.tasks_executed += 1
            # dependency resolution at run time (what replay eliminates)
            for sid in sorted(tdg.succs[tid]):
                stats.dep_resolutions += 1
                join[sid] -= 1
                if join[sid] == 0:
                    queues[w % nq].append(sid)  # locality: completer enqueues
                    stats.queue_ops += 1
            w += 1

        outputs = outputs if outputs is not None else list(tdg.output_slots)
        result = {s: env[s] for s in outputs}
        jax.block_until_ready(result)
        stats.wall_seconds += time.perf_counter() - t0
        return result


class ReplayExecutor:
    """Cached fused execution of a TDG (the paper's execute_TDG).

    ``kernel_mode`` selects the kernel substrate for every task body in the
    replayed executable (``None`` = the global mode at construction time;
    ``"auto"`` resolves per platform). The choice is made ONCE, here, and
    entered as a ``kernel_mode_scope`` around lowering and tracing — per-call
    dispatch never consults the global switch again, so the fused executable
    is substrate-stable and per-signature cache entries are keyed by mode.

    Lowering is wave-fused and structurally interned by default (see
    ``lower.py``): isomorphic tasks in one wave trace as a single batched
    call, and executors over structurally identical TDGs share one compiled
    executable. ``fuse=False`` restores fully unrolled lowering;
    ``aot_compile()`` pays trace+compile eagerly (off the hot path) and
    returns a serializable ``AotExecutable``.
    """

    def __init__(self, tdg: TDG, donate_slots: tuple[str, ...] = (),
                 order: list[int] | None = None,
                 kernel_mode: str | None = None,
                 fuse: bool | str = "auto",
                 batcher: str = "auto",
                 mesh: Any = "auto"):
        tdg.validate()
        self.tdg = tdg
        self.donate_slots = tuple(donate_slots)
        self.order = order
        self.fuse = fuse
        # The batcher *plan* is resolved once, like the substrate and mesh:
        # "auto" -> the adaptive cost-model policy (or "vmap" under
        # REPRO_ADAPTIVE=0), and its plan key joins the per-signature cache
        # signature so executables lowered under different plans never
        # collide in this executor either.
        self.batcher = batcher
        self.plan_key = _costmodel.plan_key(batcher)
        self.kernel_mode = _kreg.resolved_mode(kernel_mode)
        # Like the kernel substrate, the replay mesh is resolved ONCE at
        # construction and pinned: fused executables bake their sharding
        # constraints into the trace, so a mesh flip mid-lifetime must
        # produce a different cache entry, never mutate an existing one.
        self.mesh = _shreplay.resolve_mesh(mesh)
        self.mesh_fp = _shreplay.mesh_fingerprint(self.mesh)
        self._cache: dict[tuple, Callable] = {}
        self.replays = 0

    def _compiled_for(self, buffers: Mapping[str, Any]) -> Callable:
        sig = (buffers_signature(buffers), self.kernel_mode, self.mesh_fp,
               self.plan_key)
        fn = self._cache.get(sig)
        if fn is None:
            with _kreg.kernel_mode_scope(self.kernel_mode):
                fn = _lower.lower_tdg(self.tdg, order=self.order,
                                      donate_slots=self.donate_slots,
                                      fuse=self.fuse, batcher=self.batcher,
                                      mesh=self.mesh)
            self._cache[sig] = fn
        return fn

    def aot_compile(self, buffers: Mapping[str, Any]) -> "_lower.AotExecutable":
        """Eagerly compile (trace now, not at first run) for these shapes.

        The executable is installed in the per-signature cache under this
        executor's pinned substrate, so subsequent ``run`` calls with
        matching buffers execute without any tracing; the returned
        ``AotExecutable`` carries XLA cost analysis and is serializable via
        ``serialize.save_executable``. Requires ``order=None`` (AOT lowering
        is wave-ordered).
        """
        if self.order is not None:
            raise ValueError("aot_compile does not support a custom order")
        with _kreg.kernel_mode_scope(self.kernel_mode):
            aot = _lower.aot_compile_tdg(self.tdg, buffers,
                                         donate_slots=self.donate_slots,
                                         fuse=self.fuse, batcher=self.batcher,
                                         mesh=self.mesh)
        self._cache[(buffers_signature(buffers), self.kernel_mode,
                     self.mesh_fp, self.plan_key)] = aot
        return aot

    def run(self, buffers: Mapping[str, Any], block: bool = True) -> dict:
        fn = self._compiled_for(buffers)
        # jax.jit traces lazily on first invocation: keep the pinned mode in
        # scope around the call so that trace bakes in this executor's
        # substrate, not whatever the global flag says at the time.
        with _kreg.kernel_mode_scope(self.kernel_mode):
            out = fn(dict(buffers))
        self.replays += 1
        if block:
            jax.block_until_ready(out)
        return out
