"""Schedules over a TDG.

The paper's replay executor needs exactly two scheduling artifacts, both
computed once per TDG and reused on every replay:

  * a *wave decomposition* (topological levels) — tasks in one wave are
    mutually independent, so they can run in any order / in parallel; and
  * a *static placement* of each wave's tasks onto workers, with the paper's
    round-robin policy for root tasks (§4.3.1/§4.3.2) generalized to every
    wave (zero-coordination work placement).

It also provides a list scheduler (HEFT-lite) used for load-balanced
placement when cost hints exist, a critical-path metric, and the 1F1B /
GPipe pipeline schedule generators (a pipeline schedule *is* a static TDG
over (microbatch, stage) tasks).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from .tdg import TDG, Task


def topo_order(tdg: TDG) -> list[int]:
    """Deterministic topological order (Kahn, tid tie-break = record order)."""
    indeg = {t.tid: len(tdg.preds[t.tid]) for t in tdg.tasks}
    import heapq

    ready = [tid for tid, d in indeg.items() if d == 0]
    heapq.heapify(ready)
    order: list[int] = []
    while ready:
        tid = heapq.heappop(ready)
        order.append(tid)
        for s in sorted(tdg.succs[tid]):
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(ready, s)
    if len(order) != tdg.num_tasks:
        raise ValueError(f"cycle detected in {tdg.region!r}")
    return order


def topo_waves(tdg: TDG) -> list[list[int]]:
    """Wave k = tasks whose longest pred-path has length k."""
    depth: dict[int, int] = {}
    for tid in topo_order(tdg):
        preds = tdg.preds[tid]
        depth[tid] = 1 + max((depth[p] for p in preds), default=-1)
    waves: list[list[int]] = []
    for tid, d in depth.items():
        while len(waves) <= d:
            waves.append([])
        waves[d].append(tid)
    for w in waves:
        w.sort()
    return waves


def round_robin_assign(tids: Sequence[int], n_workers: int, start: int = 0) -> list[list[int]]:
    """Paper §4.3.2: hand out tasks round-robin to per-worker queues."""
    queues: list[list[int]] = [[] for _ in range(n_workers)]
    for i, tid in enumerate(tids):
        queues[(start + i) % n_workers].append(tid)
    return queues


def wave_placement(tdg: TDG, n_workers: int) -> list[list[list[int]]]:
    """Static placement: per wave, round-robin its tasks across workers.

    Returned as ``placement[wave][worker] -> [tid, ...]``. Rotating the
    starting worker between waves avoids systematically over-loading
    worker 0 with the remainder tasks.
    """
    placement = []
    start = 0
    for wave in topo_waves(tdg):
        placement.append(round_robin_assign(wave, n_workers, start=start))
        start = (start + len(wave)) % max(n_workers, 1)
    return placement


def critical_path(tdg: TDG, cost: Callable[[Task], float] | None = None) -> float:
    """Length of the longest weighted path (lower bound on makespan)."""
    cost = cost or (lambda t: t.cost_hint)
    dist: dict[int, float] = {}
    best = 0.0
    for tid in topo_order(tdg):
        t = tdg.tasks[tid]
        dist[tid] = cost(t) + max((dist[p] for p in tdg.preds[tid]), default=0.0)
        best = max(best, dist[tid])
    return best


def work(tdg: TDG, cost: Callable[[Task], float] | None = None) -> float:
    cost = cost or (lambda t: t.cost_hint)
    return sum(cost(t) for t in tdg.tasks)


def parallelism(tdg: TDG) -> float:
    """Average parallelism = total work / critical path (unit costs)."""
    cp = critical_path(tdg, lambda t: 1.0)
    return tdg.num_tasks / max(cp, 1.0)


@dataclasses.dataclass
class ListSchedule:
    """Output of the list scheduler: per-worker ordered task lists plus the
    simulated makespan under the cost model (used for placement decisions
    and for load-balance assertions in tests)."""

    worker_tasks: list[list[int]]
    start_time: dict[int, float]
    finish_time: dict[int, float]
    makespan: float

    def order(self) -> list[int]:
        merged = sorted(self.start_time.items(), key=lambda kv: (kv[1], kv[0]))
        return [tid for tid, _ in merged]


def list_schedule(tdg: TDG, n_workers: int,
                  cost: Callable[[Task], float] | None = None) -> ListSchedule:
    """HEFT-lite: tasks become ready when preds finish; each ready task goes
    to the earliest-available worker; ties broken by critical-path priority.
    Communication costs are zero (shared memory / single executable)."""
    cost = cost or (lambda t: t.cost_hint)
    # upward rank (critical-path-to-exit priority)
    rank: dict[int, float] = {}
    for tid in reversed(topo_order(tdg)):
        t = tdg.tasks[tid]
        rank[tid] = cost(t) + max((rank[s] for s in tdg.succs[tid]), default=0.0)

    import heapq

    indeg = {t.tid: len(tdg.preds[t.tid]) for t in tdg.tasks}
    ready_at = {t.tid: 0.0 for t in tdg.tasks}
    # ready heap: (-rank, tid) so higher rank first
    ready: list[tuple[float, int]] = [(-rank[tid], tid) for tid, d in indeg.items() if d == 0]
    heapq.heapify(ready)
    worker_free = [0.0] * n_workers
    worker_tasks: list[list[int]] = [[] for _ in range(n_workers)]
    start: dict[int, float] = {}
    finish: dict[int, float] = {}

    scheduled = 0
    while scheduled < tdg.num_tasks:
        if not ready:
            # Cannot happen for a valid DAG: every unscheduled task either
            # has indegree 0 (it was pushed) or a scheduled-pred chain that
            # pushed it on the last decrement.
            raise RuntimeError(
                f"list_schedule stalled with {tdg.num_tasks - scheduled} "
                f"unscheduled tasks in {tdg.region!r} (cyclic TDG?)")
        _, tid = heapq.heappop(ready)
        t = tdg.tasks[tid]
        w = min(range(n_workers), key=lambda i: (worker_free[i], i))
        s = max(worker_free[w], ready_at[tid])
        f = s + cost(t)
        worker_free[w] = f
        worker_tasks[w].append(tid)
        start[tid], finish[tid] = s, f
        scheduled += 1
        for sid in sorted(tdg.succs[tid]):
            indeg[sid] -= 1
            ready_at[sid] = max(ready_at[sid], f)
            if indeg[sid] == 0:
                heapq.heappush(ready, (-rank[sid], sid))
    return ListSchedule(worker_tasks, start, finish, max(finish.values(), default=0.0))


# ---------------------------------------------------------------------------
# Pipeline schedules as TDGs (microbatch x stage task grids)
# ---------------------------------------------------------------------------

def pipeline_tdg(n_stages: int, n_microbatches: int,
                 include_backward: bool = True) -> TDG:
    """Build the TDG of a synchronous pipeline-parallel step.

    Forward task F(m, s) depends on F(m, s-1) (activation flow) and the
    previous microbatch on the same stage (in-order stage occupancy).
    Backward task B(m, s) depends on B(m, s+1) and F(m, s).
    This graph *is* the static taskgraph that 1F1B/GPipe replay.
    """
    tdg = TDG(region=f"pipeline[{n_stages}x{n_microbatches}]")

    def _noop(*xs):  # placeholder payload; lowering substitutes stage fns
        return xs[0] if len(xs) == 1 else xs

    for m in range(n_microbatches):
        for s in range(n_stages):
            ins = []
            if s > 0:
                ins.append(f"act[{m},{s - 1}]")
            if m > 0:
                ins.append(f"stage{s}.tok")  # serialization token per stage
            tdg.add_task(_noop, ins=ins, outs=[f"act[{m},{s}]", f"stage{s}.tok"],
                         name=f"F[{m},{s}]", microbatch=m, stage=s, phase="fwd")
    if include_backward:
        for m in range(n_microbatches):
            for s in reversed(range(n_stages)):
                ins = [f"act[{m},{s}]"]
                if s < n_stages - 1:
                    ins.append(f"grad[{m},{s + 1}]")
                tdg.add_task(_noop, ins=ins,
                             outs=[f"grad[{m},{s}]", f"stage{s}.tok"],
                             name=f"B[{m},{s}]", microbatch=m, stage=s, phase="bwd")
    tdg.validate()
    return tdg


def one_f_one_b_order(n_stages: int, n_microbatches: int) -> list[list[tuple[str, int]]]:
    """Per-stage static instruction streams for the 1F1B schedule.

    Returns ``streams[stage] = [("F", m) | ("B", m), ...]`` — the classic
    1F1B order: warm-up of (n_stages - stage) forwards, then alternate
    1 forward / 1 backward, then drain. This is the per-worker queue content
    of the pipeline TDG's list schedule, precomputed exactly.
    """
    streams: list[list[tuple[str, int]]] = []
    for s in range(n_stages):
        warmup = min(n_stages - s, n_microbatches)
        stream: list[tuple[str, int]] = [("F", m) for m in range(warmup)]
        nf, nb = warmup, 0
        while nb < n_microbatches:
            stream.append(("B", nb))
            nb += 1
            if nf < n_microbatches:
                stream.append(("F", nf))
                nf += 1
        streams.append(stream)
    return streams


def validate_execution_order(tdg: TDG, order: Sequence[int]) -> bool:
    """True iff ``order`` respects every edge (used by property tests)."""
    pos = {tid: i for i, tid in enumerate(order)}
    if len(pos) != tdg.num_tasks:
        return False
    return all(pos[e.src] < pos[e.dst] for e in tdg.edges)
