"""Core Taskgraph framework: TDG, record-and-replay, schedules, executors,
wave-fused lowering, cost-model-driven batcher selection, structural
executable interning and AOT compilation."""
from .tdg import (TDG, Task, Edge, DepKind, EdgeKind, DependencyTable,
                  buffers_signature, structure_signature)
from .costmodel import (CostModel, ClassCost, BatcherDecision, BucketTuner,
                        adaptive_enabled, resolve_batcher, plan_key,
                        default_model, fit_boundaries, pow2_boundaries)
from .fuse import (FusionPlan, WaveClass, classify_wave, fused_tdg_as_function,
                   plan as fusion_plan)
from .schedule import (
    topo_order,
    topo_waves,
    round_robin_assign,
    wave_placement,
    critical_path,
    work,
    parallelism,
    list_schedule,
    ListSchedule,
    pipeline_tdg,
    one_f_one_b_order,
    validate_execution_order,
)
from .lower import (tdg_as_function, lower_tdg, aot_compile_tdg, AotExecutable,
                    intern_stats, clear_intern_cache, fuse_enabled)
from .executor import EagerExecutor, ReplayExecutor, ExecStats
from .record import taskgraph, TaskGraphRegion, GraphBuilder, registry, reset_registry
from .serialize import (TaskFnRegistry, TopologyMismatch, save_tdg, load_tdg,
                        tdg_to_dict, tdg_from_dict, save_executable,
                        load_executable, executable_to_bytes,
                        executable_from_bytes,
                        executable_serialization_available,
                        topology_fingerprint, warmup_and_save, load_warm)

__all__ = [
    "TDG", "Task", "Edge", "DepKind", "EdgeKind", "DependencyTable",
    "buffers_signature", "structure_signature",
    "CostModel", "ClassCost", "BatcherDecision", "BucketTuner",
    "adaptive_enabled", "resolve_batcher", "plan_key", "default_model",
    "fit_boundaries", "pow2_boundaries",
    "FusionPlan", "WaveClass", "classify_wave", "fused_tdg_as_function",
    "fusion_plan",
    "topo_order", "topo_waves", "round_robin_assign", "wave_placement",
    "critical_path", "work", "parallelism", "list_schedule", "ListSchedule",
    "pipeline_tdg", "one_f_one_b_order", "validate_execution_order",
    "tdg_as_function", "lower_tdg", "aot_compile_tdg", "AotExecutable",
    "intern_stats", "clear_intern_cache", "fuse_enabled",
    "EagerExecutor", "ReplayExecutor", "ExecStats",
    "taskgraph", "TaskGraphRegion", "GraphBuilder", "registry", "reset_registry",
    "TaskFnRegistry", "save_tdg", "load_tdg", "tdg_to_dict", "tdg_from_dict",
    "save_executable", "load_executable",
    "executable_to_bytes", "executable_from_bytes",
    "executable_serialization_available", "warmup_and_save", "load_warm",
    "TopologyMismatch", "topology_fingerprint",
]
