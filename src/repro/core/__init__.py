"""Core Taskgraph framework: TDG, record-and-replay, schedules, executors."""
from .tdg import TDG, Task, Edge, DepKind, EdgeKind, DependencyTable, buffers_signature
from .schedule import (
    topo_order,
    topo_waves,
    round_robin_assign,
    wave_placement,
    critical_path,
    work,
    parallelism,
    list_schedule,
    ListSchedule,
    pipeline_tdg,
    one_f_one_b_order,
    validate_execution_order,
)
from .lower import tdg_as_function, lower_tdg
from .executor import EagerExecutor, ReplayExecutor, ExecStats
from .record import taskgraph, TaskGraphRegion, GraphBuilder, registry, reset_registry
from .serialize import TaskFnRegistry, save_tdg, load_tdg, tdg_to_dict, tdg_from_dict

__all__ = [
    "TDG", "Task", "Edge", "DepKind", "EdgeKind", "DependencyTable",
    "buffers_signature",
    "topo_order", "topo_waves", "round_robin_assign", "wave_placement",
    "critical_path", "work", "parallelism", "list_schedule", "ListSchedule",
    "pipeline_tdg", "one_f_one_b_order", "validate_execution_order",
    "tdg_as_function", "lower_tdg",
    "EagerExecutor", "ReplayExecutor", "ExecStats",
    "taskgraph", "TaskGraphRegion", "GraphBuilder", "registry", "reset_registry",
    "TaskFnRegistry", "save_tdg", "load_tdg", "tdg_to_dict", "tdg_from_dict",
]
