"""Grouped (per-expert) matmul — Pallas TPU kernel for MoE expert GEMMs.

Capacity-based MoE dispatch produces dense per-expert activations
``x: (E, C, d)`` multiplied by per-expert weights ``w: (E, d, f)``.
The kernel grids over (expert, C-tiles, f-tiles, d-tiles) with a VMEM f32
accumulator; (bc, bd, bf) default to MXU-aligned 128 tiles. The expert
dimension is embarrassingly parallel — on an EP-sharded mesh each device
runs only its local experts (the round-robin root-task distribution of the
paper, realized as a static shard).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    kd = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(kd == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                      # (bc, bd)
    w = w_ref[0]                      # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(
    x: jax.Array,   # (E, C, d)
    w: jax.Array,   # (E, d, f)
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    E, C, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, max(8, 1 << (C - 1).bit_length()))
    block_d = min(block_d, d)
    block_f = min(block_f, f)
    assert d % block_d == 0 and f % block_f == 0, (d, f, block_d, block_f)
    c_pad = math.ceil(C / block_c) * block_c
    if c_pad != C:
        x = jnp.pad(x, ((0, 0), (0, c_pad - C), (0, 0)))

    grid = (E, c_pad // block_c, f // block_f, d // block_d)
    out = compat.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_d, block_f), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, c_pad, f), x.dtype),
        scratch_shapes=[compat.vmem((block_c, block_f), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="moe_grouped_matmul",
    )(x, w)
    return out[:, :C]
