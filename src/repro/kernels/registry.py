"""Kernel substrate registry: ``(op, backend, mode)`` -> implementation.

The dispatch point for every performance-critical op in this package. Before
this module, each function in ``ops.py`` carried its own hand-rolled if/elif
over the substrate choice, so adding a backend (GPU/Triton, a new ref path)
meant editing every op. Mirroring how worksharing-task runtimes centralize
backend-specific orchestration behind one dispatch table, all of that now
lives here:

* **op** — the logical kernel name (``"attention"``, ``"rmsnorm"``,
  ``"grouped_matmul"``, ``"ssd"``).
* **backend** — the device platform the implementation targets (``"tpu"``,
  ``"gpu"``, ``"cpu"``) or the wildcard ``"*"`` for platform-agnostic
  implementations (the jnp references, interpret-mode Pallas).
* **mode** — the substrate family: ``"pallas"`` (compiled kernels),
  ``"ref"`` (pure-jnp oracles), ``"interpret"`` (Pallas bodies on the
  interpreter; CPU-debuggable bit-twins of the compiled kernels).

Resolution prefers an exact ``(op, backend, mode)`` entry and falls back to
``(op, "*", mode)``. The global *kernel mode* (``"auto"`` resolves to
``pallas`` on TPU and ``ref`` elsewhere) is owned here too: the env override
``REPRO_KERNELS`` is validated eagerly at import so a typo fails at process
start with a clear message, not deep inside a jit trace. Executors that
record-and-replay a task graph pin the resolved mode once at lowering time
via :func:`kernel_mode_scope`, so a replayed executable never flips
substrate mid-flight.

This registry is the extension point for future backends: a GPU/Triton PR
registers ``(op, "gpu", "pallas")`` implementations and every caller —
models, executors, benchmarks — picks them up with no dispatch edits. The
full extension recipe (and how this table relates to the ``compat.py``
shim) is documented in ``docs/kernels.md``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Any, Callable, Iterator

import jax

MODES = ("auto", "pallas", "ref", "interpret")
SUBSTRATES = ("pallas", "ref", "interpret")   # concrete (non-auto) modes
WILDCARD = "*"
_ENV_VAR = "REPRO_KERNELS"


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered kernel implementation.

    Calling the instance calls ``fn`` directly — resolution cost is paid in
    :func:`resolve`, never per invocation. ``doc`` is a one-line human
    description (defaults to the first docstring line at registration).
    """
    op: str
    backend: str
    mode: str
    fn: Callable[..., Any]
    doc: str = ""

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        """Invoke the underlying implementation (no further dispatch)."""
        return self.fn(*args, **kwargs)


_lock = threading.Lock()
_impls: dict[tuple[str, str, str], KernelImpl] = {}


# ---------------------------------------------------------------- mode state

def validate_mode(mode: str) -> str:
    """Return ``mode`` if legal, else raise with the full legal set."""
    if mode not in MODES:
        raise ValueError(
            f"invalid kernel mode {mode!r}: expected one of {MODES} "
            f"(set via set_kernel_mode() or the {_ENV_VAR} env var)")
    return mode


def _env_mode() -> str:
    raw = os.environ.get(_ENV_VAR, "auto")
    try:
        return validate_mode(raw)
    except ValueError as e:
        raise ValueError(f"bad {_ENV_VAR} environment variable: {e}") from None


# Validated eagerly at import: a bogus REPRO_KERNELS fails here, at process
# start, instead of exploding later inside dispatch.
_mode: str = _env_mode()

# Scope overrides are per-thread: two executors pinned to different
# substrates can trace concurrently from different threads without racing
# each other's mode (the process-wide base set by set_kernel_mode stays
# shared; only the dynamic-extent override is thread-local).
_scope = threading.local()


def set_kernel_mode(mode: str) -> None:
    """Set the process-wide substrate mode (validated immediately)."""
    global _mode
    _mode = validate_mode(mode)


def kernel_mode() -> str:
    """The currently effective mode, possibly ``"auto"``.

    A ``kernel_mode_scope`` override active on THIS thread wins over the
    process-wide mode.
    """
    return getattr(_scope, "mode", None) or _mode


def resolved_mode(mode: str | None = None) -> str:
    """Resolve ``mode`` (default: the effective mode) to a concrete substrate.

    ``"auto"`` means: compiled Pallas on TPU, jnp references elsewhere.
    """
    mode = kernel_mode() if mode is None else validate_mode(mode)
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@contextlib.contextmanager
def kernel_mode_scope(mode: str) -> Iterator[None]:
    """Pin the mode for a dynamic extent on this thread (always restores).

    Replay executors enter this scope around lowering/tracing so the
    substrate choice is baked into the compiled executable exactly once —
    and, being thread-local, concurrent executors pinned to different
    substrates cannot race each other's choice.
    """
    prev = getattr(_scope, "mode", None)
    _scope.mode = validate_mode(mode)
    try:
        yield
    finally:
        _scope.mode = prev


# ----------------------------------------------------------------- registry

def register(op: str, mode: str, backend: str = WILDCARD,
             fn: Callable[..., Any] | None = None, doc: str = ""):
    """Register an implementation for ``(op, backend, mode)``.

    Usable directly (``register("rmsnorm", "ref", fn=impl)``) or as a
    decorator. Re-registration of the same key replaces the entry (latest
    wins), so downstream packages can override a substrate.
    """
    if mode not in SUBSTRATES:
        raise ValueError(
            f"cannot register mode {mode!r} for op {op!r}: expected one of "
            f"{SUBSTRATES} ('auto' is a resolution rule, not a substrate)")

    def _do(f: Callable[..., Any]) -> Callable[..., Any]:
        impl = KernelImpl(op=op, backend=backend, mode=mode, fn=f,
                          doc=doc or (f.__doc__ or "").strip().split("\n")[0])
        with _lock:
            _impls[(op, backend, mode)] = impl
        return f

    return _do(fn) if fn is not None else _do


def resolve(op: str, mode: str | None = None,
            backend: str | None = None) -> KernelImpl:
    """Look up the implementation for ``op`` under ``mode`` on ``backend``.

    ``mode=None`` uses the global mode; ``"auto"`` resolves per platform.
    Exact ``(op, backend, mode)`` entries win over ``(op, "*", mode)``.
    """
    concrete = resolved_mode(mode)
    backend = backend or jax.default_backend()
    with _lock:
        impl = (_impls.get((op, backend, concrete))
                or _impls.get((op, WILDCARD, concrete)))
        if impl is not None:
            return impl
        known_ops = sorted({k[0] for k in _impls})
        alts = sorted(f"{k[1]}/{k[2]}" for k in _impls if k[0] == op)
    if not alts:
        raise KeyError(f"unknown kernel op {op!r}; registered ops: {known_ops}")
    raise KeyError(
        f"no implementation of {op!r} for backend={backend!r} "
        f"mode={concrete!r}; available (backend/mode): {alts}")


def dispatch(op: str, *args: Any, mode: str | None = None, **kwargs: Any) -> Any:
    """Resolve and call in one step — the hot-path entry used by ``ops``.

    Equivalent to ``resolve(op, mode=mode)(*args, **kwargs)``; raises
    ``KeyError`` (with the registered alternatives) when no implementation
    matches the effective backend/mode.
    """
    return resolve(op, mode=mode)(*args, **kwargs)


def ops() -> list[str]:
    """Sorted list of registered op names."""
    with _lock:
        return sorted({k[0] for k in _impls})


def substrates(op: str) -> list[tuple[str, str]]:
    """Sorted ``(backend, mode)`` pairs registered for ``op``."""
    with _lock:
        return sorted((k[1], k[2]) for k in _impls if k[0] == op)
