"""Mamba-2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The SSD decomposition splits the sequential SSM recurrence into
  (1) an *intra-chunk* part — dense, attention-like matmuls of size
      (Q x N) @ (N x Q) and (Q x Q) @ (Q x P) per chunk: MXU work, and
  (2) an *inter-chunk* state recurrence over n_chunks steps — tiny,
      sequential, O(S/Q) depth.

Part (1) dominates FLOPs and is the Pallas kernel below, gridded over
(batch*heads, chunks) with everything for one chunk resident in VMEM
(Q=chunk, N=state, P=headdim all 64/128-aligned → MXU-shaped matmuls).
Part (2) plus the cross-chunk output correction stay in jnp (a
``lax.scan`` over n_chunks elements and one small einsum) — they are
bandwidth-trivial and XLA fuses them well.

Validated against ``ref.ssd_ref`` (exact sequential oracle) and
``ref.ssd_chunked_ref`` (blockwise jnp twin of this kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat


def _ssd_chunk_kernel(xs_ref, b_ref, c_ref, lda_ref,
                      y_ref, state_ref, cdecay_ref):
    """One (batch*head, chunk) cell: intra-chunk output + local end-state.

    xs  : (Q, P)  dt * x
    b,c : (Q, N)
    lda : (Q, 1)  log dA = dt * A
    out y      : (Q, P)   intra-chunk contribution
    out state  : (N, P)   chunk end-state (before inter-chunk recurrence)
    out cdecay : (1, 1)   total log-decay across the chunk
    """
    xs = xs_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)
    lda = lda_ref[0].astype(jnp.float32)          # (Q, 1)
    Q = xs.shape[0]

    cums = jnp.cumsum(lda, axis=0)                # (Q, 1) inclusive
    # decay(i<-j) = exp(cums[i] - cums[j]) for j <= i
    diff = cums - cums.reshape(1, Q)              # (Q_i, Q_j)
    li = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(li >= lj, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(scores * L, xs, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q, P)
    y_ref[0] = y.astype(y_ref.dtype)

    total = cums[Q - 1:Q, :]                      # (1, 1)
    decay_to_end = jnp.exp(total - cums)          # (Q, 1)
    state = jax.lax.dot_general(b * decay_to_end, xs,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (N, P)
    state_ref[0] = state.astype(state_ref.dtype)
    cdecay_ref[0] = total.astype(cdecay_ref.dtype)


def ssd_intra_chunk(xs, b, c, lda, *, chunk: int, interpret: bool = False):
    """Pallas-gridded intra-chunk pass.

    xs: (BH, S, P); b, c: (BH, S, N); lda: (BH, S, 1). S % chunk == 0.
    Returns (y_intra (BH,S,P), state_local (BH,nc,N,P), cdecay (BH,nc,1,1)).
    """
    BH, S, P = xs.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    grid = (BH, nc)
    seq_map = lambda h, c_: (h, c_, 0)
    out = compat.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), seq_map),
            pl.BlockSpec((1, chunk, N), seq_map),
            pl.BlockSpec((1, chunk, N), seq_map),
            pl.BlockSpec((1, chunk, 1), seq_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), seq_map),
            pl.BlockSpec((1, N, P), lambda h, c_: (h * nc + c_, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda h, c_: (h * nc + c_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH * nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((BH * nc, 1, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="ssd_intra_chunk",
    )(xs, b, c, lda)
    y_intra, state_local, cdecay = out
    return (y_intra,
            state_local.reshape(BH, nc, N, P),
            cdecay.reshape(BH, nc, 1, 1))


def ssd(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H)
    A: jax.Array,     # (H,)
    Bm: jax.Array,    # (B, S, G, N)
    Cm: jax.Array,    # (B, S, G, N)
    D: jax.Array | None = None,
    init_state: jax.Array | None = None,   # (B, H, P, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Full SSD: Pallas intra-chunk + jnp inter-chunk. Matches ``ref.ssd_ref``."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    # layout: (B, S, H, *) -> (B*H, S, *)
    def to_bh(t, d):
        return jnp.moveaxis(t, 2, 1).reshape(Bsz * H, S, d)

    dtf = dt.astype(jnp.float32)
    xs = to_bh(x.astype(jnp.float32) * dtf[..., None], P)
    Bh = to_bh(jnp.repeat(Bm, rep, axis=2).astype(jnp.float32), N)
    Ch = to_bh(jnp.repeat(Cm, rep, axis=2).astype(jnp.float32), N)
    lda = to_bh((dtf * A[None, None, :])[..., None], 1)

    y_intra, state_local, cdecay = ssd_intra_chunk(
        xs, Bh, Ch, lda, chunk=chunk, interpret=interpret)

    # inter-chunk recurrence (tiny: nc sequential steps over (BH, N, P))
    h0 = (jnp.zeros((Bsz * H, N, P), jnp.float32) if init_state is None
          else jnp.swapaxes(init_state.astype(jnp.float32), 2, 3)
          .reshape(Bsz * H, N, P))
    cd = jnp.exp(cdecay[..., 0, 0])                     # (BH, nc)

    def step(h, inp):
        cd_c, sl_c = inp                                # (BH,), (BH, N, P)
        h_prev = h
        h = cd_c[:, None, None] * h + sl_c
        return h, h_prev

    hT, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(cd, 1, 0), jnp.moveaxis(state_local, 1, 0)))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                # (BH, nc, N, P)

    # cross-chunk output: y[i] += exp(cums[i]) * C[i] @ h_prev(chunk(i))
    cums = jnp.cumsum(lda.reshape(Bsz * H, nc, chunk, 1), axis=2)
    c_c = Ch.reshape(Bsz * H, nc, chunk, N)
    y_inter = jnp.einsum("zcin,zcnp,zci->zcip", c_c, h_prev,
                         jnp.exp(cums[..., 0]))
    y = y_intra + y_inter.reshape(Bsz * H, S, P)

    y = jnp.moveaxis(y.reshape(Bsz, H, S, P), 1, 2)     # (B, S, H, P)
    if D is not None:
        y = y + D[None, None, :, None] * x.astype(jnp.float32)
    hT = jnp.swapaxes(hT.reshape(Bsz, H, N, P), 2, 3)   # (B, H, P, N)
    return y.astype(x.dtype), hT
