"""Version-adaptive Pallas compatibility layer (the kernel substrate shim).

JAX renames and reshuffles the Pallas TPU surface between releases: the TPU
compiler-params class has been spelled both ``pltpu.TPUCompilerParams``
(jax<=0.4.x) and ``pltpu.CompilerParams`` (newer), ``pl.pallas_call`` gains
and loses optional keywords (``name``, ``cost_estimate``, ``backend``), and
interpret mode has moved between a keyword and a context manager. Before this
module existed, every kernel in this package hard-coded one spelling, so a
single upstream rename broke all four kernels at once (32 red tests on
jax 0.4.37).

This shim centralizes every such decision behind feature detection —
``getattr`` / signature inspection only, never version-string parsing — so
the next rename is absorbed here, in one file:

* :func:`tpu_compiler_params` builds the TPU compiler-params object under
  whatever name this JAX exports, silently dropping hint fields the local
  class does not know about (they are scheduling hints, never semantics).
* :func:`pallas_call` wraps ``pl.pallas_call``, forwarding only the optional
  keywords the installed signature accepts and resolving interpret-mode
  execution (keyword if available, context-manager fallback otherwise).
* :func:`vmem` allocates VMEM scratch under the local spelling.
* :func:`interpret_supported` / :func:`tpu_available` answer capability
  questions for the registry's mode resolution.

Kernels in this package must not import ``jax.experimental.pallas.tpu``
directly for anything this module provides; ``grep pltpu.CompilerParams``
outside this file should stay empty. See ``docs/kernels.md`` for how this
shim and the ``(op, backend, mode)`` registry fit together.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Sequence

import jax
from jax.experimental import pallas as pl

try:  # the TPU dialect may be absent on exotic builds; kernels then run
    from jax.experimental.pallas import tpu as pltpu  # interpret-only.
except Exception:  # pragma: no cover - import guard
    pltpu = None  # type: ignore[assignment]


def _first_attr(mod: Any, *names: str) -> Any:
    """Return the first attribute of ``mod`` that exists, else None."""
    if mod is None:
        return None
    for name in names:
        obj = getattr(mod, name, None)
        if obj is not None:
            return obj
    return None


# Newer JAX spells it CompilerParams; 0.4.x spells it TPUCompilerParams.
_COMPILER_PARAMS_CLS = _first_attr(pltpu, "CompilerParams", "TPUCompilerParams")
_VMEM = _first_attr(pltpu, "VMEM")
_FORCE_INTERPRET = _first_attr(pltpu, "force_tpu_interpret_mode")
_PALLAS_CALL_PARAMS = frozenset(inspect.signature(pl.pallas_call).parameters)

# Optional keywords that are pure hints: safe to drop when the installed
# pallas_call does not accept them. Structural kwargs (grid, in_specs, ...)
# are always forwarded so a genuinely incompatible JAX fails loudly.
_DROPPABLE = ("compiler_params", "name", "cost_estimate", "backend", "debug")


def _accepted_fields(cls: Any) -> frozenset[str]:
    if dataclasses.is_dataclass(cls):
        return frozenset(f.name for f in dataclasses.fields(cls))
    try:
        return frozenset(inspect.signature(cls).parameters)
    except (TypeError, ValueError):  # pragma: no cover - exotic class
        return frozenset()


def has_tpu_compiler_params() -> bool:
    """True if this JAX exports a TPU compiler-params class at all."""
    return _COMPILER_PARAMS_CLS is not None


def tpu_available() -> bool:
    """True if the default JAX backend is a real TPU."""
    return jax.default_backend() == "tpu"


def interpret_supported() -> bool:
    """True if interpret-mode execution is reachable on this JAX."""
    return "interpret" in _PALLAS_CALL_PARAMS or _FORCE_INTERPRET is not None


def tpu_compiler_params(
    *, dimension_semantics: Sequence[str] | None = None, **hints: Any
) -> Any:
    """Build TPU compiler params under whatever name this JAX exports.

    Returns an instance of ``pltpu.CompilerParams`` / ``pltpu.TPUCompilerParams``
    (whichever exists), or ``None`` when the class is unavailable — the hints
    only steer Mosaic scheduling, so omitting them is always semantically
    safe. Hint fields the local class does not recognize are dropped rather
    than raising, which is what lets one kernel source span JAX versions with
    different hint vocabularies.
    """
    cls = _COMPILER_PARAMS_CLS
    if cls is None:
        return None
    kw = dict(hints)
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    fields = _accepted_fields(cls)
    if fields:
        kw = {k: v for k, v in kw.items() if k in fields}
    try:
        return cls(**kw)
    except TypeError:  # pragma: no cover - field-introspection miss
        return None


def vmem(shape: Sequence[int], dtype: Any) -> Any:
    """Allocate a VMEM scratch shape under the local spelling."""
    if _VMEM is None:  # pragma: no cover - TPU dialect absent
        raise NotImplementedError(
            "this JAX build exposes no pallas TPU VMEM scratch; kernels "
            "needing scratch cannot run here (use the 'ref' substrate)")
    return _VMEM(tuple(shape), dtype)


def pallas_call(
    kernel: Callable[..., None],
    *,
    interpret: bool = False,
    **kwargs: Any,
) -> Callable[..., Any]:
    """``pl.pallas_call`` with version differences resolved.

    * optional hint kwargs (``compiler_params``, ``name``, ...) are forwarded
      only when the installed signature accepts them, and skipped when None;
    * ``interpret=True`` uses the keyword when available, else falls back to
      the ``force_tpu_interpret_mode`` context manager, else raises a clear
      error instead of a deep Mosaic lowering failure.
    """
    kw = dict(kwargs)
    for key in _DROPPABLE:
        if key in kw and (kw[key] is None or key not in _PALLAS_CALL_PARAMS):
            del kw[key]

    if "interpret" in _PALLAS_CALL_PARAMS:
        return pl.pallas_call(kernel, interpret=interpret, **kw)
    inner = pl.pallas_call(kernel, **kw)
    if not interpret:
        return inner
    if _FORCE_INTERPRET is None:  # pragma: no cover - no interpret path
        raise NotImplementedError(
            "interpret-mode pallas execution is unavailable on this JAX "
            "(no interpret= kwarg and no force_tpu_interpret_mode)")

    def run_interpreted(*args: Any) -> Any:  # pragma: no cover - old JAX only
        with _FORCE_INTERPRET():
            return inner(*args)

    return run_interpreted
