"""Pallas TPU kernels (with jnp oracles) for the performance-critical ops.

``compat`` is the version-adaptive Pallas shim (one place absorbs upstream
API renames); ``registry`` maps ``(op, backend, mode)`` to a substrate and
owns the global kernel-mode switch; ``ops`` exposes the registry-dispatched
public entry points used by models, executors, and benchmarks.
"""
from . import compat, ops, ref, registry
from .flash_attention import flash_attention
from .moe_gmm import grouped_matmul
from .rmsnorm import rmsnorm
from .ssd_scan import ssd

__all__ = ["compat", "ops", "ref", "registry",
           "flash_attention", "grouped_matmul", "rmsnorm", "ssd"]
