"""Pallas TPU kernels (with jnp oracles) for the performance-critical ops."""
from . import ops, ref
from .flash_attention import flash_attention
from .moe_gmm import grouped_matmul
from .rmsnorm import rmsnorm
from .ssd_scan import ssd

__all__ = ["ops", "ref", "flash_attention", "grouped_matmul", "rmsnorm", "ssd"]
