"""Flash attention for TPU in Pallas (forward).

TPU-native tiling: the grid is (batch*heads, q_blocks, kv_blocks) with the
kv dimension innermost ("arbitrary" semantics) so the online-softmax
accumulators live in VMEM scratch across kv steps. Block shapes default to
(block_q, head_dim) / (block_k, head_dim) with head_dim padded to a lane
multiple (128) by the caller; q/kv blocks are MXU-aligned multiples of 128.

Supports: causal masking, sliding-window (Hymba), chunked-local attention
(Llama-4 iRoPE local layers), GQA (kv-head broadcast done in the k/v
BlockSpec index maps — no repeated kv materialization in HBM), and a
query-position offset for decode. Masked-out kv blocks are *skipped*
(``pl.when``) so causal attention does ~half the matmul work, window/chunked
attention O(S·w) work — the same block-skipping that makes these kernels
sub-quadratic on real hardware.

Validated on CPU with interpret=True against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat
from . import ref as _ref

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_LANES = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               chunk: int | None, block_q: int, block_k: int,
               kv_len: int, q_offset: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i = pl.program_id(1)
    q_start = i * block_q + q_offset        # absolute position of first query
    k_start = j * block_k

    # Block-level reachability: can any (q, k) pair in this tile attend?
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + block_q - 1
    if window is not None:
        needed &= (k_start + block_k - 1) > (q_start - window)
    if chunk is not None:
        # chunk-index ranges of the two tiles must overlap
        q_c0, q_c1 = q_start // chunk, (q_start + block_q - 1) // chunk
        k_c0, k_c1 = k_start // chunk, (k_start + block_k - 1) // chunk
        needed &= jnp.maximum(q_c0, k_c0) <= jnp.minimum(q_c1, k_c1)

    @pl.when(needed)
    def _body():
        q = q_ref[0].astype(jnp.float32)    # (block_q, d)
        k = k_ref[0].astype(jnp.float32)    # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len                # tail padding
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        if chunk is not None:
            mask &= (qpos // chunk) == (kpos // chunk)
        s = jnp.where(mask, s, _ref.NEG_INF)

        m_prev = m_scr[:, :1]                              # (bq, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True),
            l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_cur, m_scr.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)     # fully-masked rows -> 0 output
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    chunk: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    block_q = min(block_q, max(8, 1 << (Sq - 1).bit_length()))
    block_k = min(block_k, max(_LANES, 1 << (Sk - 1).bit_length()))
    sq_pad = math.ceil(Sq / block_q) * block_q
    sk_pad = math.ceil(Sk / block_k) * block_k

    # (B, S, H, D) -> (B*H, S, D); kv stays at Hkv heads, broadcast by index map
    qt = jnp.moveaxis(q, 2, 1).reshape(B * Hq, Sq, D)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Sk, D)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Sk, D)
    if sq_pad != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, sq_pad - Sq), (0, 0)))
    if sk_pad != Sk:
        kt = jnp.pad(kt, ((0, 0), (0, sk_pad - Sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, sk_pad - Sk), (0, 0)))

    grid = (B * Hq, sq_pad // block_q, sk_pad // block_k)

    def q_map(b, i, j):
        return (b, i, 0)

    def kv_map(b, i, j):
        # GQA: query head b = bi*Hq + h attends kv head h // group
        bi = b // Hq
        h = b % Hq
        return (bi * Hkv + h // group, j, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window, chunk=chunk,
        block_q=block_q, block_k=block_k, kv_len=Sk, q_offset=q_offset)

    out = compat.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, sq_pad, D), q.dtype),
        scratch_shapes=[
            compat.vmem((block_q, _LANES), jnp.float32),  # m
            compat.vmem((block_q, _LANES), jnp.float32),  # l
            compat.vmem((block_q, D), jnp.float32),       # acc
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(qt, kt, vt)

    out = out[:, :Sq].reshape(B, Hq, Sq, D)
    return jnp.moveaxis(out, 1, 2)
