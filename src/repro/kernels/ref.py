"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical ground truth: simple, obviously-correct
implementations used (a) by kernel tests (``assert_allclose`` against the
Pallas kernels in interpret mode, sweeping shapes/dtypes) and (b) as the
default compute path on CPU, where Pallas TPU kernels only run interpreted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free


# ---------------------------------------------------------------------------
# Attention (full / causal / sliding-window / chunked-local, GQA)
# ---------------------------------------------------------------------------

def attention_ref(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,        # sliding window size (keys kept per query)
    chunk: int | None = None,         # chunked-local attention (llama4 iRoPE style)
    scale: float | None = None,
    q_offset: int = 0,                # absolute position of q[0] (decode steps)
) -> jax.Array:
    """Reference scaled-dot-product attention with GQA broadcast."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    kf = jnp.repeat(k, group, axis=2)  # (B, Sk, Hq, D)
    vf = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale

    qpos = q_offset + jnp.arange(Sq)[:, None]   # (Sq, 1)
    kpos = jnp.arange(Sk)[None, :]              # (1, Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    if chunk is not None:
        mask &= (qpos // chunk) == (kpos // chunk)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space dual) — exact sequential-scan oracle
# ---------------------------------------------------------------------------

def ssd_ref(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H)      positive step sizes
    A: jax.Array,     # (H,)           negative decay rates
    Bm: jax.Array,    # (B, S, G, N)   input projections (G groups)
    Cm: jax.Array,    # (B, S, G, N)   output projections
    D: jax.Array | None = None,   # (H,) skip
    init_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential SSM recurrence: h[t] = exp(dt*A) h[t-1] + dt*B[t] x[t];
    y[t] = C[t] . h[t] (+ D x[t]). Returns (y, final_state)."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B, S, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, None, :])               # (B, S, H)
    dBx = jnp.einsum("bsh,bshn,bshp->bshpn", dtf, Bh.astype(jnp.float32), xf)

    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t[..., None, None] * h + dBx_t
        y_t = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y_t

    hT, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
         jnp.moveaxis(Ch.astype(jnp.float32), 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, P)
    if D is not None:
        y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), hT.astype(jnp.float32)


def ssd_chunked_ref(x, dt, A, Bm, Cm, D=None, init_state=None, chunk: int = 64):
    """Chunked (SSD) form of the same recurrence, pure jnp — the blockwise
    algorithm the Pallas kernel implements; exactly matches ``ssd_ref``."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    if S % chunk != 0:
        # pad tail with dt=0 steps: dA=1 and dB·x=0, so state and outputs
        # for real positions are unchanged
        pad = chunk - S % chunk
        y, hT = ssd_chunked_ref(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0))),
            D=D, init_state=init_state, chunk=chunk)
        return y[:, :S], hT
    nc, Q = S // chunk, chunk

    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    lda = dtf * A[None, None, :]                       # log dA  (B, S, H)
    xs = xf * dtf[..., None]                           # dt * x

    def rs(t, last):  # reshape to chunks
        return t.reshape((Bsz, nc, Q) + last)

    lda_c = rs(lda, (H,))                              # (B, nc, Q, H)
    xs_c = rs(xs, (H, P))
    b_c = rs(Bh, (H, N))
    c_c = rs(Ch, (H, N))

    cums = jnp.cumsum(lda_c, axis=2)                   # (B, nc, Q, H)
    # intra-chunk: y[i] += (C[i].B[j]) exp(cums[i]-cums[j]) xs[j], j<=i
    decay = jnp.exp(cums[:, :, :, None] - cums[:, :, None, :, :])  # (B,nc,Qi,Qj,H)
    iota = jnp.arange(Q)
    lmask = (iota[:, None] >= iota[None, :])[None, None, :, :, None]
    L = jnp.where(lmask, decay, 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", c_c, b_c)
    y_intra = jnp.einsum("bcijh,bcijh,bcjhp->bcihp", scores, L, xs_c)

    # chunk-local end states
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)  # (B, nc, Q, H)
    state_local = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", b_c, decay_to_end, xs_c)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cums[:, :, -1, :])           # (B, nc, H)
    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(h, inp):
        cd, sl = inp
        h_prev = h
        h = cd[..., None, None] * h + sl
        return h, h_prev

    hT, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(state_local, 1, 0)))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)               # (B, nc, H, P, N) state entering chunk
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp", c_c, h_prev, jnp.exp(cums))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    if D is not None:
        y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), hT.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Grouped (per-expert) matmul — MoE expert GEMM
# ---------------------------------------------------------------------------

def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(E, C, d) @ (E, d, f) -> (E, C, f), f32 accumulation."""
    return jax.lax.dot_general(
        x, w, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused RMSNorm (+ optional residual add)
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6,
                residual: jax.Array | None = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)
