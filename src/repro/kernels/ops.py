"""Registry-driven dispatch wrappers for the performance-critical ops.

Every public function here resolves its implementation through
:mod:`repro.kernels.registry` — one table maps ``(op, backend, mode)`` to a
substrate instead of per-function if/elif chains. The substrates:

  * ``pallas``    — compiled Pallas kernels (TPU), built on the
                    version-adaptive :mod:`repro.kernels.compat` shim;
  * ``ref``       — memory-sane pure-XLA/jnp references (exact numerics,
                    the default on CPU);
  * ``interpret`` — the Pallas kernel bodies on the interpreter (CPU
                    debugging / parity testing of the real kernel code).

``set_kernel_mode(...)`` / env ``REPRO_KERNELS={auto,pallas,ref,interpret}``
pick the substrate globally (``auto`` = pallas on TPU, ref elsewhere); the
env var is validated eagerly at import. Replay executors pin the resolved
mode once at lowering time via ``registry.kernel_mode_scope``.
"""
from __future__ import annotations

import functools
from typing import Literal

from . import flash_attention as _fa
from . import moe_gmm as _gmm
from . import ref as _ref
from . import registry
from . import rmsnorm as _rms
from . import ssd_scan as _ssd
from . import xla_attention as _xla

Mode = Literal["auto", "pallas", "ref", "interpret"]

# Mode state lives in the registry; re-exported here for callers that
# predate it (tests, benchmarks, notebooks).
set_kernel_mode = registry.set_kernel_mode
kernel_mode = registry.kernel_mode


# ------------------------------------------------------------ substrates

def _attention_ref(q, k, v, *, causal=True, window=None, chunk=None,
                   scale=None, q_offset=0, q_chunk=2048):
    """Pure-XLA attention (exact numerics, bounded live scores)."""
    if not causal:
        return _xla.sdpa_cross(q, k, v, scale=scale)
    if window:
        return _xla.sdpa_sliding(q, k, v, window=window, scale=scale)
    if chunk:
        return _xla.sdpa_chunked(q, k, v, chunk=chunk, scale=scale)
    return _xla.sdpa_full(q, k, v, causal=causal, scale=scale,
                          q_offset=q_offset, chunk=q_chunk)


def _attention_pallas(q, k, v, *, causal=True, window=None, chunk=None,
                      scale=None, q_offset=0, q_chunk=2048, interpret=False):
    """Flash-attention Pallas kernel (q_chunk is a ref-path knob; unused)."""
    del q_chunk
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               chunk=chunk, scale=scale, q_offset=q_offset,
                               interpret=interpret)


def _ssd_ref(x, dt, A, Bm, Cm, D=None, init_state=None, *, chunk=128):
    """Blockwise jnp SSD (chunk clamped to the sequence length)."""
    return _ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D=D, init_state=init_state,
                                chunk=min(chunk, x.shape[1]))


def _ssd_pallas(x, dt, A, Bm, Cm, D=None, init_state=None, *, chunk=128,
                interpret=False):
    return _ssd.ssd(x, dt, A, Bm, Cm, D=D, init_state=init_state,
                    chunk=chunk, interpret=interpret)


def _gmm_pallas(x, w, *, interpret=False):
    return _gmm.grouped_matmul(x, w, interpret=interpret)


def _rmsnorm_pallas(x, w, eps=1e-6, residual=None, *, interpret=False):
    return _rms.rmsnorm(x, w, eps=eps, residual=residual, interpret=interpret)


def _register_defaults() -> None:
    """Populate the registry with this package's substrates.

    All entries are platform-wildcards: the jnp references and the
    interpreter run anywhere, and an explicit mode="pallas" off-TPU runs
    the compiled-path code too (it fails loudly in Mosaic if lowering
    breaks — useful under REPRO_KERNELS=pallas on CPU CI). A future
    GPU/Triton PR adds ``backend="gpu"`` rows here (or in its own package)
    without touching the dispatch functions below; backend-specific rows
    take precedence over these wildcards.
    """
    table = {
        "attention": (_attention_ref, _attention_pallas),
        "ssd": (_ssd_ref, _ssd_pallas),
        "grouped_matmul": (_ref.grouped_matmul_ref, _gmm_pallas),
        "rmsnorm": (_ref.rmsnorm_ref, _rmsnorm_pallas),
    }
    for op, (ref_fn, pallas_fn) in table.items():
        registry.register(op, "ref", fn=ref_fn)
        registry.register(op, "pallas", fn=pallas_fn)
        registry.register(op, "interpret",
                          fn=functools.partial(pallas_fn, interpret=True),
                          doc=f"{op} Pallas body on the interpreter")


_register_defaults()


# -------------------------------------------------------------- public ops

def attention(q, k, v, *, causal=True, window=None, chunk=None, scale=None,
              q_offset=0, q_chunk=2048):
    return registry.dispatch("attention", q, k, v, causal=causal,
                             window=window, chunk=chunk, scale=scale,
                             q_offset=q_offset, q_chunk=q_chunk)


def ssd(x, dt, A, Bm, Cm, D=None, init_state=None, *, chunk=128):
    return registry.dispatch("ssd", x, dt, A, Bm, Cm, D=D,
                             init_state=init_state, chunk=chunk)


def grouped_matmul(x, w):
    return registry.dispatch("grouped_matmul", x, w)


def rmsnorm(x, w, eps=1e-6, residual=None):
    return registry.dispatch("rmsnorm", x, w, eps=eps, residual=residual)
