"""Jit'd dispatch wrappers for the Pallas kernels.

One switch decides the backend per call site:
  * on TPU, the Pallas kernels run compiled;
  * on CPU (this container), model code uses the jnp references — identical
    numerics, XLA-fused — while kernel *tests* exercise the Pallas bodies
    via interpret=True.

``set_kernel_mode(...)`` / env ``REPRO_KERNELS={auto,pallas,ref,interpret}``
override the choice globally (used by tests/benchmarks).
"""
from __future__ import annotations

import functools
import os
from typing import Literal

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import moe_gmm as _gmm
from . import ref as _ref
from . import rmsnorm as _rms
from . import ssd_scan as _ssd
from . import xla_attention as _xla

Mode = Literal["auto", "pallas", "ref", "interpret"]
_mode: Mode = os.environ.get("REPRO_KERNELS", "auto")  # type: ignore[assignment]


def set_kernel_mode(mode: Mode) -> None:
    global _mode
    assert mode in ("auto", "pallas", "ref", "interpret"), mode
    _mode = mode


def kernel_mode() -> Mode:
    return _mode


def _resolved() -> str:
    if _mode != "auto":
        return _mode
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def attention(q, k, v, *, causal=True, window=None, chunk=None, scale=None,
              q_offset=0, q_chunk=2048):
    mode = _resolved()
    if mode == "ref":
        # memory-sane pure-XLA paths (exact numerics, bounded live scores)
        if not causal:
            return _xla.sdpa_cross(q, k, v, scale=scale)
        if window:
            return _xla.sdpa_sliding(q, k, v, window=window, scale=scale)
        if chunk:
            return _xla.sdpa_chunked(q, k, v, chunk=chunk, scale=scale)
        return _xla.sdpa_full(q, k, v, causal=causal, scale=scale,
                              q_offset=q_offset, chunk=q_chunk)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               chunk=chunk, scale=scale, q_offset=q_offset,
                               interpret=(mode == "interpret"))


def ssd(x, dt, A, Bm, Cm, D=None, init_state=None, *, chunk=128):
    mode = _resolved()
    if mode == "ref":
        return _ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D=D,
                                    init_state=init_state,
                                    chunk=min(chunk, x.shape[1]))
    return _ssd.ssd(x, dt, A, Bm, Cm, D=D, init_state=init_state,
                    chunk=chunk, interpret=(mode == "interpret"))


def grouped_matmul(x, w):
    mode = _resolved()
    if mode == "ref":
        return _ref.grouped_matmul_ref(x, w)
    return _gmm.grouped_matmul(x, w, interpret=(mode == "interpret"))


def rmsnorm(x, w, eps=1e-6, residual=None):
    mode = _resolved()
    if mode == "ref":
        return _ref.rmsnorm_ref(x, w, eps=eps, residual=residual)
    return _rms.rmsnorm(x, w, eps=eps, residual=residual,
                        interpret=(mode == "interpret"))
