"""Memory-sane pure-XLA attention (the non-Pallas compute path).

The tiny oracle in ``ref.py`` materializes (B,H,S,S) scores and repeats KV
heads — fine for tests, catastrophic at 32k+. These implementations keep
the exact numerics but bound memory and (for local patterns) FLOPs:

  * ``sdpa_full``     — lax.scan over query chunks: O(S·chunk) live scores.
                        FLOPs remain S² (causal masking, no block skip —
                        the known ~2x overcount vs flash; roofline.py
                        corrects for it analytically).
  * ``sdpa_sliding``  — block-banded: each w-block of queries attends its
                        own + previous key block: exact O(S·2w) flops+mem.
  * ``sdpa_chunked``  — block-diagonal (llama4 iRoPE local layers):
                        exact O(S·c).

All use grouped-GQA einsums (no KV repeat) and f32 softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _group(q, k):
    """(B,S,Hq,D),(B,S,Hkv,D) -> q as (B,S,Hkv,G,D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    return q.reshape(B, S, Hkv, Hq // Hkv, D)


def sdpa_full(q, k, v, *, causal: bool = True, scale: float | None = None,
              q_offset: int = 0, chunk: int = 2048) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    scale = (D ** -0.5) if scale is None else scale
    qg = _group(q, k).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    chunk = min(chunk, Sq)
    if Sq % chunk != 0:
        return _sdpa_full_once(qg, kf, vf, causal, scale, q_offset, 0, Sq).astype(q.dtype)

    # python loop over q chunks (not lax.scan): bounded live scores, exact
    # dry-run cost accounting; XLA reuses the chunk buffers across steps.
    nq = Sq // chunk
    outs = []
    for i in range(nq):
        qc = qg[:, i * chunk:(i + 1) * chunk]
        outs.append(_sdpa_full_once(qc, kf, vf, causal, scale, q_offset,
                                    i * chunk, chunk))
    out = jnp.concatenate(outs, axis=1).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def _sdpa_full_once(qg, kf, vf, causal, scale, q_offset, chunk_start, chunk_len):
    B, Sq = qg.shape[0], qg.shape[1]
    Sk = kf.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * scale
    qpos = q_offset + chunk_start + jnp.arange(chunk_len)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    if causal:
        s = jnp.where((qpos >= kpos)[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(out.shape[:2] + (-1, out.shape[-1]))


def sdpa_sliding(q, k, v, *, window: int, scale: float | None = None) -> jax.Array:
    """Causal sliding-window attention, block-banded (exact O(S·2w))."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    scale = (D ** -0.5) if scale is None else scale
    w = window
    if S % w != 0 or S <= w:
        # small/ragged: single band via full path with window mask
        return _sdpa_masked_small(q, k, v, scale, window=w)
    nb = S // w
    qg = _group(q, k).astype(jnp.float32).reshape(B, nb, w, Hkv, Hq // Hkv, D)
    kb = k.astype(jnp.float32).reshape(B, nb, w, Hkv, D)
    vb = v.astype(jnp.float32).reshape(B, nb, w, Hkv, D)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)           # (B, nb, 2w, Hkv, D)
    v2 = jnp.concatenate([vprev, vb], axis=2)

    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qg, k2) * scale
    qpos = jnp.arange(w)[:, None] + w                   # within the 2w frame
    kpos = jnp.arange(2 * w)[None, :]
    base = (qpos >= kpos) & ((qpos - kpos) < w)         # (w, 2w)
    first = base & (kpos >= w)                          # block 0 has no prev
    mask = jnp.where((jnp.arange(nb) == 0)[:, None, None],
                     first[None], base[None])           # (nb, w, 2w)
    s = jnp.where(mask[None, :, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, v2)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def sdpa_chunked(q, k, v, *, chunk: int, scale: float | None = None) -> jax.Array:
    """Causal block-diagonal (chunked-local) attention: exact O(S·c)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    scale = (D ** -0.5) if scale is None else scale
    c = chunk
    if S % c != 0 or S <= c:
        return _sdpa_masked_small(q, k, v, scale, chunk=c)
    nb = S // c
    qg = _group(q, k).astype(jnp.float32).reshape(B, nb, c, Hkv, Hq // Hkv, D)
    kb = k.astype(jnp.float32).reshape(B, nb, c, Hkv, D)
    vb = v.astype(jnp.float32).reshape(B, nb, c, Hkv, D)
    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qg, kb) * scale
    i = jnp.arange(c)
    mask = i[:, None] >= i[None, :]
    s = jnp.where(mask[None, None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, vb)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def _sdpa_masked_small(q, k, v, scale, window: int | None = None,
                       chunk: int | None = None):
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    qg = _group(q, k).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    if chunk:
        mask &= (qpos // chunk) == (kpos // chunk)
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def sdpa_cross(q, k, v, *, scale: float | None = None) -> jax.Array:
    """Non-causal (encoder / cross) attention, grouped-GQA."""
    B, Sq, Hq, D = q.shape
    scale = (D ** -0.5) if scale is None else scale
    qg = _group(q, k).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)
