"""Fused RMSNorm (+ optional residual add) — Pallas TPU kernel.

One VMEM round-trip instead of three (add, norm, scale): rows are tiled
(block_rows, d) with d resident, matching the (8k..) token-major layouts of
the model stack. Hot in every block (2 norms/layer), bandwidth-bound.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rmsnorm_res_kernel(x_ref, r_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
            residual: jax.Array | None = None, *, block_rows: int = 256,
            interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, max(8, 1 << (n - 1).bit_length()))
    n_pad = math.ceil(n / block_rows) * block_rows
    pad = n_pad - n
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    row_map = lambda i: (i, 0)
    w_map = lambda i: (0, 0)
    common = dict(
        grid=(n_pad // block_rows,),
        out_specs=pl.BlockSpec((block_rows, d), row_map),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="rmsnorm",
    )
    if residual is None:
        out = compat.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            in_specs=[pl.BlockSpec((block_rows, d), row_map),
                      pl.BlockSpec((1, d), w_map)],
            **common,
        )(xf, w.reshape(1, d))
    else:
        rf = residual.reshape(-1, d)
        if pad:
            rf = jnp.pad(rf, ((0, pad), (0, 0)))
        out = compat.pallas_call(
            functools.partial(_rmsnorm_res_kernel, eps=eps),
            in_specs=[pl.BlockSpec((block_rows, d), row_map),
                      pl.BlockSpec((block_rows, d), row_map),
                      pl.BlockSpec((1, d), w_map)],
            **common,
        )(xf, rf, w.reshape(1, d))
    return out[:n].reshape(orig_shape)
