"""ShapeDtypeStruct stand-ins + shardings for every model input.

Nothing here allocates: params/opt-state/caches/batches are built with
``jax.eval_shape`` and annotated with NamedShardings so ``jit(...).lower()``
sees the exact production layout.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import model as M
from ..optim.adamw import Optimizer
from ..sharding import partition as P_


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh: Mesh) -> int:
    s = 1
    for a in _dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def batch_sharding(mesh: Mesh, batch: int, extra_dims: int) -> NamedSharding:
    dp = _dp_axes(mesh)
    if batch % max(_dp_size(mesh), 1) != 0:
        dp = ()
    spec = P(dp if dp else None, *([None] * extra_dims))
    return NamedSharding(mesh, spec)


def with_sharding(tree, shardings):
    return jax.tree_util.tree_map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        tree, shardings)


def param_specs(cfg: ModelConfig, mesh: Mesh):
    """(abstract params, shardings) without allocating."""
    sds = jax.eval_shape(functools.partial(M.init_params, cfg),
                         jax.random.PRNGKey(0))
    sh = P_.param_shardings(sds, mesh)
    return with_sharding(sds, sh), sh


def opt_specs(cfg: ModelConfig, mesh: Mesh, optimizer: Optimizer, params_sds):
    sds = jax.eval_shape(optimizer.init, params_sds)
    sh = P_.param_shardings(sds, mesh)
    return with_sharding(sds, sh), sh


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    B, S = shape.global_batch, shape.seq_len
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                sharding=batch_sharding(mesh, B, 1))
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=batch_sharding(mesh, B, 2))
    return batch


def _cache_sharding(mesh: Mesh, shape: tuple, batch: int,
                    shard_dims: dict[int, str]) -> NamedSharding:
    """Shard dim0 (batch) over dp when divisible; named dims over model
    when divisible (first divisible one wins — one use per mesh axis)."""
    axes: list = [None] * len(shape)
    dp = _dp_axes(mesh)
    if dp and batch % _dp_size(mesh) == 0:
        axes[0] = dp if len(dp) > 1 else dp[0]
    msize = _model_size(mesh)
    for dim, _name in shard_dims.items():
        if ("model" in mesh.axis_names and shape[dim] % msize == 0
                and "model" not in axes):
            axes[dim] = "model"
    return NamedSharding(mesh, P(*axes))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Abstract per-layer caches with shardings (decode shapes).

    Default: shard kv-heads over "model" when divisible. With
    ``cfg.shard_kv_seq`` (beyond-paper §Perf iteration 2), the cache
    LENGTH dim is sharded over "model" instead — for MHA-style archs whose
    head count doesn't divide the TP axis, this turns the per-step cache
    read from fully-replicated into 1/TP per device.
    """
    B, S = shape.global_batch, shape.seq_len
    msize = _model_size(mesh)
    sds = jax.eval_shape(lambda: M.init_caches(cfg, B, S))

    def shard_leaf(path, leaf):
        names = P_._path_names(path)
        if names[-1] in ("k", "v"):
            dims = {2: "kv_heads"}
            if cfg.shard_kv_seq and leaf.shape[2] % msize != 0:
                dims = {1: "kv_seq"}
            sh = _cache_sharding(mesh, leaf.shape, B, dims)
        elif names[-1] == "pos":
            dims = {}
            if cfg.shard_kv_seq and cfg.num_kv_heads % msize != 0:
                dims = {1: "kv_seq"}
            sh = _cache_sharding(mesh, leaf.shape, B, dims)
        elif names[-1] == "ssd":
            sh = _cache_sharding(mesh, leaf.shape, B, {1: "heads"})
        elif names[-1] == "conv":
            sh = _cache_sharding(mesh, leaf.shape, B, {2: "ch"})
        else:
            sh = _cache_sharding(mesh, leaf.shape, B, {})
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    return jax.tree_util.tree_map_with_path(shard_leaf, sds)


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    B = shape.global_batch
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                sharding=batch_sharding(mesh, B, 1))
    pos = jax.ShapeDtypeStruct((B,), jnp.int32,
                               sharding=batch_sharding(mesh, B, 0))
    return toks, pos, cache_specs(cfg, shape, mesh)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                optimizer: Optimizer | None = None) -> dict[str, Any]:
    """Everything ``dryrun`` needs for one (arch, shape, mesh) cell."""
    params_sds, params_sh = param_specs(cfg, mesh)
    out = {"params": params_sds, "params_sharding": params_sh}
    if shape.kind == "train":
        assert optimizer is not None
        opt_sds, opt_sh = opt_specs(cfg, mesh, optimizer, params_sds)
        out.update(opt_state=opt_sds, opt_sharding=opt_sh,
                   batch=train_batch_specs(cfg, shape, mesh))
    elif shape.kind == "prefill":
        out.update(batch=train_batch_specs(cfg, shape, mesh))
    else:  # decode
        toks, pos, caches = decode_input_specs(cfg, shape, mesh)
        out.update(tokens=toks, pos=pos, caches=caches)
        if cfg.family == "encdec":
            out["batch"] = train_batch_specs(cfg, shape, mesh)
    return out
