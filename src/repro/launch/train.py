"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 100 --batch 8 --seq 128

Wires together every substrate: config -> data pipeline -> model ->
optimizer (cosine or WSD) -> Taskgraph record/replay of the train step ->
async checkpointing -> fault-tolerant supervisor. ``--smoke`` uses the
reduced same-family config (CPU-runnable); omit it on real hardware.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..checkpoint import Checkpointer
from ..configs import ARCHS, get_config, reduced
from ..data import DataConfig, make_loader
from ..models import init_params, param_count
from ..optim import adamw, warmup_cosine, wsd
from ..runtime import RunState, StragglerPolicy, run_with_recovery
from ..sharding import partition as P_
from ..training import make_train_step
from .mesh import make_small_mesh


def build(arch: str, smoke: bool, seq: int, batch: int, steps: int,
          lr: float, schedule: str):
    cfg = get_config(arch)
    if smoke:
        cfg = reduced(cfg, num_layers=4, d_model=128, d_ff=256,
                      vocab_size=512, scan_layers=False)
    cfg = dataclasses.replace(cfg, loss_chunk=0)
    if schedule == "wsd" or (schedule == "auto" and arch == "minicpm-2b"):
        lr_fn = wsd(lr, max(steps // 10, 1), int(steps * 0.7),
                    max(int(steps * 0.2), 1))
    else:
        lr_fn = warmup_cosine(lr, max(steps // 10, 1), steps)
    optimizer = adamw(lr_fn)
    return cfg, optimizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["auto", "cosine", "wsd"],
                    default="auto")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, optimizer = build(args.arch, args.smoke, args.seq, args.batch,
                           args.steps, args.lr, args.schedule)
    print(f"arch={cfg.name} family={cfg.family}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    print(f"params: {param_count(params):,}")
    opt_state = optimizer.init(params)

    step_fn_raw = jax.jit(make_train_step(cfg, optimizer),
                          donate_argnums=(0, 1))

    def step_fn(state: RunState, batch):
        b = {"tokens": jnp.asarray(batch["tokens"])}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros(
                (b["tokens"].shape[0], cfg.encoder_seq, cfg.d_model),
                cfg.compute_dtype)
        p, s, metrics = step_fn_raw(state.params, state.opt_state, b)
        return RunState(p, s, state.step), metrics

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    ckpt = Checkpointer(args.ckpt_dir)
    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.3f}",
                  flush=True)

    t0 = time.time()
    state, report = run_with_recovery(
        step_fn, RunState(params, opt_state, 0),
        data_iter_factory=lambda s: make_loader(dcfg, s),
        num_steps=args.steps, checkpointer=ckpt,
        checkpoint_every=args.ckpt_every, on_metrics=on_metrics,
        straggler_policy=StragglerPolicy())
    dt = time.time() - t0
    first = sum(losses[:5]) / max(len(losses[:5]), 1)
    last = sum(losses[-5:]) / max(len(losses[-5:]), 1)
    print(f"done: {report}  wall={dt:.1f}s  "
          f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "loss did not improve"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
