import os

# The 512-fake-device XLA_FLAGS override MUST be set before any jax import
# (jax locks the device count at first init) — but ONLY when this module is
# the program (`python -m repro.launch.dryrun`) or explicitly asked for via
# REPRO_DRYRUN_DEVICES: merely importing a symbol from here must never
# silently reconfigure jax for the whole process.
if __name__ == "__main__" or os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        f"{os.environ.get('REPRO_DRYRUN_DEVICES') or 512}")
DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact under ``dryrun_artifacts/`` with
  * memory_analysis  (per-device bytes: argument/output/temp/peak)
  * cost_analysis    (HLO FLOPs / bytes accessed)
  * collective bytes (parsed from the post-SPMD HLO: all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)
  * derived roofline terms (compute / memory / collective seconds)

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-370m \
          --shape train_4k [--multi-pod] [--all] [--opt key=val ...]
"""

import argparse
import functools
import json
import re
import time
import dataclasses
import pathlib
import sys

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from ..configs.base import ModelConfig, ShapeConfig
from ..models import model as M
from ..optim.adamw import adamw as _adamw
from ..sharding import partition as P_
from ..training.step import make_train_step, make_serve_step
from . import specs as SP
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "dryrun_artifacts"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def collective_stats(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the partitioned HLO.

    Per-op link-byte factors (ring algorithms, (n-1)/n ~ 1):
      all-reduce ~ 2x payload (reduce-scatter + all-gather phases);
      others ~ 1x.
    """
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    link_bytes = 0
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        _lhs, rhs = line.strip().split(" = ", 1)
        m = _COLL_RE.search(rhs)
        if not m or m.start() == 0:
            continue  # opcode must follow the output shape
        base = m.group(1)
        out_bytes = _shape_bytes(rhs[:m.start()])
        stats[base]["count"] += 1
        stats[base]["bytes"] += out_bytes
        link_bytes += out_bytes * (2 if base == "all-reduce" else 1)
    stats["link_bytes"] = link_bytes
    return stats


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals", "bytes accessed")
                or k.startswith("bytes accessed"))}


def apply_overrides(cfg: ModelConfig, opts: dict) -> ModelConfig:
    if not opts:
        return cfg
    coerced = {}
    for k, v in opts.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            coerced[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            coerced[k] = int(v)
        elif isinstance(cur, float):
            coerced[k] = float(v)
        else:
            coerced[k] = v
    return dataclasses.replace(cfg, **coerced)


def _lower_and_compile(cfg: ModelConfig, shape: ShapeConfig, mesh, rules=None):
    """One lower+compile of the given config/shape on the mesh. Returns
    (lowered, compiled, timings)."""
    t0 = time.time()
    with P_.use_mesh(mesh, rules):
        if shape.kind == "train":
            optimizer = _adamw(1e-4)
            sp = SP.input_specs(cfg, shape, mesh, optimizer)
            step = make_train_step(cfg, optimizer)
            jitted = jax.jit(
                step,
                in_shardings=(sp["params_sharding"], sp["opt_sharding"],
                              jax.tree_util.tree_map(lambda x: x.sharding,
                                                     sp["batch"])),
                donate_argnums=(0, 1))
            lowered = jitted.lower(sp["params"], sp["opt_state"], sp["batch"])
        elif shape.kind == "prefill":
            sp = SP.input_specs(cfg, shape, mesh)

            def prefill_fn(params, batch):
                logits, caches, pos = M.prefill(params, cfg, batch,
                                                max_len=shape.seq_len)
                return logits, caches, pos

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(sp["params_sharding"],
                              jax.tree_util.tree_map(lambda x: x.sharding,
                                                     sp["batch"])))
            lowered = jitted.lower(sp["params"], sp["batch"])
        else:  # decode
            sp = SP.input_specs(cfg, shape, mesh)
            serve = make_serve_step(cfg)
            jitted = jax.jit(
                serve,
                in_shardings=(sp["params_sharding"],
                              sp["tokens"].sharding, sp["pos"].sharding,
                              jax.tree_util.tree_map(lambda x: x.sharding,
                                                     sp["caches"])),
                donate_argnums=(3,))
            lowered = jitted.lower(sp["params"], sp["tokens"], sp["pos"],
                                   sp["caches"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return lowered, compiled, {"lower_s": round(t_lower, 2),
                               "compile_s": round(t_compile, 2)}


def _measure(compiled) -> dict:
    mem = _mem_dict(compiled)
    cost = _cost_dict(compiled)
    coll = collective_stats(compiled.as_text())
    return {"memory": mem, "cost": cost, "collectives": coll,
            "flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "link_bytes": coll["link_bytes"]}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               opts: dict | None = None, mesh=None, save: bool = True,
               rules=None) -> dict:
    """Dry-run one (arch, shape, mesh) cell.

    Train cells: (a) full-depth scan-mode compile — proves the production
    config lowers/compiles and gives full-depth memory analysis; (b) two
    reduced-depth UNROLLED compiles (L=g and L=2g layers) whose cost delta
    gives the exact per-layer FLOPs/bytes/collective bytes (lax.scan bodies
    are counted once by XLA cost analysis, so scan-mode numbers undercount);
    costs are linearly extrapolated to full depth. Prefill/decode cells are
    fully unrolled already -> exact without extrapolation.
    """
    opts = dict(opts or {})
    rules_tag = opts.pop("_rules", None)
    cfg = apply_overrides(get_config(arch), opts)
    if rules_tag is not None:
        opts["_rules"] = rules_tag   # keep in artifact tag/record
    shape = SHAPES[shape_name]
    if shape.kind == "prefill":
        # larger q-chunks at 32k keep the unrolled HLO compact (compile time;
        # total FLOPs/bytes are chunking-invariant, only live temps grow)
        cfg = dataclasses.replace(
            cfg, attn_q_chunk=max(cfg.attn_q_chunk, 8192))
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)

    timings: dict = {}
    if shape.kind == "train":
        full_cfg = dataclasses.replace(cfg, scan_layers=True)
        _, compiled, t = _lower_and_compile(full_cfg, shape, mesh, rules)
        timings["full_scan"] = t
        m_full = _measure(compiled)
        del compiled
        g = max(cfg.global_attn_every, 1)
        if cfg.family == "encdec":
            small = lambda L: dataclasses.replace(
                cfg, scan_layers=False, num_layers=L, encoder_layers=L)
        else:
            small = lambda L: dataclasses.replace(
                cfg, scan_layers=False, num_layers=L)
        _, c1, t1 = _lower_and_compile(small(g), shape, mesh, rules)
        timings["unroll_g"] = t1
        m1 = _measure(c1)
        del c1
        _, c2, t2 = _lower_and_compile(small(2 * g), shape, mesh, rules)
        timings["unroll_2g"] = t2
        m2 = _measure(c2)
        del c2
        L = cfg.num_layers
        def extrap(key):
            slope = (m2[key] - m1[key]) / g          # per layer
            return m2[key] + (L - 2 * g) * slope
        flops_total = extrap("flops")
        bytes_total = extrap("bytes")
        link_bytes = extrap("link_bytes")
        mem = m_full["memory"]
        cost_mode = "extrapolated_exact"
        coll = {"scan_mode": m_full["collectives"],
                "unrolled_2g": m2["collectives"]}
    else:
        _, compiled, t = _lower_and_compile(cfg, shape, mesh, rules)
        timings["full_unrolled"] = t
        m = _measure(compiled)
        del compiled
        flops_total, bytes_total, link_bytes = m["flops"], m["bytes"], m["link_bytes"]
        mem = m["memory"]
        coll = m["collectives"]
        cost_mode = "exact"

    compute_s = flops_total / PEAK_FLOPS_BF16
    memory_s = bytes_total / HBM_BW
    collective_s = link_bytes / ICI_BW

    training = shape.kind == "train"
    decode = shape.kind == "decode"
    model_flops = (cfg.model_flops_per_token(shape.seq_len, training=training,
                                             decode=decode)
                   * shape.tokens_per_step)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "chips": n_chips,
        "opts": opts or {},
        "timings": timings,
        "cost_mode": cost_mode,
        "memory": mem,
        "collectives": coll,
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "hlo_flops_per_device": flops_total,
            "hlo_bytes_per_device": bytes_total,
            "link_bytes_per_device": link_bytes,
            "model_flops_global": model_flops,
            "model_flops_per_device": model_flops / n_chips,
            "useful_flop_ratio": (model_flops / n_chips) / flops_total
            if flops_total else None,
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if save:
        ART_DIR.mkdir(exist_ok=True)
        tag = "" if not opts else "_opt-" + "-".join(
            f"{k}={v}" for k, v in sorted((opts or {}).items()))
        fname = ART_DIR / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
        fname.write_text(json.dumps(result, indent=1))
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every applicable cell")
    ap.add_argument("--opt", action="append", default=[],
                    help="config override key=value (perf iterations)")
    ap.add_argument("--rules", choices=["default", "no_ssm_fsdp",
                                        "ssm_dp_only"],
                    default="default",
                    help="partition rule table (perf iterations)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    rules = {"default": None,
             "no_ssm_fsdp": P_.NO_SSM_FSDP_RULES,
             "ssm_dp_only": P_.SSM_DP_ONLY_RULES}[args.rules]
    opts = dict(kv.split("=", 1) for kv in args.opt)
    if args.rules != "default":
        opts["_rules"] = args.rules  # lands in the artifact tag
    archs = list(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    pods = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    meshes = {mp: make_production_mesh(multi_pod=mp) for mp in set(pods)}
    failures = 0
    for mp in pods:
        for arch in archs:
            for shape in shapes:
                mesh = meshes[mp]
                mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
                out = ART_DIR / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and out.exists() and not opts:
                    print(f"[skip-existing] {arch} {shape} {mesh_name}")
                    continue
                try:
                    r = lower_cell(arch, shape, multi_pod=mp, opts=opts,
                                   mesh=mesh, rules=rules)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {arch} {shape} multi_pod={mp}: {type(e).__name__}: {e}",
                          flush=True)
                    continue
                if "skipped" in r:
                    print(f"[skip] {arch} {shape}: {r['skipped']}", flush=True)
                    continue
                rl = r["roofline"]
                tsum = sum(t["compile_s"] for t in r["timings"].values())
                print(f"[ok] {arch} {shape} {r['mesh']} "
                      f"compile={tsum:.0f}s dom={rl['dominant']} "
                      f"comp={rl['compute_s']:.4f}s mem={rl['memory_s']:.4f}s "
                      f"coll={rl['collective_s']:.4f}s "
                      f"useful={rl['useful_flop_ratio'] and round(rl['useful_flop_ratio'], 3)}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
