"""Serving driver: batched prefill + decode; single-stream, server or cluster.

Three modes:

* **Single-stream** (default): one prompt batch, prefill then an
  autoregressive decode loop. The decode step is a recurrent taskgraph
  region in the paper's sense: recorded (compiled) once, replayed per
  generated token with donated caches.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \\
          --batch 4 --prompt-len 64 --gen 32

* **Multi-tenant server** (``--server``): N tenants each own a decode-step
  taskgraph region (same structure, same payload, private KV/SSM caches,
  shared params) and drive it concurrently through
  ``repro.serving.RegionServer``. Structurally identical decode requests
  coalesce into one batched fused replay per step; the run prints
  throughput plus the server's queue/batch/intern/latency metrics.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \\
          --server --tenants 4 --gen 16

* **Distributed cluster** (``--cluster W``): the same N-tenant decode
  drive, but through ``repro.serving.ClusterFrontend`` — W worker
  *processes* each running a ``RegionServer`` behind the socket RPC layer.
  Model params are shipped once per worker as pinned buffers; per-step
  requests carry only tokens/pos/caches; tenants route sticky-by-structure
  so one worker serves all structurally identical decode regions from one
  warm executable. ``--cluster 0`` uses ``REPRO_CLUSTER_WORKERS``.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \\
          --cluster 2 --tenants 4 --gen 8

  ``--workers host:port,...`` swaps the local spawner for **pre-started
  remote workers** (bootstrap each host with ``python -m
  repro.serving.worker --bind ... --registry
  repro.launch.serve:build_decode_registry --registry-kwargs '{...}'``);
  mix in the literal ``local`` to also spawn workers here. ``--token``
  (default ``$REPRO_RPC_TOKEN``) must match the workers' handshake token.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \\
          --workers 10.0.0.5:7077,local --tenants 4 --gen 8
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config, reduced
from ..core.serialize import TaskFnRegistry
from ..models import init_params, prefill
from ..training import make_serve_step


def build_decode_registry(arch: str = "qwen2.5-3b",
                          smoke: bool = True) -> TaskFnRegistry:
    """Payload symbol table for ``--cluster`` workers (and the frontend).

    A spawned worker cannot receive the decode-step closure over the wire;
    it re-links the TDG's ``"decode"`` symbol by importing this factory and
    rebuilding the step from the (deterministic) model config — the same
    contract as the paper's compiler-emitted TDG referencing outlined
    functions by name.
    """
    cfg = get_config(arch)
    if smoke:
        cfg = reduced(cfg)
    reg = TaskFnRegistry()
    reg.register("decode")(make_serve_step(cfg))
    return reg


def _run_single_stream(args, cfg, params) -> int:
    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 2, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    max_len = args.prompt_len + args.gen
    t0 = time.time()
    logits, caches, pos = prefill(params, cfg, batch, max_len=max_len)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(3,))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, caches = serve_step(params, tok[:, None], pos, caches)
        pos = pos + 1
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.stack(outs, axis=1)
    tput = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.gen-1} steps "
          f"({tput:.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())
    return 0


def _tenant_tiers(args) -> list[int]:
    """Per-tenant QoS tiers from ``--tiers`` ("1" or "0,1,...", cycled)."""
    if not args.tiers:
        return [0] * args.tenants
    cycle = [max(0, int(t)) for t in str(args.tiers).split(",") if t.strip()]
    return [cycle[i % len(cycle)] for i in range(args.tenants)]


def _print_tier_latency(tiers_summary) -> None:
    for tier in sorted(tiers_summary or {}, key=int):
        s = tiers_summary[tier]
        print(f"tier {tier}: n {s['count']}  p50 {s['p50_s']*1e3:.2f} ms  "
              f"p99 {s['p99_s']*1e3:.2f} ms")


def _run_server(args, cfg, params) -> int:
    from ..core import TDG
    from ..serving import RegionServer

    decode = make_serve_step(cfg)
    max_len = args.prompt_len + args.gen

    # Per-tenant prefill: private prompt, caches and positions; params are
    # shared (same object), so the server broadcasts rather than stacks them
    # in a coalesced batch.
    states = []
    t0 = time.time()
    for i in range(args.tenants):
        key = jax.random.PRNGKey(args.seed + 1 + i)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 2, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        logits, caches, pos = prefill(params, cfg, batch, max_len=max_len)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        states.append({"tok": tok, "pos": pos, "caches": caches, "out": [tok]})
    jax.block_until_ready([s["tok"] for s in states])
    t_prefill = time.time() - t0

    server = RegionServer(max_batch=args.max_batch or args.tenants,
                          max_wait_ms=args.max_wait_ms, name="decode-server",
                          continuous=False if args.request_level else None)
    tiers = _tenant_tiers(args)
    for i in range(args.tenants):
        # One decode-step region per tenant — structurally identical across
        # tenants (same payload object), so they intern to one executable.
        tdg = TDG(f"decode[{i}]")
        tdg.add_task(decode, ins=["params", "tokens", "pos", "caches"],
                     outs=["next", "caches"], name="decode")
        server.register_tenant(f"tenant{i}", tdg, outputs=("next", "caches"),
                               tier=tiers[i],
                               rate=args.tenant_rate or None)

    errors: list[BaseException] = []

    def tenant_loop(i: int) -> None:
        try:
            st = states[i]
            for _ in range(args.gen - 1):
                out = server.serve(f"tenant{i}", {
                    "params": params, "tokens": st["tok"][:, None],
                    "pos": st["pos"], "caches": st["caches"]})
                st["tok"] = out["next"]
                st["caches"] = out["caches"]
                st["pos"] = st["pos"] + 1
                st["out"].append(st["tok"])
        except BaseException as e:   # surface thread failures, don't exit 0
            errors.append(e)

    threads = [threading.Thread(target=tenant_loop, args=(i,))
               for i in range(args.tenants)]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t_decode = time.time() - t0
    server.close()
    if errors:
        raise errors[0]

    stats = server.stats()
    m = stats["metrics"]
    toks = args.tenants * args.batch * (args.gen - 1)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.tenants} tenants "
          f"x {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.gen-1} steps x "
          f"{args.tenants} tenants ({toks / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"server:  {m['batches']} batches, occupancy mean "
          f"{m['batch_occupancy_mean']:.2f} max {m['batch_occupancy_max']}, "
          f"{m['batch_fallbacks']} fallbacks, queue peak "
          f"{m['queue_depth_peak']}")
    print(f"pool:    {stats['pool']}  intern: {stats['intern']}")
    print(f"latency: p50 {m['latency']['p50_s']*1e3:.2f} ms  "
          f"p99 {m['latency']['p99_s']*1e3:.2f} ms")
    _print_tier_latency(m.get("tiers"))
    print(f"trace:   {m['trace']}")
    if args.trace_out:
        server.dump_trace(args.trace_out)
        print(f"trace ring written to {args.trace_out}")
    for i in (0, args.tenants - 1):
        gen = jnp.stack(states[i]["out"], axis=1)
        print(f"tenant{i} sample token ids:", gen[0, :12].tolist())
    return 0


def _run_cluster(args, cfg, params) -> int:
    from ..core import TDG
    from ..serving import ClusterFrontend

    registry = build_decode_registry(args.arch, args.smoke)
    decode = registry.get("decode")
    max_len = args.prompt_len + args.gen

    states = []
    t0 = time.time()
    for i in range(args.tenants):
        key = jax.random.PRNGKey(args.seed + 1 + i)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 2, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        logits, caches, pos = prefill(params, cfg, batch, max_len=max_len)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        states.append({"tok": tok, "pos": pos, "caches": caches, "out": [tok]})
    jax.block_until_ready([s["tok"] for s in states])
    t_prefill = time.time() - t0

    if args.workers:
        workers = [w.strip() for w in args.workers.split(",") if w.strip()]
    else:
        workers = args.cluster or None
    t0 = time.time()
    frontend = ClusterFrontend(
        workers=workers,
        registry="repro.launch.serve:build_decode_registry",
        registry_kwargs={"arch": args.arch, "smoke": args.smoke},
        max_batch=args.max_batch or args.tenants,
        max_wait_ms=args.max_wait_ms, token=args.token,
        continuous=False if args.request_level else None,
        name="decode-cluster")
    tiers = _tenant_tiers(args)
    for i in range(args.tenants):
        tdg = TDG(f"decode[{i}]")
        tdg.add_task(decode, ins=["params", "tokens", "pos", "caches"],
                     outs=["next", "caches"], name="decode")
        # params ship ONCE per worker (pinned); each step's request carries
        # only the varying decode state.
        frontend.register_tenant(f"tenant{i}", tdg, outputs=("next", "caches"),
                                 pinned={"params": params}, tier=tiers[i],
                                 rate=args.tenant_rate or None)
    t_spawn = time.time() - t0

    errors: list[BaseException] = []

    def tenant_loop(i: int) -> None:
        try:
            st = states[i]
            for _ in range(args.gen - 1):
                out = frontend.serve(f"tenant{i}", {
                    "tokens": st["tok"][:, None], "pos": st["pos"],
                    "caches": st["caches"]}, timeout=300)
                st["tok"] = jnp.asarray(out["next"])
                st["caches"] = out["caches"]
                st["pos"] = st["pos"] + 1
                st["out"].append(st["tok"])
        except BaseException as e:   # surface thread failures, don't exit 0
            errors.append(e)

    threads = [threading.Thread(target=tenant_loop, args=(i,))
               for i in range(args.tenants)]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t_decode = time.time() - t0
    stats = frontend.stats()
    if args.trace_out:
        import json as _json
        with open(args.trace_out, "w") as f:
            _json.dump(frontend.trace(), f, indent=1)
        print(f"per-worker trace rings written to {args.trace_out}")
    frontend.close()
    if errors:
        raise errors[0]

    fr, agg = stats["frontend"], stats["aggregate"]
    toks = args.tenants * args.batch * (args.gen - 1)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.tenants} tenants "
          f"x {args.batch}x{args.prompt_len}")
    print(f"cluster: {fr['workers']} workers ({fr['remote_workers']} remote) "
          f"ready+registered in {t_spawn*1e3:.0f} ms")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.gen-1} steps x "
          f"{args.tenants} tenants ({toks / max(t_decode, 1e-9):.1f} tok/s "
          f"over RPC)")
    print(f"fleet:   admitted {agg['admitted']}, {agg['batches']} batches, "
          f"coalesced {agg['coalesced_requests']}, aot_served "
          f"{agg['aot_served']}, hydrate failures "
          f"{agg['aot_hydrate_failures']}")
    print(f"routing: {stats['tenants']}")
    print(f"fleet intern: {agg['intern']}  pool: {agg['pool']}")
    print(f"frontend: deaths {fr['worker_deaths']}, requeues "
          f"{fr['requeues']}, artifacts shipped {fr['artifacts_shipped']}")
    for i in (0, args.tenants - 1):
        gen = jnp.stack(states[i]["out"], axis=1)
        print(f"tenant{i} sample token ids:", gen[0, :12].tolist())
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--server", action="store_true",
                    help="multi-tenant RegionServer mode (see repro.serving)")
    ap.add_argument("--cluster", type=int, default=None, nargs="?", const=0,
                    help="distributed mode: worker process count "
                         "(0/omitted value = REPRO_CLUSTER_WORKERS)")
    ap.add_argument("--workers", default=None, metavar="SPEC,SPEC,...",
                    help="distributed mode with explicit worker specs: "
                         "comma-separated host:port of pre-started "
                         "`python -m repro.serving.worker` nodes, plus the "
                         "literal 'local' to also spawn here; implies "
                         "--cluster")
    ap.add_argument("--token", default=None,
                    help="RPC handshake auth token for --cluster/--workers "
                         "(default: $REPRO_RPC_TOKEN)")
    ap.add_argument("--tenants", type=int, default=4,
                    help="[--server/--cluster] concurrent decode tenants")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="[--server/--cluster] coalescing ceiling (0 = #tenants)")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="[--server/--cluster] admission window for coalescing")
    ap.add_argument("--request-level", action="store_true",
                    help="[--server/--cluster] legacy run-to-completion "
                         "batching instead of continuous (iteration-level)")
    ap.add_argument("--tiers", default=None, metavar="T0,T1,...",
                    help="[--server/--cluster] per-tenant QoS tiers, cycled "
                         "over tenants (e.g. '0,1'); default all tier 0")
    ap.add_argument("--tenant-rate", type=float, default=0.0,
                    help="[--server/--cluster] per-tenant token-bucket rate "
                         "limit in req/s (0 = unlimited)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="[--server/--cluster] dump the execution-pattern "
                         "trace ring(s) to PATH as JSON after the run")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    if args.cluster is not None or args.workers:
        return _run_cluster(args, cfg, params)
    if args.server:
        return _run_server(args, cfg, params)
    return _run_single_stream(args, cfg, params)


if __name__ == "__main__":
    raise SystemExit(main())
