"""Serving driver: batched prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --batch 4 --prompt-len 64 --gen 32

The decode step is a recurrent taskgraph region in the paper's sense:
recorded (compiled) once, replayed per generated token with donated caches.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config, reduced
from ..models import init_params, prefill
from ..training import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 2, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    max_len = args.prompt_len + args.gen
    t0 = time.time()
    logits, caches, pos = prefill(params, cfg, batch, max_len=max_len)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(3,))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, caches = serve_step(params, tok[:, None], pos, caches)
        pos = pos + 1
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.stack(outs, axis=1)
    tput = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.gen-1} steps "
          f"({tput:.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
