"""Per-structure cost report: print every adaptive decision with its numbers.

``launch/dryrun.py`` audits model-scale lowering (memory, collectives,
roofline); this is its sibling for the *grain* decisions of
``core/costmodel.py``. For a TDG it lowers nothing and runs nothing heavy —
it probes each fused wave class's payload exactly like trace-time adaptive
fusion does and prints, per class, the measured flops / bytes accessed /
arithmetic intensity and the batcher they selected (vmap | lax.map |
unrolled), plus the policy thresholds in force. For a serving occupancy
stream it shows the histogram, the boundaries the bucket tuner would fit,
and the pad-lane bill under pow-2 vs fitted ladders. The point is that the
adaptive path is auditable: every decision traces back to a number printed
here, never to "the model felt like it".

Run:  PYTHONPATH=src python -m repro.launch.costreport [--json OUT]

The built-in demo covers all three batcher outcomes (a compute-bound
matmul class, a memory-bound stencil class, a below-break-even scalar
class) and a skewed occupancy stream whose fitted boundaries beat pow-2.

Library use::

    from repro.launch.costreport import structure_report, bucket_report
    rep = structure_report(tdg, buffers)        # per-class decisions
    buckets = bucket_report(occupancies, max_batch=16)
"""
from __future__ import annotations

import argparse
import collections
import json
from typing import Any, Iterable, Mapping, Sequence

from ..core import costmodel as _costmodel
from ..core import fuse as _fuse
from ..core.tdg import TDG


def structure_report(tdg: TDG, buffers: Mapping[str, Any],
                     min_class_size: int = 2,
                     batcher: str = "auto") -> dict:
    """Per-wave-class batcher decisions for ``tdg`` with measured numbers.

    ``buffers`` holds arrays or ``ShapeDtypeStruct`` trees for the region's
    input slots (no data is touched — shapes propagate by abstract
    evaluation, payload costs by probe compiles). The decisions are exactly
    what ``batcher="auto"`` replay will apply for these shapes: both run
    ``fuse._decide_class`` over the same cost-model cache.
    """
    model = _costmodel.default_model()
    plan = _fuse.plan(tdg, buffers=buffers, min_class_size=min_class_size,
                      batcher=batcher)
    summary = plan.summary()
    return {
        "region": tdg.region,
        "adaptive": _costmodel.adaptive_enabled(),
        "policy": {
            "plan_key": _costmodel.plan_key(batcher),
            "ridge_flops_per_byte": model.ridge,
            "map_member_bytes_max": model.map_member_bytes,
            "map_total_bytes_min": model.map_total_bytes,
            "unroll_flops_breakeven": model.unroll_flops,
        },
        "tasks": summary["tasks"],
        "waves": summary["waves"],
        "batchers": summary["batchers"],
        "decisions": summary["decisions"],
    }


def bucket_report(occupancies: Iterable[int], max_batch: int,
                  max_buckets: int = 8) -> dict:
    """What the bucket tuner fits for an occupancy stream, with the bill.

    Returns the histogram (the numbers that drive the fit), the pow-2
    ladder, the fitted boundaries, and total pad lanes under each — the
    operator-facing answer to "why did the server retune".
    """
    hist = collections.Counter(int(n) for n in occupancies if int(n) >= 2)
    pow2 = _costmodel.pow2_boundaries(max_batch)
    fitted = _costmodel.fit_boundaries(hist, max_buckets) or pow2

    def pad_bill(bounds: Sequence[int]) -> int:
        total = 0
        for occ, cnt in hist.items():
            b = next((x for x in sorted(bounds) if x >= occ), None)
            if b is None:
                b = bounds and max(bounds) or occ
                while b < occ:
                    b *= 2
            total += cnt * (b - occ)
        return total

    return {
        "observations": sum(hist.values()),
        "histogram": {str(k): v for k, v in sorted(hist.items())},
        "pow2_boundaries": pow2,
        "fitted_boundaries": fitted,
        "pad_lanes_pow2": pad_bill(pow2),
        "pad_lanes_fitted": pad_bill(fitted),
    }


# ------------------------------------------------------------------ printing

def print_structure_report(rep: dict) -> None:
    pol = rep["policy"]
    print(f"== {rep['region']}: per-class batcher decisions "
          f"(adaptive={'on' if rep['adaptive'] else 'OFF'}, "
          f"plan={pol['plan_key']})")
    print(f"   policy: intensity ridge {pol['ridge_flops_per_byte']:g} "
          f"flops/B | map member<= {pol['map_member_bytes_max']}B, "
          f"batch>= {pol['map_total_bytes_min']}B | unroll< "
          f"{pol['unroll_flops_breakeven']:g} flops")
    for d in rep["decisions"]:
        flops = "?" if d["flops"] is None else f"{d['flops']:g}"
        nbytes = "?" if d["bytes"] is None else f"{d['bytes']:g}"
        inten = "?" if d["intensity"] is None else f"{d['intensity']:g}"
        print(f"   wave {d['wave']} x{d['size']:<3d} -> {d['batcher']:<8s} "
              f"flops={flops:<10s} bytes={nbytes:<10s} int={inten:<8s} "
              f"({d['reason']})")


def print_bucket_report(rep: dict) -> None:
    print(f"== occupancy buckets over {rep['observations']} batched steps")
    print(f"   histogram: {rep['histogram']}")
    print(f"   pow-2 ladder  {rep['pow2_boundaries']} -> "
          f"{rep['pad_lanes_pow2']} pad lanes")
    print(f"   fitted ladder {rep['fitted_boundaries']} -> "
          f"{rep['pad_lanes_fitted']} pad lanes")


# ---------------------------------------------------------------- demo / CLI

def _demo_tdgs() -> list[tuple[TDG, dict]]:
    """Three structures spanning all three batcher outcomes."""
    import jax.numpy as jnp
    import numpy as np

    def mm(a, w):
        return a @ w

    def relax(x):
        return 0.25 * (jnp.roll(x, 1, 0) + jnp.roll(x, -1, 0)
                       + jnp.roll(x, 1, 1) + jnp.roll(x, -1, 1))

    def nudge(x):
        return x + 0.5

    f32 = jnp.float32
    import jax

    mm_tdg = TDG(region="demo_compute_bound")
    for i in range(8):
        mm_tdg.add_task(mm, ins=[f"x{i}", "w"], outs=[f"y{i}"])
    mm_bufs = {f"x{i}": jax.ShapeDtypeStruct((64, 64), f32) for i in range(8)}
    mm_bufs["w"] = jax.ShapeDtypeStruct((64, 64), f32)

    st_tdg = TDG(region="demo_memory_bound")
    for i in range(8):
        st_tdg.add_task(relax, ins=[f"h{i}"], outs=[f"g{i}"])
    st_bufs = {f"h{i}": jax.ShapeDtypeStruct((128, 128), f32)
               for i in range(8)}

    tiny_tdg = TDG(region="demo_below_breakeven")
    for i in range(8):
        tiny_tdg.add_task(nudge, ins=[f"s{i}"], outs=[f"t{i}"])
    tiny_bufs = {f"s{i}": jax.ShapeDtypeStruct((2,), f32) for i in range(8)}

    return [(mm_tdg, mm_bufs), (st_tdg, st_bufs), (tiny_tdg, tiny_bufs)]


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the full report as JSON")
    args = ap.parse_args(argv)

    doc: dict = {"structures": [], "buckets": None}
    for tdg, bufs in _demo_tdgs():
        rep = structure_report(tdg, bufs)
        doc["structures"].append(rep)
        print_structure_report(rep)

    # A skewed occupancy stream (stragglers pin most steps at 5 or 12):
    # pow-2 rounds them to 8 and 16; the fitted ladder lands on the modes.
    occupancies = [5] * 40 + [12] * 30 + [3] * 10 + [16] * 5
    rep = bucket_report(occupancies, max_batch=16)
    doc["buckets"] = rep
    print_bucket_report(rep)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
