"""Production meshes.

Target hardware: TPU v5e pods — 16x16 = 256 chips per pod; multi-pod runs
add a leading "pod" axis (2 pods = 512 chips for the dry-run; the axis
generalizes to any pod count). Defined as FUNCTIONS so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    return jax.make_mesh(shape, axes, devices=devices)


def make_replay_mesh(n_devices: int | None = None,
                     axis: str = "data") -> jax.sharding.Mesh:
    """1-D mesh over the fused-replay batch dimension.

    ``axis`` defaults to ``"data"`` so ``partition.DEFAULT_RULES`` resolves
    the logical ``"batch"`` axis onto it. ``n_devices=None`` takes every
    local device — the ``REPRO_MESH=all`` configuration.
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"need a positive device count, got {n_devices!r}")
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices for the replay mesh, have {len(devices)} — "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before any jax import")
    return jax.make_mesh((n,), (axis,), devices=devices[:n])


def make_small_mesh(n_data: int = 2, n_model: int = 2) -> jax.sharding.Mesh:
    """CPU-test mesh (uses however many host devices exist)."""
    n = n_data * n_model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh((n_data, n_model), ("data", "model"), devices=devices)


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~4 links usable per chip)
