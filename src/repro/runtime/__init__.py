"""Distributed runtime: fault tolerance, elasticity, straggler mitigation."""
from .fault_tolerance import (RunState, run_with_recovery, StepTimer,
                              StragglerPolicy)
from .elastic import reshard_checkpoint, elastic_restart_plan

__all__ = ["RunState", "run_with_recovery", "StepTimer", "StragglerPolicy",
           "reshard_checkpoint", "elastic_restart_plan"]
