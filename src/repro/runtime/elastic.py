"""Elastic scaling: restart on a different device count.

Checkpoints are host-sharded numpy trees (device-agnostic); re-meshing is
therefore: load -> device_put with the NEW mesh's shardings. The plan
helper validates that the new mesh divides the model's partitionable dims
and falls back per-leaf to replication where it does not (same sanitize
rule as launch-time sharding).
"""
from __future__ import annotations

from typing import Any

import jax

from ..sharding import partition as P_


def elastic_restart_plan(old_chips: int, new_chips: int,
                         global_batch: int) -> dict:
    """Batch/mesh bookkeeping when the pod shrinks or grows."""
    if new_chips <= 0:
        raise ValueError("new_chips must be positive")
    plan = {"old_chips": old_chips, "new_chips": new_chips}
    # keep global batch fixed (training semantics unchanged); adjust
    # per-device microbatch, dropping to grad-accumulation if needed
    if global_batch % new_chips == 0:
        plan["per_device_batch"] = global_batch // new_chips
        plan["grad_accum"] = 1
    else:
        accum = 1
        while global_batch % (new_chips * accum) and accum < 64:
            accum += 1
        plan["per_device_batch"] = max(1, global_batch // (new_chips * accum))
        plan["grad_accum"] = accum
    return plan


def reshard_checkpoint(tree: Any, mesh: jax.sharding.Mesh, rules=None):
    """Place a host-resident checkpoint tree onto a (new) mesh."""
    shardings = P_.param_shardings(tree, mesh, rules)
    return jax.tree_util.tree_map(jax.device_put, tree, shardings)
