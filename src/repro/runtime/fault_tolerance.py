"""Fault tolerance: recoverable training runs, step timing, stragglers.

At 1000+ nodes the failure model is: some host dies mid-step every few
hours. The contract here:

  * every N steps an async checkpoint is cut (``Checkpointer``);
  * ``run_with_recovery`` executes the step loop inside a supervisor that
    catches step failures (device OOM, preempted host, injected faults in
    tests), restores the last committed checkpoint, rebuilds the data
    iterator at the restored step (deterministic addressing — no data-state
    to save) and resumes;
  * a ``StepTimer`` tracks a running P50/P99; ``StragglerPolicy`` flags
    steps beyond ``k * p50`` — on a real pod this triggers the backup-task
    hook (the work-stealing analogue at cluster scale: re-execute the
    straggler's shard elsewhere); on CPU we surface the signal and count it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax


@dataclasses.dataclass
class RunState:
    params: Any
    opt_state: Any
    step: int = 0


class StepTimer:
    def __init__(self, window: int = 128):
        self.durations: list[float] = []
        self.window = window

    def record(self, seconds: float):
        self.durations.append(seconds)
        if len(self.durations) > self.window:
            self.durations.pop(0)

    def percentile(self, q: float) -> float:
        if not self.durations:
            return 0.0
        s = sorted(self.durations)
        idx = min(len(s) - 1, int(q / 100.0 * len(s)))
        return s[idx]


@dataclasses.dataclass
class StragglerPolicy:
    """Flag steps slower than ``threshold x p50`` once warmed up."""
    threshold: float = 3.0
    warmup_steps: int = 8
    flagged: int = 0

    def check(self, timer: StepTimer, seconds: float) -> bool:
        if len(timer.durations) < self.warmup_steps:
            return False
        p50 = timer.percentile(50)
        if p50 > 0 and seconds > self.threshold * p50:
            self.flagged += 1
            return True
        return False


def run_with_recovery(
    step_fn: Callable[[RunState, dict], tuple[RunState, dict]],
    state: RunState,
    data_iter_factory: Callable[[int], Any],
    num_steps: int,
    checkpointer=None,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    on_metrics: Callable[[int, dict], None] | None = None,
    straggler_policy: StragglerPolicy | None = None,
    fault_injector: Callable[[int], None] | None = None,
) -> tuple[RunState, dict]:
    """Supervised step loop. Returns (final state, run report)."""
    report = {"restarts": 0, "completed_steps": 0, "stragglers": 0,
              "checkpoints": 0}
    timer = StepTimer()
    restarts = 0
    target = state.step + num_steps

    while state.step < target:
        data = data_iter_factory(state.step)
        try:
            while state.step < target:
                batch = next(data)
                if fault_injector is not None:
                    fault_injector(state.step)   # may raise (test hook)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                timer.record(dt)
                if straggler_policy and straggler_policy.check(timer, dt):
                    report["stragglers"] += 1
                state.step += 1
                report["completed_steps"] += 1
                if on_metrics:
                    on_metrics(state.step, metrics)
                if checkpointer and state.step % checkpoint_every == 0:
                    checkpointer.save(
                        {"params": state.params, "opt_state": state.opt_state,
                         "step": state.step}, state.step)
                    report["checkpoints"] += 1
        except Exception:
            restarts += 1
            report["restarts"] = restarts
            if restarts > max_restarts or checkpointer is None:
                raise
            restored, ck_step = checkpointer.restore(
                {"params": state.params, "opt_state": state.opt_state,
                 "step": 0})
            if restored is None:
                raise
            state = RunState(params=restored["params"],
                             opt_state=restored["opt_state"],
                             step=int(ck_step))
            # data iterator rebuilt at the restored step by the factory
            continue
    if checkpointer is not None:
        checkpointer.wait()
    return state, report
