"""Mesh-sharded fused replay: spread the coalesced batch axis over devices.

The fused replay path (``core/fuse.py``, ``serving/server.py``) turns a
wave of isomorphic tasks — or a batch of coalesced tenant requests — into
ONE vmap-batched call over a stacked leading axis. Every lane of that axis
is independent by construction, which makes it the natural unit of data
parallelism: constraining the stacked arrays to a 1-D device mesh lets
GSPMD split the batch across all local devices while the traced program —
and therefore the numerics — stay identical lane for lane. This module is
the one place that policy lives:

* :func:`resolve_mesh` turns a ``mesh=`` argument (``"auto"`` | ``None`` |
  a concrete :class:`jax.sharding.Mesh`) into the mesh actually used,
  honouring :func:`repro.sharding.partition.use_mesh` scopes and the
  ``REPRO_MESH`` env knob (``N`` devices, ``all``, or ``0``/``off``).
  Meshes that cannot shard the batch axis (size <= 1, or no axis the
  ``"batch"`` rule resolves to) normalize to ``None`` — "sharded" is
  never a zero-way split in disguise.
* :func:`mesh_fingerprint` is the JSON-stable identity (``"data=8"``)
  carried in intern-cache keys, ``WarmPool`` keys and
  ``serialize.topology_fingerprint`` so single-device and N-device
  executables never collide and cross-topology artifacts are rejected
  loudly.
* :func:`shard_leading` applies the ``with_sharding_constraint`` over the
  stacked batch dim (``partition.batch_pspec``), sanitized per leaf so a
  non-divisible dim degrades to replicated instead of erroring — callers
  pad to a mesh multiple first (see ``fuse._run_fused_class``) so the
  constraint actually bites.

Sharding is exactness-preserving: lanes are independent, the per-lane op
sequence is unchanged, and padded lanes are computed but never read — the
differential harness in ``tests/test_mesh_replay.py`` asserts bit-equality
against the single-device path.
"""
from __future__ import annotations

import os
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from . import partition as _partition

#: Env knob: ``REPRO_MESH=N`` shards fused replay over the first N local
#: devices, ``all`` over every local device; unset/``0``/``off`` disables.
MESH_ENV = "REPRO_MESH"

_OFF = ("", "0", "off", "false", "no", "none")

# env-spec -> Mesh, keyed by (raw value, visible device count) so a test
# that monkeypatches the env (or a process that gains devices) never sees
# a stale mesh.
_env_cache: dict[tuple[str, int], Mesh] = {}


def mesh_from_env() -> Mesh | None:
    """The ``REPRO_MESH``-configured replay mesh (``None`` = disabled)."""
    raw = os.environ.get(MESH_ENV, "").strip().lower()
    if raw in _OFF:
        return None
    key = (raw, len(jax.devices()))
    mesh = _env_cache.get(key)
    if mesh is None:
        from ..launch import mesh as _launch_mesh

        if raw == "all":
            mesh = _launch_mesh.make_replay_mesh()
        else:
            try:
                n = int(raw)
            except ValueError:
                raise ValueError(
                    f"{MESH_ENV}={raw!r} is not a device count, 'all', or "
                    "0/off") from None
            mesh = _launch_mesh.make_replay_mesh(n)
        _env_cache[key] = mesh
    return mesh


def batch_axis_size(mesh: Mesh | None) -> int:
    """How many ways ``mesh`` splits the replay batch axis (1 = no split)."""
    if mesh is None:
        return 1
    axis = _partition.resolve_axis("batch", mesh, _partition.DEFAULT_RULES)
    if axis is None:
        return 1
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        n *= mesh.shape[a]
    return n


def resolve_mesh(mesh: Any = "auto") -> Mesh | None:
    """Resolve a ``mesh=`` argument to the mesh fused replay will use.

    Precedence: an explicit :class:`Mesh` wins; ``"auto"`` takes the
    ambient :func:`partition.use_mesh` scope, then the ``REPRO_MESH`` env
    knob; ``None`` forces single-device. Any result that cannot split the
    batch axis at least 2 ways normalizes to ``None``, so callers (and
    cache keys) only ever see a mesh that genuinely shards.
    """
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        resolved = mesh
    elif mesh == "auto":
        resolved = _partition.active_mesh()
        if resolved is None:
            resolved = mesh_from_env()
    else:
        raise ValueError(
            f"mesh must be a jax.sharding.Mesh, None or 'auto', got {mesh!r}")
    if resolved is None or batch_axis_size(resolved) <= 1:
        return None
    return resolved


def mesh_fingerprint(mesh: Mesh | None) -> str | None:
    """JSON-stable identity of a replay mesh (``"data=8"``; ``None`` = off).

    This string — not the mesh object — is what keys intern caches and
    ``WarmPool`` entries and rides inside ``serialize.topology_fingerprint``
    across the cluster tier's JSON wire, so it must stay a plain string.
    """
    if mesh is None:
        return None
    return ",".join(f"{name}={size}" for name, size in mesh.shape.items())


def pad_group(members: list, mesh: Mesh | None) -> int:
    """Extend ``members`` (in place) to a batch-axis multiple; return #pads.

    Padding repeats the last member, so padded lanes trace the exact same
    program as real ones and are simply never read back — occupancy that
    doesn't divide the mesh axis costs idle lanes, not correctness.
    """
    if mesh is None or not members:
        return 0
    pad = (-len(members)) % batch_axis_size(mesh)
    members.extend(members[-1:] * pad)
    return pad


def shard_leading(tree: Any, mesh: Mesh | None) -> Any:
    """Constrain every array leaf's leading (stacked batch) dim to ``mesh``.

    Leaves whose leading dim the mesh axis does not divide are constrained
    replicated instead (``partition.sanitize_spec``) — semantically the
    identity either way, which is what keeps sharding exactness-preserving.
    """
    if mesh is None:
        return tree

    def leaf(x):
        ndim = getattr(x, "ndim", 0)
        if ndim == 0:
            return x
        spec = _partition.batch_pspec(mesh, extra=ndim - 1,
                                      rules=_partition.DEFAULT_RULES)
        spec = _partition.sanitize_spec(tuple(x.shape), spec, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(leaf, tree)
