"""Logical-axis partitioning: one rule table maps model-space axis names to
mesh axes (pod/data/model). Model code only ever names LOGICAL axes
("batch", "embed", "heads", "ff", "experts", "vocab", "seq"); the mesh
shape and the parallelism strategy (DP+FSDP over "data", TP/EP/SP over
"model", DP over "pod") are decided here and can be swapped per run —
the paper's "scheduling is decided once, outside the tasks" principle
applied to distribution.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> candidate mesh axes (first all present are used, in order)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),     # data parallel
    "embed": ("data",),           # FSDP / ZeRO-3 weight sharding
    "vocab": ("model",),          # tensor parallel over vocab
    "heads": ("model",),          # tensor parallel over attention heads
    "kv_heads": ("model",),
    "ff": ("model",),             # tensor parallel over MLP hidden
    "experts": ("model",),        # expert parallel
    "ssm_inner": ("model",),
    "ssm_embed": ("data",),      # FSDP for SSM projections (see §Perf it.3b:
    #                              NO_SSM_FSDP_RULES replicates them instead)
    "seq": (),                    # sequence parallel (off by default)
    "kv_seq": (),                 # shard KV-cache length (long-context decode)
}


# §Perf iteration 3b: replicate SSM projection weights over "data" —
# GSPMD otherwise contracts over the FSDP-sharded dim with per-layer
# activation all-reduces (measured: dominant AR bytes for mamba2).
NO_SSM_FSDP_RULES = {**DEFAULT_RULES, "ssm_embed": ()}

# §Perf iterations 3c/3d: small SSM models should not be tensor-parallel at
# all — TP of d_model=1024 over 16 chips costs an (B,S,d) fwd+bwd
# all-reduce pair per layer (measured dominant). Instead: 256-way pure DP
# (batch over data AND model), FSDP over data, no vocab TP. Weights fit
# replicated trivially (~0.4 GB/device fp32+Adam with FSDP/16).
SSM_DP_ONLY_RULES = {**DEFAULT_RULES,
                     "batch": ("pod", "data", "model"),
                     "ssm_inner": (), "ssm_embed": ("data",),
                     "vocab": ()}


class _Active(threading.local):
    mesh: Mesh | None = None
    rules: dict[str, tuple[str, ...]] | None = None


_ACTIVE = _Active()


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: Mapping[str, tuple[str, ...]] | None = None):
    """Activate a mesh + rule table for ``constrain`` and spec resolution."""
    prev = (_ACTIVE.mesh, _ACTIVE.rules)
    _ACTIVE.mesh = mesh
    _ACTIVE.rules = dict(rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _ACTIVE.mesh, _ACTIVE.rules = prev


def active_mesh() -> Mesh | None:
    return _ACTIVE.mesh


def resolve_axis(logical: str | None,
                 mesh: Mesh | None = None,
                 rules: Mapping[str, tuple[str, ...]] | None = None):
    if logical is None:
        return None
    mesh = mesh or _ACTIVE.mesh
    rules = rules or _ACTIVE.rules or DEFAULT_RULES
    if mesh is None:
        return None
    axes = [a for a in rules.get(logical, ()) if a in mesh.axis_names]
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def to_pspec(logical_axes: Sequence[str | None],
             mesh: Mesh | None = None,
             rules: Mapping[str, tuple[str, ...]] | None = None) -> P:
    return P(*(resolve_axis(a, mesh, rules) for a in logical_axes))


def constrain(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op if none)."""
    mesh = _ACTIVE.mesh
    if mesh is None:
        return x
    spec = to_pspec(logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter / state partition specs (by leaf path)
# ---------------------------------------------------------------------------

_LEAF_LOGICAL: list[tuple[tuple[str, ...], tuple[str | None, ...]]] = [
    (("table",), ("vocab", "embed")),
    (("wq", "w"), ("embed", "heads")),
    (("wk", "w"), ("embed", "heads")),
    (("wv", "w"), ("embed", "heads")),
    (("wo", "w"), ("heads", "embed")),
    (("wq", "b"), ("heads",)),
    (("wk", "b"), ("heads",)),
    (("wv", "b"), ("heads",)),
    (("up", "w"), ("embed", "ff")),
    (("gate", "w"), ("embed", "ff")),
    (("down", "w"), ("ff", "embed")),
    (("router", "w"), ("embed", None)),
    (("in_proj", "w"), ("ssm_embed", "ssm_inner")),
    (("out_proj", "w"), ("ssm_inner", "ssm_embed")),
    (("conv", "w"), (None, "ssm_inner")),
    (("conv", "b"), ("ssm_inner",)),
    # split-proj SSM layout (§Perf): z/x TP-sharded, B/C/dt replicated
    (("z_proj", "w"), ("ssm_embed", "ssm_inner")),
    (("x_proj", "w"), ("ssm_embed", "ssm_inner")),
    (("b_proj", "w"), ("ssm_embed", None)),
    (("c_proj", "w"), ("ssm_embed", None)),
    (("dt_proj", "w"), ("ssm_embed", None)),
    (("xconv", "w"), (None, "ssm_inner")),
    (("xconv", "b"), ("ssm_inner",)),
    (("bconv", "w"), (None, None)),
    (("cconv", "w"), (None, None)),
    (("A_log",), ("ssm_inner",)),
    (("D",), ("ssm_inner",)),
    (("dt_bias",), ("ssm_inner",)),
]


def _path_names(path) -> tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def logical_axes_for_path(names: tuple[str, ...], ndim: int) -> tuple[str | None, ...]:
    # per-expert weights: EP owns the mesh "model" axis, expert-internal
    # dims stay unsharded (each expert lives wholly on its EP shard)
    if "experts" in names and names[-1] == "w":
        if names[-2] in ("up", "gate"):
            logical: tuple[str | None, ...] = ("experts", "embed", None)
        elif names[-2] == "down":
            logical = ("experts", None, "embed")
        else:
            logical = ("experts",) + (None,) * max(ndim - 1, 0)
        while len(logical) < ndim:
            logical = (None,) + logical
        return logical[-ndim:] if len(logical) > ndim else logical

    logical = None
    for suffix, axes in _LEAF_LOGICAL:
        if names[-len(suffix):] == suffix:
            logical = axes
            break
    if logical is None:
        logical = (None,) * ndim           # norms, scalars: replicated
    while len(logical) < ndim:             # leading L (stacked layers) etc.
        logical = (None,) + logical
    return logical[-ndim:] if len(logical) > ndim else logical


def param_pspecs(params: Any, mesh: Mesh | None = None,
                 rules: Mapping[str, tuple[str, ...]] | None = None):
    """PartitionSpec tree matching ``params`` (works on shapes or arrays)."""

    def leaf(path, x):
        names = _path_names(path)
        ndim = len(getattr(x, "shape", ()))
        return to_pspec(logical_axes_for_path(names, ndim), mesh, rules)

    return jax.tree_util.tree_map_with_path(leaf, params)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def sanitize_spec(shape: tuple, spec: P, mesh: Mesh) -> P:
    """Drop mesh axes on dims they don't divide (pjit argument rule)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        out.append(entry if dim % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def param_shardings(params: Any, mesh: Mesh,
                    rules: Mapping[str, tuple[str, ...]] | None = None):
    specs = param_pspecs(params, mesh, rules)

    def leaf(x, s):
        return NamedSharding(mesh, sanitize_spec(tuple(x.shape), s, mesh))

    return jax.tree_util.tree_map(leaf, params, specs)


def batch_pspec(mesh: Mesh | None = None, extra: int = 1,
                rules: Mapping[str, tuple[str, ...]] | None = None) -> P:
    """(batch, ...) inputs: shard the leading batch dim."""
    return to_pspec(("batch",) + (None,) * extra, mesh, rules)
