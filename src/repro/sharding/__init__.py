"""Distribution: logical-axis partitioning rules over pod/data/model meshes."""
from . import partition
from . import replay
from .replay import MESH_ENV, mesh_fingerprint, resolve_mesh
from .partition import (
    DEFAULT_RULES,
    use_mesh,
    active_mesh,
    constrain,
    to_pspec,
    param_pspecs,
    param_shardings,
    batch_pspec,
)

__all__ = ["partition", "replay", "DEFAULT_RULES", "use_mesh",
           "active_mesh", "constrain", "to_pspec", "param_pspecs",
           "param_shardings", "batch_pspec", "MESH_ENV", "mesh_fingerprint",
           "resolve_mesh"]
