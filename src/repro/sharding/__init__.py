"""Distribution: logical-axis partitioning rules over pod/data/model meshes."""
from . import partition
from .partition import (
    DEFAULT_RULES,
    use_mesh,
    active_mesh,
    constrain,
    to_pspec,
    param_pspecs,
    param_shardings,
    batch_pspec,
)

__all__ = ["partition", "DEFAULT_RULES", "use_mesh", "active_mesh",
           "constrain", "to_pspec", "param_pspecs", "param_shardings",
           "batch_pspec"]
