"""Standalone worker bootstrap: run a ``WorkerNode`` on any host.

This is the multi-host entrypoint the cluster tier attaches to via
``ClusterFrontend(workers=["host:port", ...])`` — same wire protocol, same
``RegionServer`` semantics as a locally spawned worker, but the process is
started by whatever the fleet uses (ssh, k8s, systemd, a shell):

    PYTHONPATH=src python -m repro.serving.worker \\
        --bind 0.0.0.0:7077 \\
        --registry repro.serving.demo:DEMO_REGISTRY \\
        --token s3cret

The worker prints one machine-parseable line once it is listening::

    REPRO_WORKER_READY host=0.0.0.0 port=7077 pid=12345

(``--bind host:0`` lets the OS pick the port — the READY line / the
``--port-file`` is then the only way to learn it, which is how the tests
and ``benchmarks/cluster.py`` bootstrap subprocess workers race-free.)

The ``--registry`` spec is the payload symbol table: TDGs arrive over the
wire as JSON referencing task payloads *by name* (the paper's
compiler-emitted-TDG contract), and this worker re-links them by importing
``module:attr`` — a ``TaskFnRegistry`` or a factory returning one
(``--registry-kwargs`` JSON is passed to a factory). Frontends must resolve
a registry with the same symbols.

``--token`` (default: ``$REPRO_RPC_TOKEN``) gates every connection via the
RPC handshake; without it the worker accepts any client that speaks the
protocol — fine on localhost, not on a shared network. Artifact bytes
shipped by a frontend are checked against this host's device-topology
fingerprint at register time and rejected loudly (counted in
``aot_topology_rejects``; the tenant re-lowers) when they were compiled for
different hardware or a different jax version.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import threading

from .cluster import WorkerNode, resolve_registry

#: The READY-line contract, owned here next to its producer (``main``).
#: Tests and ``benchmarks/cluster.py`` parse it via
#: :func:`spawn_worker_subprocess` instead of keeping private copies.
READY_RE = re.compile(r"REPRO_WORKER_READY host=(\S+) port=(\d+)")


def spawn_worker_subprocess(registry_spec: str, token: str | None = None,
                            timeout: float = 120.0, extra_args=(),
                            ) -> tuple["subprocess.Popen", str]:
    """Bootstrap one worker subprocess on localhost; returns ``(proc, addr)``.

    The same-host analogue of an ssh/k8s bootstrap, used by the tests and
    ``benchmarks/cluster.py``: a plain ``subprocess`` (never
    ``multiprocessing`` — the frontend must hold no process handle
    semantics beyond POSIX), ``--bind 127.0.0.1:0``, address learned from
    the READY line. stderr is merged into stdout (two separate pipes can
    deadlock once either fills) and a reader thread keeps draining the
    pipe for the worker's lifetime, so chatty jax/XLA warnings can never
    block it. ``timeout`` is enforced even if the child prints nothing:
    the reader is awaited via an event, and a child that missed the
    deadline or exited early is killed and reported.
    """
    import repro

    src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + (os.pathsep + env["PYTHONPATH"]
                                   if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.serving.worker",
           "--bind", "127.0.0.1:0", "--registry", registry_spec]
    if token is not None:
        cmd += ["--token", token]
    cmd += list(extra_args)
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env)
    ready = threading.Event()
    found: list[str] = []

    def _drain() -> None:
        for line in proc.stdout:
            if not ready.is_set():
                m = READY_RE.search(line)
                if m:
                    found.append(f"{m.group(1)}:{m.group(2)}")
                    ready.set()
        ready.set()                      # EOF: unblock the waiter either way

    t = threading.Thread(target=_drain, name="worker-bootstrap-drain",
                         daemon=True)
    t.start()
    if not ready.wait(timeout) or not found:
        proc.kill()
        proc.wait(timeout=10)
        raise RuntimeError(
            f"worker subprocess did not print REPRO_WORKER_READY within "
            f"{timeout}s (exit code {proc.poll()})")
    return proc, found[0]


def parse_bind(spec: str) -> tuple[str, int]:
    """``HOST:PORT`` -> ``(host, port)``; bare ``:PORT`` binds 127.0.0.1.

    Unlike ``spawner.parse_worker_spec`` (which addresses a peer), port 0
    is legal here — it means "let the OS pick". Out-of-range ports fail
    HERE with a clear message, not as an OverflowError out of ``bind()``.
    """
    host, sep, port = spec.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(f"--bind {spec!r} is not HOST:PORT")
    port_num = int(port)
    if not 0 <= port_num < 65536:
        raise ValueError(f"--bind {spec!r}: port must be 0-65535")
    return host or "127.0.0.1", port_num


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.worker",
        description="Bootstrap one cluster-tier worker: a RegionServer "
                    "behind the repro.serving.rpc listener, ready for a "
                    "ClusterFrontend to attach by host:port.")
    ap.add_argument("--bind", default="127.0.0.1:0",
                    help="HOST:PORT to listen on (port 0 = OS-assigned; "
                         "read the REPRO_WORKER_READY line or --port-file)")
    ap.add_argument("--registry", required=True,
                    help="importable 'module:attr' TaskFnRegistry (or "
                         "factory) that re-links task payload symbols")
    ap.add_argument("--registry-kwargs", default=None, metavar="JSON",
                    help="JSON kwargs for a factory-style --registry spec")
    ap.add_argument("--token", default=os.environ.get("REPRO_RPC_TOKEN"),
                    help="handshake auth token (default: $REPRO_RPC_TOKEN; "
                         "unset = accept any client)")
    ap.add_argument("--port-file", default=None, metavar="PATH",
                    help="also write 'host port pid' to PATH (atomically) "
                         "once listening — for script bootstraps")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="RegionServer coalescing ceiling")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="RegionServer admission window")
    ap.add_argument("--pool-capacity", type=int, default=64,
                    help="warm executable pool LRU bound")
    ap.add_argument("--queue-bound", type=int, default=None,
                    help="admission-queue depth bound; submissions beyond "
                         "it are shed with QueueFull (default: "
                         "$REPRO_QUEUE_BOUND or unbounded)")
    ap.add_argument("--transport", default=None,
                    choices=("tcp", "shm", "auto"),
                    help="data-plane policy for THIS worker (default: "
                         "$REPRO_RPC_TRANSPORT or auto): tcp refuses "
                         "frontend shm-setup offers, shm/auto attach when "
                         "the segments are reachable")
    ap.add_argument("--request-level", action="store_true",
                    help="use the legacy run-to-completion batch dispatcher "
                         "instead of continuous (iteration-level) batching "
                         "(default: continuous, or $REPRO_CONTINUOUS)")
    args = ap.parse_args(argv)

    host, port = parse_bind(args.bind)
    registry_kwargs = (json.loads(args.registry_kwargs)
                       if args.registry_kwargs else None)
    registry = resolve_registry(args.registry, registry_kwargs)
    node = WorkerNode(registry, host=host, port=port, token=args.token,
                      transport=args.transport,
                      max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
                      pool_capacity=args.pool_capacity,
                      queue_bound=args.queue_bound,
                      continuous=False if args.request_level else None)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{host} {node.port} {os.getpid()}\n")
        os.replace(tmp, args.port_file)   # atomic: readers never see partial
    print(f"REPRO_WORKER_READY host={host} port={node.port} "
          f"pid={os.getpid()}", flush=True)
    node.serve_forever()
    print(f"repro worker pid={os.getpid()} shut down", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
