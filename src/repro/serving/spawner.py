"""Worker spawners: how a ``ClusterFrontend`` obtains its worker fleet.

PR 4's cluster tier could only ``multiprocessing``-spawn workers on the
frontend's own host — a single-host demo. This module splits "where a
worker comes from" out of the frontend behind two spawners with one
contract, so local and remote workers are interchangeable behind the same
``StickyRouter`` / artifact-shipping / death-requeue machinery:

* :class:`LocalSpawner` — the PR 4 path, kept: fork/spawn a fresh process
  on this host running ``WorkerNode`` (fresh jax runtime per worker), learn
  its ephemeral RPC port over a pipe, connect.
* :class:`RemoteSpawner` — the multi-host path: *attach* to a pre-started
  worker (``python -m repro.serving.worker --bind HOST:PORT ...``) by TCP
  address. The frontend never owns the process — bootstrap is whatever the
  host fleet uses (ssh, k8s, systemd); the wire protocol is the whole
  contract.

Both return a :class:`SpawnedWorker` whose connection has already completed
the :func:`repro.serving.rpc.client_handshake` (protocol version pinned,
token checked, worker identity + device-topology fingerprint captured), so
the frontend talks to every worker identically after this point.

Worker *specs* (the ``ClusterFrontend(workers=...)`` list form) are
strings: ``"host:port"`` attaches remotely, the literal ``"local"`` spawns
on this host — mixing both in one list is the expected shape for a
frontend that keeps some capacity local while farming the rest out.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import re
from typing import Any, Mapping

from . import faults as _faults
from . import rpc

#: ``host:port`` — hostname/IPv4 label followed by a port. (IPv6 literals
#: would need brackets; the serving tier targets DNS names and IPv4.)
_ADDR_RE = re.compile(r"^(?P<host>[A-Za-z0-9._-]+):(?P<port>\d{1,5})$")

#: The spec string that means "spawn a worker process on this host".
LOCAL_SPEC = "local"


def parse_worker_spec(spec: Any) -> tuple[str, int] | None:
    """Normalize one worker spec: ``None`` for local, ``(host, port)`` remote.

    Accepts the literal ``"local"`` (case-insensitive) or ``"host:port"``.
    Anything else — including a bare hostname with no port — is a
    ``ValueError`` naming the offending spec, so a typo'd fleet list fails
    at construction, not mid-registration.
    """
    if not isinstance(spec, str):
        raise ValueError(f"worker spec must be a string, got {spec!r}")
    if spec.strip().lower() == LOCAL_SPEC:
        return None
    m = _ADDR_RE.match(spec.strip())
    if m is None:
        raise ValueError(
            f"worker spec {spec!r} is neither 'local' nor 'host:port'")
    port = int(m.group("port"))
    if not 0 < port < 65536:
        raise ValueError(f"worker spec {spec!r} has an invalid port")
    return m.group("host"), port


@dataclasses.dataclass
class SpawnedWorker:
    """One ready worker: a handshaken connection plus provenance.

    ``process`` is the ``multiprocessing.Process`` for local workers and
    ``None`` for remote ones — the frontend's shutdown path keys off this
    (a local worker is joined/terminated/killed and asserted reaped; a
    remote worker gets a best-effort shutdown RPC and a connection close,
    because its lifecycle belongs to whoever bootstrapped it).
    """

    idx: int
    kind: str                      # "local" | "remote"
    address: tuple[str, int]
    conn: rpc.RpcConnection
    process: Any = None
    info: dict = dataclasses.field(default_factory=dict)   # handshake ack
    transport: str = "tcp"         # negotiated data plane: "tcp" | "shm"
    shm_fallback: bool = False     # shm was attempted and refused/failed
    spawner: Any = None            # producer, for respawn(); None = remote

    def respawn(self, timeout: float = 120.0) -> "SpawnedWorker":
        """Start a replacement worker in this one's slot.

        The self-healing contract the cluster supervisor builds on: reap
        whatever is left of this worker's process, spawn a fresh one, and
        hand back a new ready :class:`SpawnedWorker` with the same ``idx``.
        The replacement's first connection is **TCP-only** even when the
        spawner would normally negotiate shm — the death that got us here
        may have been mid-ring-write, and a clean control plane first is
        worth one counted ``shm_fallback`` (a later reconnect can upgrade).
        Remote workers are never respawned from here: their lifecycle
        belongs to whoever bootstrapped them (:class:`SpawnError`).
        """
        if self.spawner is None or self.kind != "local":
            raise SpawnError(
                f"worker {self.idx} ({self.kind}) cannot be respawned from "
                "this frontend — its process lifecycle is owned elsewhere")
        return self.spawner.respawn(self, timeout=timeout)


def _negotiate_transport(conn: rpc.RpcConnection, attempt: bool,
                         shm_bytes: int | None) -> tuple[str, bool]:
    """Try the shm data plane right after the handshake (single-threaded
    window: no reader thread exists yet, so the setup round-trip owns the
    connection). Returns ``(transport, fallback)`` — a refusal or attach
    failure is a TCP fallback, never an error; a *connection* failure
    mid-negotiation propagates (dead worker, not a transport downgrade)."""
    if not attempt:
        return "tcp", False
    from . import shm

    if shm.negotiate_rings(conn, size=shm_bytes):
        return "shm", False
    return "tcp", True


class SpawnError(RuntimeError):
    """A worker could not be spawned/attached (port never reported, TCP
    connect refused, handshake rejected)."""


def _worker_main(port_conn, registry_spec, registry_kwargs, server_kwargs,
                 token) -> None:
    """Spawned-process entry point: build the node, report the port, serve."""
    # Deferred import: this body runs in the child process; importing
    # cluster at module scope here would cycle (cluster imports spawner).
    from .cluster import WorkerNode, resolve_registry

    registry = resolve_registry(registry_spec, registry_kwargs)
    node = WorkerNode(registry, token=token, **(server_kwargs or {}))
    try:
        port_conn.send(node.port)
    finally:
        port_conn.close()
    node.serve_forever()


class LocalSpawner:
    """Spawn ``WorkerNode`` processes on this host via ``multiprocessing``.

    Two-phase on purpose: :meth:`launch` starts the process and returns
    immediately so a frontend can overlap N cold starts (a fresh
    interpreter + jax import is seconds each); :meth:`connect` then waits
    for the reported port, TCP-connects and handshakes.
    """

    def __init__(self, registry_spec: str,
                 registry_kwargs: Mapping[str, Any] | None,
                 server_kwargs: Mapping[str, Any] | None,
                 token: str | None, start_method: str = "spawn",
                 transport: str = "auto", shm_bytes: int | None = None):
        self.registry_spec = registry_spec
        self.registry_kwargs = dict(registry_kwargs or {})
        self.server_kwargs = dict(server_kwargs or {})
        self.token = token
        # "shm" and "auto" both attempt the shared-memory data plane for
        # spawned workers — same host is guaranteed here. The worker's own
        # policy (inherited env, since a spawned child shares os.environ
        # semantics of its start method, or an explicit --transport) can
        # still refuse, which lands as a counted TCP fallback.
        self.transport = rpc.transport_mode(transport)
        self.shm_bytes = shm_bytes
        self._ctx = multiprocessing.get_context(start_method)

    def launch(self, idx: int, name: str) -> tuple:
        if _faults.ENABLED:
            # Chaos hook: a "fail" rule here simulates a host that cannot
            # start workers (fork bomb protection, OOM) — the supervisor's
            # respawn backoff is what this exercises.
            _faults.on_point("spawn")
        parent_conn, child_conn = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=_worker_main,
            args=(child_conn, self.registry_spec, self.registry_kwargs,
                  self.server_kwargs, self.token),
            name=name, daemon=True)
        proc.start()
        child_conn.close()
        return idx, proc, parent_conn

    def connect(self, pending: tuple, timeout: float,
                force_tcp: bool = False) -> SpawnedWorker:
        idx, proc, parent_conn = pending
        if not parent_conn.poll(timeout):
            raise SpawnError(f"worker {idx} did not report its RPC port "
                             f"within {timeout}s")
        port = parent_conn.recv()
        parent_conn.close()
        conn = rpc.connect("127.0.0.1", port, timeout=timeout)
        would_shm = self.transport in ("shm", "auto")
        try:
            info = rpc.client_handshake(conn, token=self.token)
            transport, fallback = _negotiate_transport(
                conn, would_shm and not force_tcp, self.shm_bytes)
        except Exception:
            conn.close()
            raise
        if force_tcp and would_shm:
            fallback = True     # shm deliberately suppressed; still counted
        return SpawnedWorker(idx=idx, kind="local",
                             address=("127.0.0.1", port), conn=conn,
                             process=proc, info=info,
                             transport=transport, shm_fallback=fallback,
                             spawner=self)

    def respawn(self, old: SpawnedWorker, timeout: float = 120.0
                ) -> SpawnedWorker:
        """Reap ``old``'s process and spawn a ready replacement in its slot.

        The replacement's first connection is TCP-only (see
        :meth:`SpawnedWorker.respawn`). The old connection is NOT touched
        here — the supervisor already closed it when it declared the worker
        dead (that close is what unlinks the shm rings and wakes any
        blocked dispatcher).
        """
        proc = old.process
        if proc is not None and proc.is_alive():
            # A declared-dead-but-breathing process (hung, stopped, or just
            # slow past its lease) must not linger beside its replacement.
            proc.terminate()
            proc.join(timeout=5.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)
        elif proc is not None:
            proc.join(timeout=5.0)      # reap the zombie
        name = getattr(proc, "name", None) or f"repro-worker-{old.idx}"
        pending = self.launch(old.idx, name)
        try:
            return self.connect(pending, timeout, force_tcp=True)
        except Exception:
            # The replacement never became ready; don't leak its process.
            _, proc2, _ = pending
            if proc2.is_alive():
                proc2.terminate()
                proc2.join(timeout=5.0)
                if proc2.is_alive():
                    proc2.kill()
            raise


class RemoteSpawner:
    """Attach to pre-started workers (``python -m repro.serving.worker``).

    No process handle, no bootstrap: the worker is already listening
    wherever its host started it. Attachment is TCP connect + handshake;
    the ack's ``topology`` field is the remote device fingerprint the
    frontend surfaces in :meth:`ClusterFrontend.health`.
    """

    def __init__(self, token: str | None, transport: str = "auto",
                 shm_bytes: int | None = None):
        self.token = token
        # Remote default is tcp: "auto" only means shm for workers we
        # spawned ourselves (same host guaranteed). An explicit "shm"
        # still *attempts* it remotely — a "remote" address can point at
        # this host, and a wrong guess is just a counted fallback.
        self.transport = rpc.transport_mode(transport)
        self.shm_bytes = shm_bytes

    def attach(self, idx: int, host: str, port: int,
               timeout: float) -> SpawnedWorker:
        try:
            conn = rpc.connect(host, port, timeout=timeout)
        except OSError as exc:
            raise SpawnError(
                f"worker {idx}: cannot connect to {host}:{port} ({exc}) — "
                "is `python -m repro.serving.worker` running there?"
            ) from exc
        try:
            info = rpc.client_handshake(conn, token=self.token)
            transport, fallback = _negotiate_transport(
                conn, self.transport == "shm", self.shm_bytes)
        except Exception:
            conn.close()
            raise
        return SpawnedWorker(idx=idx, kind="remote", address=(host, port),
                             conn=conn, info=info,
                             transport=transport, shm_fallback=fallback)
