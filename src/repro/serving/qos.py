"""Per-tenant QoS primitives: token-bucket rate limits + priority tiers.

PR 7 gave the serving tier *global* backpressure — a bounded admission
queue (``REPRO_QUEUE_BOUND`` -> ``QueueFull``) and per-request deadlines.
What real multi-tenant traffic needs on top is *per-tenant fairness*: one
chatty tenant must not starve its neighbours, and paying tiers must see
better tail latency than best-effort ones. This module holds the two
mechanisms, deliberately free of server state so they unit-test without
threads or clocks:

* :class:`TokenBucket` — the classic leaky-refill limiter. ``rate`` is
  sustained requests/second, ``burst`` the bucket depth (default: one
  second's worth). All time is injectable (``now=``) so accounting under
  burst is testable deterministically.
* :class:`SmoothWRR` — nginx-style smooth weighted round-robin. Used twice
  by the continuous scheduler: to pick which structure class steps next,
  and to pick which *tier* fills the next free slot of a resident batch.
  Weight is :func:`tier_weight` (``2**tier``), so tier 1 gets ~2x the
  admission slots of tier 0 under contention while tier 0 is never starved
  — which composes with queue-bound shedding so low-tier work sheds first.

Tier/rate defaults come from the environment so fleets configure QoS
without code: ``REPRO_TENANT_TIER`` / ``REPRO_TENANT_RATE`` each accept a
single value applied to every tenant (``"1"``) or a per-tenant spec
(``"free=0,paid=1"``, with an optional ``*=N`` fallback). Explicit
``register_tenant(tier=..., rate=...)`` arguments beat the environment.
"""
from __future__ import annotations

import os
import time
from typing import Hashable, Mapping

TENANT_RATE_ENV = "REPRO_TENANT_RATE"
TENANT_TIER_ENV = "REPRO_TENANT_TIER"


def tier_weight(tier: int) -> int:
    """Scheduling weight of a tier: ``2**tier`` (tier 0 -> 1, tier 1 -> 2).

    Exponential so each tier up doubles its share of contended admission
    slots; never zero, so no tier can be starved outright.
    """
    return 1 << max(0, min(16, int(tier)))


def _parse_spec(raw: str, tenant: str) -> str | None:
    """Resolve ``raw`` (``"2"`` or ``"a=1,b=2,*=0"``) for ``tenant``."""
    raw = raw.strip()
    if not raw:
        return None
    if "=" not in raw:
        return raw
    fallback = None
    for part in raw.split(","):
        name, sep, value = part.strip().partition("=")
        if not sep:
            continue
        if name == tenant:
            return value.strip()
        if name == "*":
            fallback = value.strip()
    return fallback


def tenant_tier_default(tenant: str) -> int:
    """Env-configured tier for ``tenant`` (``REPRO_TENANT_TIER``; 0 = base)."""
    value = _parse_spec(os.environ.get(TENANT_TIER_ENV, ""), tenant)
    try:
        return max(0, int(value)) if value else 0
    except ValueError:
        return 0


def tenant_rate_default(tenant: str) -> float:
    """Env-configured rate for ``tenant`` (req/s; 0 = unlimited)."""
    value = _parse_spec(os.environ.get(TENANT_RATE_ENV, ""), tenant)
    try:
        return max(0.0, float(value)) if value else 0.0
    except ValueError:
        return 0.0


class TokenBucket:
    """Token-bucket rate limiter: ``rate`` tokens/s, depth ``burst``.

    Not thread-safe by itself — the server calls it under its admission
    lock. The clock is injectable everywhere (``now=`` in seconds, any
    monotonic origin) so tests can drive accounting deterministically;
    ``None`` falls back to ``time.monotonic()``.
    """

    def __init__(self, rate: float, burst: float | None = None,
                 now: float | None = None):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(1.0, rate)
        self.tokens = self.burst            # a fresh tenant may burst
        self._t = time.monotonic() if now is None else float(now)

    def _refill(self, now: float | None) -> float:
        now = time.monotonic() if now is None else float(now)
        if now > self._t:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t) * self.rate)
        self._t = max(self._t, now)
        return now

    def take(self, n: float = 1, now: float | None = None) -> bool:
        """Consume ``n`` tokens if available; False = rate-limit the caller."""
        self._refill(now)
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    def available(self, now: float | None = None) -> float:
        """Tokens currently in the bucket (after refill accounting)."""
        self._refill(now)
        return self.tokens


class SmoothWRR:
    """Smooth weighted round-robin over a *dynamic* candidate set.

    The nginx algorithm: each pick adds every candidate's weight to its
    running ``current`` score, selects the max, then subtracts the total
    weight from the winner. For static weights ``{a: 2, b: 1}`` the pick
    sequence is ``a b a  a b a ...`` — proportional *and* interleaved
    (never ``a a b``), which is what keeps low tiers from bursty
    starvation. Candidates may come and go between picks; state for keys
    absent from ``weights`` is dropped so departed classes/tiers cannot
    skew future picks.
    """

    def __init__(self) -> None:
        self._current: dict[Hashable, float] = {}

    def pick(self, weights: Mapping[Hashable, float]) -> Hashable | None:
        if not weights:
            return None
        self._current = {k: self._current.get(k, 0.0) for k in weights}
        total = float(sum(weights.values()))
        best = None
        for key, weight in weights.items():
            self._current[key] += float(weight)
            if best is None or self._current[key] > self._current[best]:
                best = key
        self._current[best] -= total
        return best
