"""Importable demo payloads for the cluster tier's tests and benchmarks.

Worker processes re-link task payloads *by registered name* (the TDG JSON
carries symbols, not code — exactly the paper's compiler-emitted-TDG
contract), so any payload driven through :class:`~repro.serving.cluster.
ClusterFrontend` must live in a module both the frontend and the spawned
workers can import. Tests and ``benchmarks/cluster.py`` use this one:
pass ``registry="repro.serving.demo:DEMO_REGISTRY"``.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.serialize import TaskFnRegistry
from ..core.tdg import TDG

DEMO_REGISTRY = TaskFnRegistry()


@DEMO_REGISTRY.register("demo_mix")
def demo_mix(x, w):
    """The serving benchmark's body: a tanh-matmul residual mix."""
    return jnp.tanh(x @ w) * 0.5 + x


@DEMO_REGISTRY.register("demo_affine")
def demo_affine(x, w):
    """A second, structurally distinguishable payload (different symbol)."""
    return x @ w + 1.0


def demo_region(name: str, waves: int = 2, width: int = 2,
                body=demo_mix) -> TDG:
    """A ``waves x width`` dependent grid over slots ``x0..x{width-1}`` + ``w``.

    Same shape as ``benchmarks/serving.py``'s tenant region: every task
    reads the shared weight slot ``w`` and read-modify-writes its private
    column, so consecutive waves chain RAW edges per column.
    """
    tdg = TDG(name)
    for wv in range(waves):
        for s in range(width):
            tdg.add_task(body, ins=[f"x{s}", "w"], outs=[f"x{s}"],
                         name=f"t{wv}.{s}")
    return tdg
