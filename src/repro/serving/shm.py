"""Same-host shared-memory data plane for the cluster wire path.

TCP is a fine control plane, but for a locally spawned worker every tensor
blob sent through it is copied twice through kernel socket buffers. This
module gives :class:`~repro.serving.rpc.RpcConnection` an optional data
plane: one ``multiprocessing.shared_memory`` ring per direction, carrying
only the *blob bytes* of a frame, while the (small) frame itself still
travels over the socket and merely references ring positions. The socket
stays the source of ordering and liveness — there is no cross-process
atomic anywhere in this file.

Design constraints, and how the ring meets them:

* **Single producer, single consumer.** Each ring is written by exactly one
  thread (the frontend's per-worker dispatcher, or the worker's reply
  writer — both send ``codec="binary"`` frames) and drained by exactly one
  reader thread. That discipline is what makes the *cumulative* ack below
  sound: blobs are consumed in the order they were allocated because one
  thread allocates and one thread (the peer's frame reader) consumes, in
  frame order.
* **Flow control over TCP, not shared counters.** The sender tracks an
  absolute ``head`` (bytes ever allocated) and ``tail`` (bytes the peer has
  confirmed consuming). After the receiver copies a frame's blobs out of
  the ring it sends a tiny ``shm-ack`` frame carrying the highest absolute
  end position it consumed; :meth:`ack` advances ``tail``. A full ring
  blocks :meth:`alloc` until an ack arrives — bounded memory, no busy-wait,
  no cross-process mutex.
* **Contiguous blobs.** ``alloc`` pads to the segment end rather than
  wrapping a blob, so :meth:`read` is always one slice. Blobs are capped at
  ``size // 2`` (``max_blob``): with that bound the pad-plus-blob need can
  never exceed the segment, so an empty ring always makes progress.
  Oversized blobs fall back to inline TCP placement in the frame codec.
* **Bounded hostile input.** :meth:`read` validates the (attacker-
  controlled, frame-supplied) position/length against the segment bounds
  and raises :class:`~repro.serving.rpc.ProtocolError` — a bogus reference
  can yield garbage bytes (caught by the codec's dtype-times-shape check)
  but never an out-of-bounds access or a crash.

Lifecycle: the frontend *creates* both rings and offers their names to the
worker in a ``shm-setup`` control frame; the worker *attaches* (Python
3.10's ``SharedMemory`` has no ``track=False``, so the attach path
unregisters from the resource tracker to keep a worker exit from unlinking
segments the frontend still owns). The creator unlinks at close.
"""
from __future__ import annotations

import os
import secrets
import threading
import time
from multiprocessing import shared_memory

from . import faults as _faults
from .rpc import ProtocolError

#: Default per-direction ring size (bytes); override with
#: ``REPRO_RPC_SHM_BYTES``. Backed by tmpfs pages allocated lazily on
#: write, so an idle ring costs address space, not memory.
DEFAULT_RING_BYTES = 1 << 26


_attach_lock = threading.Lock()


def _attach_untracked(name: str) -> shared_memory.SharedMemory:
    """Attach to ``name`` without resource-tracker registration.

    ``SharedMemory(name=...)`` registers the segment with the attaching
    process's resource tracker, which would unlink it when that tracker
    winds down — destroying a segment the *creator* still owns (subprocess
    workers have their own tracker) or, when the tracker is shared
    (``multiprocessing``-spawned workers), cancelling the creator's own
    registration and spewing KeyErrors at unlink. Python 3.13 grew
    ``track=False``; on 3.10 the clean equivalent is suppressing the
    register call for the duration of the attach — unlike the
    unregister-after idiom, no tracker message is ever sent, so the
    creator's registration stays intact.
    """
    from multiprocessing import resource_tracker
    with _attach_lock:
        orig = resource_tracker.register
        resource_tracker.register = lambda *a, **k: None
        try:
            return shared_memory.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig


class ShmRing:
    """One direction of the shared-memory data plane (SPSC byte ring)."""

    def __init__(self, seg: shared_memory.SharedMemory, size: int,
                 created: bool):
        if seg.size < size:
            seg.close()
            raise ProtocolError(
                f"shm segment {seg.name!r} is {seg.size} bytes, peer "
                f"announced {size}")
        self._seg = seg
        self.name = seg.name
        self.size = int(size)        # logical size: both sides mod by THIS,
        self.created = created       # never seg.size (page-rounded on attach)
        self.max_blob = self.size // 2
        self._head = 0               # absolute bytes allocated (sender side)
        self._tail = 0               # absolute bytes acked by the peer
        self._closed = False
        self._cv = threading.Condition()

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def create(cls, size: int) -> "ShmRing":
        if size < 2:
            raise ValueError(f"ring size {size} is too small")
        name = f"repro-ring-{os.getpid()}-{secrets.token_hex(6)}"
        seg = shared_memory.SharedMemory(name=name, create=True, size=size)
        return cls(seg, size, created=True)

    @classmethod
    def attach(cls, name: str, size: int) -> "ShmRing":
        return cls(_attach_untracked(name), size, created=False)

    def close(self, unlink: bool | None = None) -> None:
        """Wake blocked allocators, release the mapping; creator unlinks."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        try:
            self._seg.close()
        except BufferError:
            # A racing read/write still exports a memoryview over the
            # mapping; the process-exit cleanup will drop it.
            pass
        if unlink if unlink is not None else self.created:
            try:
                self._seg.unlink()
            except FileNotFoundError:
                pass

    # --------------------------------------------------------------- sender
    def alloc(self, n: int, timeout: float = 120.0) -> int:
        """Reserve ``n`` contiguous bytes; returns the absolute position.

        Blocks (bounded by ``timeout``) while the peer owes acks for the
        space. ``ValueError`` for blobs that can never fit (callers fall
        back to inline placement); :class:`ProtocolError` on timeout or a
        closed ring (callers treat it as a dead connection).
        """
        if n > self.max_blob:
            raise ValueError(
                f"blob of {n} bytes exceeds the ring's {self.max_blob}-byte "
                "contiguity bound")
        deadline = time.monotonic() + timeout
        with self._cv:
            while True:
                if self._closed:
                    raise ProtocolError("shm ring closed while allocating")
                offset = self._head % self.size
                pad = self.size - offset if offset + n > self.size else 0
                if pad + n <= self.size - (self._head - self._tail):
                    self._head += pad            # skip the unusable tail-end
                    pos = self._head
                    self._head += n
                    return pos
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise ProtocolError(
                        f"shm ring full for {timeout}s (peer not acking); "
                        "treating the connection as dead")
                self._cv.wait(min(remaining, 1.0))

    def write(self, pos: int, data: bytes) -> None:
        offset = pos % self.size
        self._seg.buf[offset:offset + len(data)] = data

    def ack(self, pos: int) -> None:
        """Apply a peer ack: everything up to absolute ``pos`` is consumed."""
        if _faults.ENABLED:
            # Chaos hook: dropping an ack stalls the ring — the credit it
            # carried never lands, so a sender that fills the segment blocks
            # in alloc() until a LATER cumulative ack arrives (acks are
            # absolute positions, so one lost ack self-heals under further
            # traffic; a stalled *idle* ring is what the connection-close
            # wakeup and the supervisor's lease exist to break).
            action = _faults.on_point("ring_ack")
            if action == "drop":
                return
        with self._cv:
            if pos > self._tail:
                self._tail = pos
            self._cv.notify_all()

    # ------------------------------------------------------------- receiver
    def read(self, pos: int, n: int) -> bytes:
        """Copy one blob out. Bounds-checked: ``pos``/``n`` come off the
        wire and must never index outside the segment."""
        if not isinstance(pos, int) or not isinstance(n, int) \
                or pos < 0 or n < 0 or n > self.size:
            raise ProtocolError(
                f"shm blob reference (pos={pos!r}, len={n!r}) is not a "
                "sane segment span")
        offset = pos % self.size
        if offset + n > self.size:
            raise ProtocolError(
                f"shm blob reference overruns the ring segment "
                f"(offset {offset} + {n} > {self.size})")
        return bytes(self._seg.buf[offset:offset + n])

    # -------------------------------------------------------------- reports
    def stats(self) -> dict:
        with self._cv:
            return {"size": self.size, "allocated": self._head,
                    "acked": self._tail,
                    "outstanding": self._head - self._tail}


def negotiate_rings(conn, size: int | None = None) -> bool:
    """Client side of shm transport setup (run before any reader thread).

    Creates both rings, offers their names in a ``shm-setup`` frame, and
    attaches them to ``conn`` iff the peer reports a successful attach.
    Returns ``False`` — with both segments destroyed — when the peer
    refuses (worker pinned to tcp, cross-host attach failure) or segments
    cannot be created here; connection-level failures propagate, because a
    peer that breaks the socket mid-setup is a dead worker, not a
    transport downgrade.
    """
    size = DEFAULT_RING_BYTES if size is None else int(size)
    try:
        tx = ShmRing.create(size)
    except OSError:
        return False
    try:
        rx = ShmRing.create(size)
    except OSError:
        tx.close(unlink=True)
        return False
    try:
        conn.send({"op": "shm-setup", "id": 0, "tx": tx.name, "rx": rx.name,
                   "size": size})
        reply = conn.recv()
    except Exception:
        tx.close(unlink=True)
        rx.close(unlink=True)
        raise
    if not (isinstance(reply, dict) and reply.get("attached")):
        tx.close(unlink=True)
        rx.close(unlink=True)
        return False
    conn.attach_rings(send_ring=tx, recv_ring=rx)
    return True
