"""Multi-tenant replay serving: RegionServer over interned/AOT executables.

The serving tier of the Taskgraph reproduction (see docs/serving.md):
an admission queue coalesces concurrent requests against structurally
identical regions into one batched fused replay — continuously, at the
iteration level, with tenants joining/leaving a resident per-class batch
between fused steps — an LRU warm pool shares compiled executables across
tenants, per-tenant QoS (priority tiers + token-bucket rate limits) shapes
admission under load, and metrics (including a per-batch execution-pattern
trace ring) expose queue/batch/latency behaviour so detrimental execution
patterns are observable. The cluster tier (:mod:`repro.serving.cluster`)
puts a socket-RPC front on ``RegionServer.submit`` and ships warm compiled
artifacts to worker processes instead of re-lowering per host.
"""
from .cluster import (ClusterError, ClusterFrontend, ClusterRemoteError,
                      StickyRouter, WorkerDied, WorkerNode, resolve_registry)
from .faults import FaultPlan, InjectedFault
from .metrics import (TRACE_SCHEMA, ExecutionTraceRing, LatencyReservoir,
                      ServerMetrics, percentile, validate_trace)
from .pool import PoolEntry, WarmPool
from .qos import SmoothWRR, TokenBucket, tier_weight
from .server import (DeadlineExceeded, QueueFull, RateLimited, RegionServer,
                     Tenant)
from .shm import ShmRing
from .spawner import (LocalSpawner, RemoteSpawner, SpawnedWorker, SpawnError,
                      parse_worker_spec)

__all__ = [
    "RegionServer", "Tenant", "DeadlineExceeded", "QueueFull", "RateLimited",
    "FaultPlan", "InjectedFault",
    "WarmPool", "PoolEntry",
    "ServerMetrics", "LatencyReservoir", "percentile",
    "ExecutionTraceRing", "TRACE_SCHEMA", "validate_trace",
    "TokenBucket", "SmoothWRR", "tier_weight",
    "ClusterFrontend", "WorkerNode", "StickyRouter", "resolve_registry",
    "ClusterError", "ClusterRemoteError", "WorkerDied",
    "ShmRing",
    "LocalSpawner", "RemoteSpawner", "SpawnedWorker", "SpawnError",
    "parse_worker_spec",
]
