"""Multi-tenant replay serving: RegionServer over interned/AOT executables.

The serving tier of the Taskgraph reproduction (see docs/architecture.md):
an admission queue coalesces concurrent requests against structurally
identical regions into one batched fused replay, an LRU warm pool shares
compiled executables across tenants, and metrics expose queue/batch/latency
behaviour so detrimental execution patterns are observable.
"""
from .metrics import LatencyReservoir, ServerMetrics, percentile
from .pool import PoolEntry, WarmPool
from .server import RegionServer, Tenant

__all__ = [
    "RegionServer", "Tenant",
    "WarmPool", "PoolEntry",
    "ServerMetrics", "LatencyReservoir", "percentile",
]
