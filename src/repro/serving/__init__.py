"""Multi-tenant replay serving: RegionServer over interned/AOT executables.

The serving tier of the Taskgraph reproduction (see docs/serving.md):
an admission queue coalesces concurrent requests against structurally
identical regions into one batched fused replay, an LRU warm pool shares
compiled executables across tenants, and metrics expose queue/batch/latency
behaviour so detrimental execution patterns are observable. The cluster
tier (:mod:`repro.serving.cluster`) puts a socket-RPC front on
``RegionServer.submit`` and ships warm compiled artifacts to worker
processes instead of re-lowering per host.
"""
from .cluster import (ClusterError, ClusterFrontend, ClusterRemoteError,
                      StickyRouter, WorkerDied, WorkerNode, resolve_registry)
from .faults import FaultPlan, InjectedFault
from .metrics import LatencyReservoir, ServerMetrics, percentile
from .pool import PoolEntry, WarmPool
from .server import DeadlineExceeded, QueueFull, RegionServer, Tenant
from .shm import ShmRing
from .spawner import (LocalSpawner, RemoteSpawner, SpawnedWorker, SpawnError,
                      parse_worker_spec)

__all__ = [
    "RegionServer", "Tenant", "DeadlineExceeded", "QueueFull",
    "FaultPlan", "InjectedFault",
    "WarmPool", "PoolEntry",
    "ServerMetrics", "LatencyReservoir", "percentile",
    "ClusterFrontend", "WorkerNode", "StickyRouter", "resolve_registry",
    "ClusterError", "ClusterRemoteError", "WorkerDied",
    "ShmRing",
    "LocalSpawner", "RemoteSpawner", "SpawnedWorker", "SpawnError",
    "parse_worker_spec",
]
