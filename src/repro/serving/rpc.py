"""Length-prefixed socket RPC: the cluster tier's wire layer (stdlib only).

The distributed frontend (:mod:`repro.serving.cluster`) needs exactly four
things from a wire protocol, and nothing a heavyweight RPC stack would add:

* **Framing** — one message per frame, length-prefixed (``struct``
  big-endian), so a reader never has to guess where a message ends. A
  frame is::

      [8B total] [4B header len] [header JSON utf-8]
                 [8B blob0 len] [blob0] [8B blob1 len] [blob1] ...

* **A pytree/tensor codec** — requests and replies carry buffer dicts whose
  leaves are jax/numpy arrays (including ``bfloat16`` and 0-d scalars),
  nested arbitrarily in dicts/lists/tuples. :func:`encode` walks the tree
  into a JSON-able skeleton plus a list of raw binary blobs (array bytes out
  of ``ndarray.tobytes()``; ``bytes`` values pass through untouched — that
  is how ``.aot`` artifact payloads ship in-band), and :func:`decode`
  rebuilds it exactly: tuples stay tuples, dict keys keep their types,
  arrays come back as numpy with the recorded dtype/shape. Every blob an
  array node references is validated against ``dtype × shape`` before
  ``frombuffer`` sees it — a disagreeing length is a :class:`ProtocolError`,
  never a numpy traceback from half-parsed attacker-controlled bytes.

* **Concurrent request/reply** — every message carries a caller-chosen
  ``id``; :class:`RpcConnection` serializes *writes* with a lock and lets a
  single reader thread dispatch replies by id, so many in-flight requests
  share one socket (which is what lets a worker's ``RegionServer`` coalesce
  requests that arrived over the same connection).

* **A handshake** — the first exchange on a fresh connection
  (:func:`client_handshake` / :func:`server_handshake`) pins the protocol
  version and, when the listener was started with a token, authenticates
  the peer. Remote workers (``python -m repro.serving.worker``) accept TCP
  connections from anywhere they are bound; the token is what keeps a
  stray client from registering tenants or submitting work. Auth failures
  surface as :class:`AuthError` on both sides.

Array payloads are decoded to **numpy** (zero-copy ``frombuffer`` + reshape,
then a writable copy): the consumer is always about to hand them to jax,
which ingests numpy arrays (``bfloat16`` included, via ``ml_dtypes``'s numpy
registration) without an extra conversion step here.

The frame cap defaults to :data:`MAX_FRAME_BYTES` (8 GiB) and is
configurable via ``REPRO_RPC_MAX_FRAME`` (bytes) so deployments can bound
what a corrupt or hostile length prefix may allocate.
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
import time
from typing import Any

import numpy as np

_U32 = struct.Struct(">I")
_U64 = struct.Struct(">Q")

#: Default frame cap: a frame larger than this is a protocol error, not a
#: request — refuse it instead of trying to allocate whatever a corrupt
#: length prefix asks for. The outer frame length is a u64 on the wire, so
#: the cap (not the prefix format) is what bounds allocation. Override per
#: deployment with ``REPRO_RPC_MAX_FRAME`` (see :func:`max_frame_bytes`).
MAX_FRAME_BYTES = 1 << 33

_MAX_FRAME_ENV = "REPRO_RPC_MAX_FRAME"

#: Version pinned by the connection handshake. Bump when frames stop being
#: mutually intelligible; the handshake turns a skew into a loud
#: :class:`ProtocolError` instead of a hang or a garbage decode.
PROTOCOL_VERSION = 1

#: Frame cap applied to the *hello* frame specifically: an unauthenticated
#: peer gets 64 KiB to state its business, not the multi-GiB general cap —
#: pre-auth allocation must not be attacker-sized.
HELLO_MAX_BYTES = 1 << 16


def max_frame_bytes() -> int:
    """The effective frame cap: ``REPRO_RPC_MAX_FRAME`` or the default.

    Read per call (cheap: one env lookup) so long-lived workers honour an
    operator override without a restart dance in tests. An unparseable or
    non-positive value is a configuration error and raises
    :class:`ProtocolError` — silently falling back to 8 GiB would defeat
    the point of bounding allocation, and ProtocolError (rather than a
    bare ValueError) keeps the wire-path contract: reader loops treat it
    as a fatal connection error and fail pending work fast instead of
    dying silently.
    """
    raw = os.environ.get(_MAX_FRAME_ENV)
    if raw is None or not raw.strip():
        return MAX_FRAME_BYTES
    try:
        cap = int(raw)
    except ValueError:
        raise ProtocolError(
            f"{_MAX_FRAME_ENV}={raw!r} is not an integer byte count") from None
    if cap <= 0:
        raise ProtocolError(f"{_MAX_FRAME_ENV}={raw!r} must be positive")
    return cap


class ConnectionClosed(ConnectionError):
    """The peer closed the socket (EOF mid-frame or before one)."""


class ProtocolError(RuntimeError):
    """The bytes on the wire do not parse as a frame we wrote."""


class AuthError(ProtocolError):
    """The handshake failed authentication (missing or wrong token)."""


# --------------------------------------------------------------------- codec

def _enc(obj: Any, blobs: list[bytes]) -> Any:
    if obj is None or isinstance(obj, (bool, str)):
        return {"t": "p", "v": obj}
    if isinstance(obj, (int, float)) and not isinstance(obj, np.generic):
        return {"t": "p", "v": obj}
    if isinstance(obj, (bytes, bytearray, memoryview)):
        blobs.append(bytes(obj))
        return {"t": "b", "i": len(blobs) - 1}
    if isinstance(obj, tuple):
        return {"t": "t", "v": [_enc(x, blobs) for x in obj]}
    if isinstance(obj, list):
        return {"t": "l", "v": [_enc(x, blobs) for x in obj]}
    if isinstance(obj, dict):
        return {"t": "d",
                "v": [[_enc(k, blobs), _enc(v, blobs)]
                      for k, v in obj.items()]}
    if hasattr(obj, "shape") and hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        blobs.append(arr.tobytes())
        return {"t": "a", "i": len(blobs) - 1,
                "d": str(arr.dtype), "s": list(arr.shape)}
    raise TypeError(f"rpc codec cannot encode {type(obj).__name__}: {obj!r}")


def _blob(blobs: list[bytes], idx: Any) -> bytes:
    if not isinstance(idx, int) or not 0 <= idx < len(blobs):
        raise ProtocolError(
            f"blob index {idx!r} out of range (frame carries {len(blobs)})")
    return blobs[idx]


def _dec(node: Any, blobs: list[bytes]) -> Any:
    t = node["t"]
    if t == "p":
        return node["v"]
    if t == "b":
        return _blob(blobs, node["i"])
    if t == "t":
        return tuple(_dec(x, blobs) for x in node["v"])
    if t == "l":
        return [_dec(x, blobs) for x in node["v"]]
    if t == "d":
        return {_dec(k, blobs): _dec(v, blobs) for k, v in node["v"]}
    if t == "a":
        # np.dtype resolves "bfloat16" etc. because jax imports ml_dtypes,
        # which registers its extension dtypes with numpy.
        dtype = np.dtype(node["d"])
        shape = node["s"]
        if not isinstance(shape, list) or not all(
                isinstance(d, int) and not isinstance(d, bool) and d >= 0
                for d in shape):
            raise ProtocolError(f"array node has invalid shape {shape!r}")
        blob = _blob(blobs, node["i"])
        want = dtype.itemsize
        for d in shape:
            want *= d
        if len(blob) != want:
            raise ProtocolError(
                f"array blob of {len(blob)} bytes disagrees with "
                f"dtype {dtype} x shape {tuple(shape)} ({want} bytes)")
        arr = np.frombuffer(blob, dtype=dtype)
        return arr.reshape(tuple(shape)).copy()
    raise ProtocolError(f"unknown codec node type {t!r}")


def encode(obj: Any) -> bytes:
    """Serialize ``obj`` (JSON-able skeleton + binary tensor blobs) to a frame body."""
    blobs: list[bytes] = []
    header = json.dumps(_enc(obj, blobs)).encode("utf-8")
    parts = [_U32.pack(len(header)), header]
    for b in blobs:
        parts.append(_U64.pack(len(b)))
        parts.append(b)
    return b"".join(parts)


def decode(data: bytes) -> Any:
    """Inverse of :func:`encode`.

    Anything a peer could have actually put on the wire fails as
    :class:`ProtocolError` — malformed JSON, missing node keys, bogus
    dtypes — never as a raw ``KeyError``/``TypeError`` from half-parsed
    bytes (the reader loops treat ``ProtocolError`` as a fatal connection
    error; an unexpected exception type would kill them silently).
    """
    if len(data) < _U32.size:
        raise ProtocolError("truncated frame: missing header length")
    (hlen,) = _U32.unpack_from(data, 0)
    off = _U32.size
    if off + hlen > len(data):
        raise ProtocolError("truncated frame: header overruns body")
    try:
        header = json.loads(data[off:off + hlen].decode("utf-8"))
    except (ValueError, UnicodeDecodeError) as exc:
        raise ProtocolError(f"frame header is not valid JSON: {exc}") from exc
    off += hlen
    blobs: list[bytes] = []
    while off < len(data):
        if off + _U64.size > len(data):
            raise ProtocolError("truncated frame: blob length")
        (blen,) = _U64.unpack_from(data, off)
        off += _U64.size
        if off + blen > len(data):
            raise ProtocolError("truncated frame: blob overruns body")
        blobs.append(data[off:off + blen])
        off += blen
    try:
        return _dec(header, blobs)
    except ProtocolError:
        raise
    except (KeyError, TypeError, ValueError) as exc:
        raise ProtocolError(
            f"malformed codec node ({type(exc).__name__}: {exc})") from exc


# ------------------------------------------------------------------- framing

def _recv_exact(sock: socket.socket, n: int,
                deadline: float | None = None) -> bytes:
    """Read exactly ``n`` bytes; ``deadline`` (``time.monotonic`` value) is
    an ABSOLUTE bound across all chunks — a peer trickling one byte per
    idle-timeout window cannot stretch it (each chunk's socket timeout is
    the *remaining* budget)."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise ProtocolError(f"deadline exceeded after {got}/{n} bytes")
            sock.settimeout(remaining)
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except socket.timeout:
            raise ProtocolError(
                f"deadline exceeded after {got}/{n} bytes") from None
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_msg(sock: socket.socket, obj: Any) -> int:
    """Encode + frame + send one message; returns bytes written."""
    body = encode(obj)
    cap = max_frame_bytes()
    if len(body) > cap:
        raise ProtocolError(
            f"frame of {len(body)} bytes exceeds the {cap}-byte cap "
            f"(raise {_MAX_FRAME_ENV} if this payload is legitimate)")
    sock.sendall(_U64.pack(len(body)) + body)
    return _U64.size + len(body)


def recv_msg_sized(sock: socket.socket, cap: int | None = None,
                   deadline: float | None = None) -> tuple[Any, int]:
    """Receive one framed message; returns ``(obj, wire_bytes_consumed)``.

    The byte count is the real on-wire size (length prefix included), which
    is what :class:`RpcConnection` accounts — blocks; raises
    :class:`ConnectionClosed` on EOF and :class:`ProtocolError` on a frame
    announcing more than ``cap`` (default :func:`max_frame_bytes`).
    ``deadline`` bounds the whole receive absolutely (the pre-auth
    handshake path passes both).
    """
    (n,) = _U64.unpack(_recv_exact(sock, _U64.size, deadline))
    if cap is None:
        cap = max_frame_bytes()
    if n > cap:
        raise ProtocolError(
            f"peer announced a {n}-byte frame exceeding the {cap}-byte cap "
            f"({_MAX_FRAME_ENV}); refusing")
    return decode(_recv_exact(sock, n, deadline)), _U64.size + n


def recv_msg(sock: socket.socket) -> Any:
    """Receive + decode one framed message (blocks; raises ConnectionClosed on EOF)."""
    return recv_msg_sized(sock)[0]


class RpcConnection:
    """One socket shared by many in-flight requests.

    Writes are serialized under a lock (frames must not interleave); reads
    are left to exactly one owner — either a caller that knows it is the
    only reader (:meth:`request`, the worker-side sync pattern) or a
    dedicated reader thread that matches replies to requests by ``id`` (the
    frontend pattern — see ``cluster._WorkerHandle``). Mixing both on one
    connection is a caller bug.

    The connection accounts real wire traffic in both directions:
    ``bytes_sent`` / ``bytes_received`` are on-wire byte totals (length
    prefixes included) and ``messages_sent`` / ``messages_received`` count
    frames — the per-worker wire totals ``ClusterFrontend.stats()``
    surfaces.
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._wlock = threading.Lock()
        self._bytes_sent = 0
        self._bytes_received = 0
        self._messages_sent = 0
        self._messages_received = 0

    def send(self, obj: Any) -> None:
        with self._wlock:
            self._bytes_sent += send_msg(self.sock, obj)
            self._messages_sent += 1

    def recv(self, cap: int | None = None,
             deadline: float | None = None) -> Any:
        msg, nbytes = recv_msg_sized(self.sock, cap=cap, deadline=deadline)
        self._bytes_received += nbytes
        self._messages_received += 1
        return msg

    def request(self, obj: Any) -> Any:
        """Sync send-then-recv for single-reader callers (no id matching)."""
        self.send(obj)
        return self.recv()

    @property
    def bytes_sent(self) -> int:
        return self._bytes_sent

    @property
    def bytes_received(self) -> int:
        return self._bytes_received

    @property
    def messages_sent(self) -> int:
        return self._messages_sent

    @property
    def messages_received(self) -> int:
        return self._messages_received

    def wire_stats(self) -> dict:
        """Snapshot of this connection's traffic totals (both directions)."""
        return {"bytes_sent": self._bytes_sent,
                "bytes_received": self._bytes_received,
                "messages_sent": self._messages_sent,
                "messages_received": self._messages_received}

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


# ----------------------------------------------------------------- handshake

def client_handshake(conn: RpcConnection, token: str | None = None,
                     ) -> dict:
    """Open a fresh connection: send ``hello``, validate the ``hello-ack``.

    Must be the FIRST exchange on the connection (before any reader thread
    starts). Returns the ack — which carries whatever the listener chose to
    advertise (worker pid, port, device-topology fingerprint) — or raises
    :class:`AuthError` / :class:`ProtocolError` with the server's reason.
    """
    conn.send({"op": "hello", "proto": PROTOCOL_VERSION, "token": token})
    reply = conn.recv()
    if not isinstance(reply, dict):
        raise ProtocolError(f"handshake reply is not a message: {reply!r}")
    if reply.get("op") == "error":
        detail = reply.get("error", "handshake rejected")
        if reply.get("code") == "auth":
            raise AuthError(detail)
        raise ProtocolError(detail)
    if reply.get("op") != "hello-ack" or reply.get("proto") != PROTOCOL_VERSION:
        raise ProtocolError(f"unexpected handshake reply: {reply!r}")
    return reply


def server_handshake(conn: RpcConnection, token: str | None = None,
                     info: dict | None = None,
                     timeout: float | None = None) -> dict:
    """Validate the first frame of an accepted connection; ack or reject.

    ``token=None`` disables auth (the local-spawn case, where the frontend
    generated the token AND the worker — still checked for protocol
    version). On any failure the peer gets an ``error`` frame (``code:
    "auth"`` for token mismatches so the client can raise the right type)
    before this side raises; the caller should then drop the connection.
    ``info`` is advertised in the ack (pid, port, topology fingerprint).

    The pre-auth surface is hardened: the hello frame is capped at
    :data:`HELLO_MAX_BYTES` (an unauthenticated peer never gets a
    multi-GiB allocation), ``timeout`` is an ABSOLUTE deadline across the
    whole receive (a one-byte-per-idle-window trickler cannot stretch
    it), and the token comparison is timing-safe.
    """
    import hmac

    deadline = (time.monotonic() + timeout) if timeout is not None else None
    msg = conn.recv(cap=HELLO_MAX_BYTES, deadline=deadline)

    def _reject(code: str, detail: str) -> None:
        try:
            conn.send({"op": "error", "code": code, "error": detail})
        except OSError:
            pass
        raise (AuthError if code == "auth" else ProtocolError)(detail)

    if not isinstance(msg, dict) or msg.get("op") != "hello":
        _reject("proto", "expected a hello frame to open the connection")
    if msg.get("proto") != PROTOCOL_VERSION:
        _reject("proto", f"protocol version mismatch: peer speaks "
                f"{msg.get('proto')!r}, this side {PROTOCOL_VERSION}")
    if token is not None:
        peer = msg.get("token")
        if not isinstance(peer, str) or not hmac.compare_digest(
                peer.encode("utf-8"), token.encode("utf-8")):
            _reject("auth", "bad or missing auth token")
    conn.send({"op": "hello-ack", "proto": PROTOCOL_VERSION,
               **(info or {})})
    return msg


def connect(host: str, port: int, timeout: float | None = None
            ) -> RpcConnection:
    """TCP-connect to a worker's RPC port (``TCP_NODELAY`` — frames are small)."""
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return RpcConnection(sock)


def listener(host: str = "127.0.0.1", port: int = 0) -> socket.socket:
    """A listening socket; ``port=0`` lets the OS pick (read ``getsockname``)."""
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind((host, port))
    sock.listen(16)
    return sock
