"""Length-prefixed socket RPC: the cluster tier's wire layer (stdlib only).

The distributed frontend (:mod:`repro.serving.cluster`) needs exactly four
things from a wire protocol, and nothing a heavyweight RPC stack would add:

* **Framing** — one message per frame, length-prefixed (``struct``
  big-endian), so a reader never has to guess where a message ends. A
  frame body is::

      [1B codec tag 'J'|'B'] [4B header len] [header]
      [4B blob count] { [1B placement] [payload] } * count

  where placement ``0`` inlines the blob (``[8B len] [bytes]``) and
  placement ``1`` references the connection's shared-memory ring
  (``[8B absolute pos] [8B len]`` — see :mod:`repro.serving.shm`). The
  codec tag selects the header encoding: ``'J'`` is the JSON pytree
  skeleton (control frames: handshake, register, stats), ``'B'`` is the
  compact struct-packed binary codec below (the submit/result hot path,
  where JSON encode dominated per-request cost).

* **A pytree/tensor codec** — requests and replies carry buffer dicts whose
  leaves are jax/numpy arrays (including ``bfloat16`` and 0-d scalars),
  nested arbitrarily in dicts/lists/tuples. :func:`encode` walks the tree
  into a header skeleton plus a list of raw binary blobs (array bytes out
  of ``ndarray.tobytes()``; ``bytes`` values pass through untouched — that
  is how ``.aot`` artifact payloads ship in-band), and :func:`decode`
  rebuilds it exactly: tuples stay tuples, dict keys keep their types,
  arrays come back as numpy with the recorded dtype/shape. Every blob an
  array node references is validated against ``dtype × shape`` before
  ``frombuffer`` sees it — a disagreeing length is a :class:`ProtocolError`,
  never a numpy traceback from half-parsed attacker-controlled bytes. The
  binary header codec holds the same line: truncated nodes, bad tags,
  overrunning strings and bogus blob indices all surface as
  :class:`ProtocolError`, never a raw ``struct.error``.

* **Concurrent request/reply** — every message carries a caller-chosen
  ``id``; :class:`RpcConnection` serializes *writes* with a lock held only
  around ``sendall`` (frames are encoded outside it, so a slow encode
  never convoys other senders) and lets a single reader thread dispatch
  replies by id, so many in-flight requests share one socket.

* **A handshake** — the first exchange on a fresh connection
  (:func:`client_handshake` / :func:`server_handshake`) pins the protocol
  version and, when the listener was started with a token, authenticates
  the peer. Auth failures surface as :class:`AuthError` on both sides.

Array payloads are decoded to **numpy** (zero-copy ``frombuffer`` + reshape,
then a writable copy): the consumer is always about to hand them to jax,
which ingests numpy arrays (``bfloat16`` included, via ``ml_dtypes``'s numpy
registration) without an extra conversion step here.

The frame cap defaults to :data:`MAX_FRAME_BYTES` (8 GiB) and is
configurable via ``REPRO_RPC_MAX_FRAME`` (bytes) so deployments can bound
what a corrupt or hostile length prefix may allocate. Transport knobs —
``REPRO_RPC_TRANSPORT`` (``tcp|shm|auto``), ``REPRO_RPC_WINDOW``
(pipelining window), ``REPRO_RPC_SHM_BYTES`` / ``REPRO_RPC_SHM_MIN_BYTES``
(ring size / per-blob shm threshold) — are parsed here next to the wire
format they configure, as are the *liveness* knobs the cluster supervisor
consumes: ``REPRO_HEARTBEAT_SECS`` (lease probe period, ``<= 0`` disables)
and ``REPRO_LEASE_MISSES`` (consecutive unanswered probes before a worker
is declared dead). Heartbeat frames themselves (:data:`HEARTBEAT_OP`,
:func:`heartbeat_frame`) are the lightest message the protocol carries —
a two-key header, no blobs — and are answered on the worker's *connection*
thread, never queued behind replay work, which is exactly what lets the
supervisor tell a slow worker (acks heartbeats, results late) from a dead
one (acks nothing).

Both :meth:`RpcConnection.send` and :meth:`RpcConnection.recv` carry a
fault-injection hook (:mod:`repro.serving.faults`) behind a single
module-bool guard — zero work on the hot path unless a chaos plan is
armed.

The connection accounts real wire traffic in both directions plus codec
time (``encode_seconds`` / ``decode_seconds``) and shm data-plane bytes,
so a millisecond of per-request overhead is attributable to framing,
codec, or transport instead of vanishing into a wall-clock delta.
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
import time
from typing import Any

import numpy as np

from . import faults as _faults

_U32 = struct.Struct(">I")
_U64 = struct.Struct(">Q")
_I64 = struct.Struct(">q")
_F64 = struct.Struct(">d")
_SHM_REF = struct.Struct(">QQ")

#: Default frame cap: a frame larger than this is a protocol error, not a
#: request — refuse it instead of trying to allocate whatever a corrupt
#: length prefix asks for. The outer frame length is a u64 on the wire, so
#: the cap (not the prefix format) is what bounds allocation. Override per
#: deployment with ``REPRO_RPC_MAX_FRAME`` (see :func:`max_frame_bytes`).
MAX_FRAME_BYTES = 1 << 33

_MAX_FRAME_ENV = "REPRO_RPC_MAX_FRAME"
_TRANSPORT_ENV = "REPRO_RPC_TRANSPORT"
_WINDOW_ENV = "REPRO_RPC_WINDOW"
_SHM_BYTES_ENV = "REPRO_RPC_SHM_BYTES"
_SHM_MIN_ENV = "REPRO_RPC_SHM_MIN_BYTES"
_HEARTBEAT_ENV = "REPRO_HEARTBEAT_SECS"
_LEASE_ENV = "REPRO_LEASE_MISSES"

#: The heartbeat frame op. A probe is ``{"op": "hb", "id": N}``; the ack
#: echoes the id with ``{"op": "hb-ack", "id": N}``.
HEARTBEAT_OP = "hb"
HEARTBEAT_ACK_OP = "hb-ack"


def heartbeat_frame(mid: int) -> dict:
    """One lease probe (the smallest frame the protocol carries)."""
    return {"op": HEARTBEAT_OP, "id": mid}

#: Version pinned by the connection handshake. Bump when frames stop being
#: mutually intelligible; the handshake turns a skew into a loud
#: :class:`ProtocolError` instead of a hang or a garbage decode.
#: v2: codec-tagged frames, counted blob section with shm placements,
#: binary header codec, batch submit/result ops.
PROTOCOL_VERSION = 2

#: Frame cap applied to the *hello* frame specifically: an unauthenticated
#: peer gets 64 KiB to state its business, not the multi-GiB general cap —
#: pre-auth allocation must not be attacker-sized.
HELLO_MAX_BYTES = 1 << 16

#: Frame codec tags (the frame's first body byte — the "magic").
CODEC_JSON = 0x4A      # 'J'
CODEC_BINARY = 0x42    # 'B'

#: Blob placements inside the frame's blob section.
_PLACE_INLINE = 0
_PLACE_SHM = 1


def max_frame_bytes() -> int:
    """The effective frame cap: ``REPRO_RPC_MAX_FRAME`` or the default.

    Read per call (cheap: one env lookup) so long-lived workers honour an
    operator override without a restart dance in tests. An unparseable or
    non-positive value is a configuration error and raises
    :class:`ProtocolError` — silently falling back to 8 GiB would defeat
    the point of bounding allocation, and ProtocolError (rather than a
    bare ValueError) keeps the wire-path contract: reader loops treat it
    as a fatal connection error and fail pending work fast instead of
    dying silently.
    """
    raw = os.environ.get(_MAX_FRAME_ENV)
    if raw is None or not raw.strip():
        return MAX_FRAME_BYTES
    try:
        cap = int(raw)
    except ValueError:
        raise ProtocolError(
            f"{_MAX_FRAME_ENV}={raw!r} is not an integer byte count") from None
    if cap <= 0:
        raise ProtocolError(f"{_MAX_FRAME_ENV}={raw!r} must be positive")
    return cap


def transport_mode(explicit: str | None = None) -> str:
    """Resolve the transport selection: explicit arg, else env, else auto.

    ``tcp`` never sets up a shared-memory data plane; ``shm`` attempts it
    for every worker (falling back to tcp, counted, when a segment cannot
    attach); ``auto`` attempts it only for locally *spawned* workers —
    the one case where same-host is guaranteed rather than asserted.
    """
    raw = explicit if explicit is not None \
        else os.environ.get(_TRANSPORT_ENV, "auto")
    mode = str(raw).strip().lower()
    if mode not in ("tcp", "shm", "auto"):
        raise ValueError(
            f"transport must be tcp|shm|auto, got {raw!r} "
            f"(from {_TRANSPORT_ENV} when not passed explicitly)")
    return mode


def window_size(explicit: int | None = None) -> int:
    """Pipelining window: max batch frames in flight per connection."""
    raw = explicit if explicit is not None \
        else os.environ.get(_WINDOW_ENV, "8")
    try:
        window = int(raw)
    except (TypeError, ValueError):
        raise ValueError(
            f"{_WINDOW_ENV}={raw!r} is not an integer window") from None
    if window < 1:
        raise ValueError(f"pipelining window must be >= 1, got {window}")
    return window


def shm_ring_bytes(explicit: int | None = None) -> int:
    """Per-direction shm ring size (``REPRO_RPC_SHM_BYTES``, default 64 MiB)."""
    from .shm import DEFAULT_RING_BYTES

    raw = explicit if explicit is not None \
        else os.environ.get(_SHM_BYTES_ENV, str(DEFAULT_RING_BYTES))
    try:
        size = int(raw)
    except (TypeError, ValueError):
        raise ValueError(
            f"{_SHM_BYTES_ENV}={raw!r} is not an integer byte count") from None
    if size < 1 << 12:
        raise ValueError(f"shm ring of {size} bytes is too small to be useful")
    return size


def heartbeat_secs(explicit: float | None = None) -> float:
    """Lease probe period in seconds (``REPRO_HEARTBEAT_SECS``, default 2).

    ``<= 0`` disables the supervisor's heartbeat machinery entirely (death
    is then only noticed on socket error — the pre-supervisor behaviour).
    """
    raw = explicit if explicit is not None \
        else os.environ.get(_HEARTBEAT_ENV, "2.0")
    try:
        return float(raw)
    except (TypeError, ValueError):
        raise ValueError(
            f"{_HEARTBEAT_ENV}={raw!r} is not a number of seconds") from None


def lease_misses(explicit: int | None = None) -> int:
    """Consecutive unanswered probes before a worker is declared dead
    (``REPRO_LEASE_MISSES``, default 3). The lease a worker holds is
    ``heartbeat_secs * lease_misses`` of silence."""
    raw = explicit if explicit is not None \
        else os.environ.get(_LEASE_ENV, "3")
    try:
        misses = int(raw)
    except (TypeError, ValueError):
        raise ValueError(
            f"{_LEASE_ENV}={raw!r} is not an integer miss budget") from None
    if misses < 1:
        raise ValueError(f"lease miss budget must be >= 1, got {misses}")
    return misses


def _shm_min_bytes() -> int:
    """Per-blob threshold below which shm placement is not worth the ack."""
    try:
        return max(0, int(os.environ.get(_SHM_MIN_ENV, "1024")))
    except ValueError:
        return 1024


class ConnectionClosed(ConnectionError):
    """The peer closed the socket (EOF mid-frame or before one)."""


class ProtocolError(RuntimeError):
    """The bytes on the wire do not parse as a frame we wrote."""


class AuthError(ProtocolError):
    """The handshake failed authentication (missing or wrong token)."""


# ------------------------------------------------------- typed wire errors
#
# Remote failures cross the wire as strings ("TypeName: detail", the
# worker's str-formatting of the exception). Exception classes that must
# survive the round trip *typed* — so frontend callers can catch
# QueueFull/DeadlineExceeded/RateLimited instead of bare RuntimeError —
# register here by name; the frontend maps a detail string back through
# :func:`wire_error_class`. A registry (vs. a hard-coded tuple in
# cluster.py) keeps the set extensible without touching the mapping code.

_WIRE_ERRORS: dict[str, type] = {}


def register_wire_error(cls: type) -> type:
    """Register an exception class to be re-raised typed from wire errors."""
    _WIRE_ERRORS[cls.__name__] = cls
    return cls


def wire_error_class(detail: str) -> type | None:
    """The registered class a ``"TypeName: detail"`` string names, if any."""
    name, sep, _ = detail.partition(":")
    if sep and name in _WIRE_ERRORS:
        return _WIRE_ERRORS[name]
    return None


# ---------------------------------------------------------------- JSON codec

def _enc(obj: Any, blobs: list[bytes]) -> Any:
    if obj is None or isinstance(obj, (bool, str)):
        return {"t": "p", "v": obj}
    if isinstance(obj, (int, float)) and not isinstance(obj, np.generic):
        return {"t": "p", "v": obj}
    if isinstance(obj, (bytes, bytearray, memoryview)):
        blobs.append(bytes(obj))
        return {"t": "b", "i": len(blobs) - 1}
    if isinstance(obj, tuple):
        return {"t": "t", "v": [_enc(x, blobs) for x in obj]}
    if isinstance(obj, list):
        return {"t": "l", "v": [_enc(x, blobs) for x in obj]}
    if isinstance(obj, dict):
        return {"t": "d",
                "v": [[_enc(k, blobs), _enc(v, blobs)]
                      for k, v in obj.items()]}
    if hasattr(obj, "shape") and hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        blobs.append(arr.tobytes())
        return {"t": "a", "i": len(blobs) - 1,
                "d": str(arr.dtype), "s": list(arr.shape)}
    raise TypeError(f"rpc codec cannot encode {type(obj).__name__}: {obj!r}")


def _blob(blobs: list[bytes], idx: Any) -> bytes:
    if not isinstance(idx, int) or not 0 <= idx < len(blobs):
        raise ProtocolError(
            f"blob index {idx!r} out of range (frame carries {len(blobs)})")
    return blobs[idx]


def _make_array(dtype_name: Any, shape: Any, blob: bytes) -> np.ndarray:
    """Validated array materialization shared by both header codecs."""
    # np.dtype resolves "bfloat16" etc. because jax imports ml_dtypes,
    # which registers its extension dtypes with numpy.
    dtype = np.dtype(dtype_name)
    if not isinstance(shape, list) or not all(
            isinstance(d, int) and not isinstance(d, bool) and d >= 0
            for d in shape):
        raise ProtocolError(f"array node has invalid shape {shape!r}")
    want = dtype.itemsize
    for d in shape:
        want *= d
    if len(blob) != want:
        raise ProtocolError(
            f"array blob of {len(blob)} bytes disagrees with "
            f"dtype {dtype} x shape {tuple(shape)} ({want} bytes)")
    arr = np.frombuffer(blob, dtype=dtype)
    return arr.reshape(tuple(shape)).copy()


def _dec(node: Any, blobs: list[bytes]) -> Any:
    t = node["t"]
    if t == "p":
        return node["v"]
    if t == "b":
        return _blob(blobs, node["i"])
    if t == "t":
        return tuple(_dec(x, blobs) for x in node["v"])
    if t == "l":
        return [_dec(x, blobs) for x in node["v"]]
    if t == "d":
        return {_dec(k, blobs): _dec(v, blobs) for k, v in node["v"]}
    if t == "a":
        return _make_array(node["d"], node["s"], _blob(blobs, node["i"]))
    raise ProtocolError(f"unknown codec node type {t!r}")


# -------------------------------------------------------------- binary codec
#
# The hot-path header encoding: one tag byte per node, fixed-width scalars,
# u32-counted containers. A submit/result frame's header is a few hundred
# bytes of struct packing instead of a json.dumps over a nested node tree —
# measured at roughly an order of magnitude less encode time for typical
# batch frames, which matters because encode used to run under the write
# lock and now merely runs per frame instead of per request.

_B_NONE = 0x00
_B_FALSE = 0x01
_B_TRUE = 0x02
_B_INT = 0x03       # 8B signed big-endian
_B_FLOAT = 0x04     # 8B IEEE double
_B_STR = 0x05       # u32 len + utf-8
_B_BYTES = 0x06     # u32 blob index
_B_TUPLE = 0x07     # u32 count + nodes
_B_LIST = 0x08      # u32 count + nodes
_B_DICT = 0x09      # u32 count + (key node, value node) pairs
_B_ARRAY = 0x0A     # u32 blob idx, u8 dtype len + ascii, u8 ndim, u32*dims


def _benc(obj: Any, out: list[bytes], blobs: list[bytes]) -> None:
    if obj is None:
        out.append(b"\x00")
    elif obj is False:
        out.append(b"\x01")
    elif obj is True:
        out.append(b"\x02")
    elif isinstance(obj, int) and not isinstance(obj, np.generic):
        try:
            out.append(bytes((_B_INT,)) + _I64.pack(obj))
        except struct.error:
            raise TypeError(
                f"rpc binary codec cannot encode int {obj!r} "
                "(exceeds 64-bit range; use the json codec)") from None
    elif isinstance(obj, float) and not isinstance(obj, np.generic):
        out.append(bytes((_B_FLOAT,)) + _F64.pack(obj))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.append(struct.pack(">BI", _B_STR, len(raw)))
        out.append(raw)
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        blobs.append(bytes(obj))
        out.append(struct.pack(">BI", _B_BYTES, len(blobs) - 1))
    elif isinstance(obj, tuple):
        out.append(struct.pack(">BI", _B_TUPLE, len(obj)))
        for x in obj:
            _benc(x, out, blobs)
    elif isinstance(obj, list):
        out.append(struct.pack(">BI", _B_LIST, len(obj)))
        for x in obj:
            _benc(x, out, blobs)
    elif isinstance(obj, dict):
        out.append(struct.pack(">BI", _B_DICT, len(obj)))
        for k, v in obj.items():
            _benc(k, out, blobs)
            _benc(v, out, blobs)
    elif hasattr(obj, "shape") and hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        blobs.append(arr.tobytes())
        dt = str(arr.dtype).encode("ascii")
        out.append(struct.pack(">BI", _B_ARRAY, len(blobs) - 1))
        out.append(struct.pack(">B", len(dt)))
        out.append(dt)
        out.append(struct.pack(">B", arr.ndim))
        if arr.ndim:
            out.append(struct.pack(f">{arr.ndim}I", *arr.shape))
    else:
        raise TypeError(
            f"rpc codec cannot encode {type(obj).__name__}: {obj!r}")


def _bdec(data: bytes, pos: int, blobs: list[bytes]) -> tuple[Any, int]:
    if pos >= len(data):
        raise ProtocolError("binary header: truncated node (no tag byte)")
    tag = data[pos]
    pos += 1
    if tag == _B_NONE:
        return None, pos
    if tag == _B_FALSE:
        return False, pos
    if tag == _B_TRUE:
        return True, pos
    if tag == _B_INT:
        if pos + 8 > len(data):
            raise ProtocolError("binary header: truncated int node")
        return _I64.unpack_from(data, pos)[0], pos + 8
    if tag == _B_FLOAT:
        if pos + 8 > len(data):
            raise ProtocolError("binary header: truncated float node")
        return _F64.unpack_from(data, pos)[0], pos + 8
    if tag == _B_STR:
        if pos + 4 > len(data):
            raise ProtocolError("binary header: truncated string length")
        (n,) = _U32.unpack_from(data, pos)
        pos += 4
        if pos + n > len(data):
            raise ProtocolError(
                f"binary header: string of {n} bytes overruns the header")
        try:
            return data[pos:pos + n].decode("utf-8"), pos + n
        except UnicodeDecodeError as exc:
            raise ProtocolError(
                f"binary header: string is not valid utf-8 ({exc})") from exc
    if tag == _B_BYTES:
        if pos + 4 > len(data):
            raise ProtocolError("binary header: truncated blob index")
        (idx,) = _U32.unpack_from(data, pos)
        return _blob(blobs, idx), pos + 4
    if tag in (_B_TUPLE, _B_LIST, _B_DICT):
        if pos + 4 > len(data):
            raise ProtocolError("binary header: truncated container count")
        (n,) = _U32.unpack_from(data, pos)
        pos += 4
        # Each element costs >= 1 byte, so a count beyond the remaining
        # header is a lie — fail fast instead of looping 4 billion times.
        if n > len(data) - pos:
            raise ProtocolError(
                f"binary header: container count {n} overruns the header")
        if tag == _B_DICT:
            items = {}
            for _ in range(n):
                k, pos = _bdec(data, pos, blobs)
                v, pos = _bdec(data, pos, blobs)
                try:
                    items[k] = v
                except TypeError as exc:
                    raise ProtocolError(
                        f"binary header: unhashable dict key ({exc})") from exc
            return items, pos
        vals = []
        for _ in range(n):
            v, pos = _bdec(data, pos, blobs)
            vals.append(v)
        return (tuple(vals) if tag == _B_TUPLE else vals), pos
    if tag == _B_ARRAY:
        if pos + 5 > len(data):
            raise ProtocolError("binary header: truncated array node")
        (idx,) = _U32.unpack_from(data, pos)
        dt_len = data[pos + 4]
        pos += 5
        if pos + dt_len + 1 > len(data):
            raise ProtocolError("binary header: truncated array dtype")
        try:
            dtype_name = data[pos:pos + dt_len].decode("ascii")
        except UnicodeDecodeError as exc:
            raise ProtocolError(
                f"binary header: array dtype is not ascii ({exc})") from exc
        pos += dt_len
        ndim = data[pos]
        pos += 1
        if pos + 4 * ndim > len(data):
            raise ProtocolError("binary header: truncated array dims")
        shape = list(struct.unpack_from(f">{ndim}I", data, pos)) if ndim \
            else []
        pos += 4 * ndim
        try:
            return _make_array(dtype_name, shape, _blob(blobs, idx)), pos
        except ProtocolError:
            raise
        except (TypeError, ValueError) as exc:
            raise ProtocolError(
                f"malformed codec node ({type(exc).__name__}: {exc})") from exc
    raise ProtocolError(f"unknown binary codec tag 0x{tag:02x}")


# ------------------------------------------------------------ frame assembly

def _encode_frame(obj: Any, codec: str = "json", ring=None,
                  shm_min: int = 0) -> tuple[bytes, int]:
    """Build one frame body; returns ``(body, shm_payload_bytes)``.

    ``ring`` (the connection's send ring) routes blobs of at least
    ``shm_min`` bytes through the shared-memory data plane; everything else
    — and anything exceeding the ring's contiguity bound — is inlined.
    Ring allocation order equals frame order because each ring has exactly
    one producing thread (see :mod:`repro.serving.shm`).
    """
    blobs: list[bytes] = []
    if codec == "json":
        header = json.dumps(_enc(obj, blobs)).encode("utf-8")
        tag = CODEC_JSON
    elif codec == "binary":
        hparts: list[bytes] = []
        _benc(obj, hparts, blobs)
        header = b"".join(hparts)
        tag = CODEC_BINARY
    else:
        raise ValueError(f"unknown frame codec {codec!r}")
    parts = [bytes((tag,)), _U32.pack(len(header)), header,
             _U32.pack(len(blobs))]
    shm_bytes = 0
    for b in blobs:
        if ring is not None and shm_min <= len(b) <= ring.max_blob:
            pos = ring.alloc(len(b))
            ring.write(pos, b)
            parts.append(bytes((_PLACE_SHM,)))
            parts.append(_SHM_REF.pack(pos, len(b)))
            shm_bytes += len(b)
        else:
            parts.append(bytes((_PLACE_INLINE,)))
            parts.append(_U64.pack(len(b)))
            parts.append(b)
    return b"".join(parts), shm_bytes


def _decode_frame(data: bytes, ring=None) -> tuple[Any, int | None, int]:
    """Parse one frame body; returns ``(obj, shm_ack_end, shm_bytes)``.

    ``shm_ack_end`` is the highest absolute ring position this frame
    consumed (``None`` for a pure-TCP frame) — the receiver acks it back
    so the sender can reuse the span. Every malformed shape a peer could
    produce raises :class:`ProtocolError`.
    """
    if len(data) < 1 + _U32.size:
        raise ProtocolError("truncated frame: missing header length")
    tag = data[0]
    if tag not in (CODEC_JSON, CODEC_BINARY):
        raise ProtocolError(
            f"unknown frame codec tag 0x{tag:02x} (bad magic byte)")
    (hlen,) = _U32.unpack_from(data, 1)
    off = 1 + _U32.size
    if off + hlen > len(data):
        raise ProtocolError("truncated frame: header overruns body")
    header_bytes = data[off:off + hlen]
    off += hlen
    if off + _U32.size > len(data):
        raise ProtocolError("truncated frame: missing blob count")
    (nblobs,) = _U32.unpack_from(data, off)
    off += _U32.size
    blobs: list[bytes] = []
    ack_end: int | None = None
    shm_bytes = 0
    for _ in range(nblobs):
        if off + 1 > len(data):
            raise ProtocolError("truncated frame: missing blob placement")
        placement = data[off]
        off += 1
        if placement == _PLACE_INLINE:
            if off + _U64.size > len(data):
                raise ProtocolError("truncated frame: blob length")
            (blen,) = _U64.unpack_from(data, off)
            off += _U64.size
            if off + blen > len(data):
                raise ProtocolError("truncated frame: blob overruns body")
            blobs.append(data[off:off + blen])
            off += blen
        elif placement == _PLACE_SHM:
            if off + _SHM_REF.size > len(data):
                raise ProtocolError("truncated frame: shm blob reference")
            pos, blen = _SHM_REF.unpack_from(data, off)
            off += _SHM_REF.size
            if ring is None:
                raise ProtocolError(
                    "frame references a shm blob but this connection has "
                    "no ring attached")
            blobs.append(ring.read(pos, blen))
            shm_bytes += blen
            end = pos + blen
            ack_end = end if ack_end is None else max(ack_end, end)
        else:
            raise ProtocolError(f"unknown blob placement {placement!r}")
    if off != len(data):
        raise ProtocolError(
            f"frame has {len(data) - off} trailing bytes after the blob "
            "section")
    if tag == CODEC_JSON:
        try:
            header = json.loads(header_bytes.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as exc:
            raise ProtocolError(
                f"frame header is not valid JSON: {exc}") from exc
        try:
            return _dec(header, blobs), ack_end, shm_bytes
        except ProtocolError:
            raise
        except (KeyError, TypeError, ValueError, RecursionError) as exc:
            raise ProtocolError(
                f"malformed codec node ({type(exc).__name__}: {exc})") from exc
    try:
        obj, end_pos = _bdec(header_bytes, 0, blobs)
    except ProtocolError:
        raise
    except (struct.error, IndexError, TypeError, ValueError,
            RecursionError) as exc:
        raise ProtocolError(
            f"malformed codec node ({type(exc).__name__}: {exc})") from exc
    if end_pos != len(header_bytes):
        raise ProtocolError(
            f"binary header has {len(header_bytes) - end_pos} trailing bytes")
    return obj, ack_end, shm_bytes


def encode(obj: Any, codec: str = "json") -> bytes:
    """Serialize ``obj`` to a frame body (all blobs inlined — no ring)."""
    return _encode_frame(obj, codec=codec)[0]


def decode(data: bytes) -> Any:
    """Inverse of :func:`encode`.

    Anything a peer could have actually put on the wire fails as
    :class:`ProtocolError` — malformed JSON, missing node keys, bogus
    dtypes, truncated binary nodes — never as a raw ``KeyError`` /
    ``struct.error`` from half-parsed bytes (the reader loops treat
    ``ProtocolError`` as a fatal connection error; an unexpected exception
    type would kill them silently). Frames carrying shm blob references
    require a connection with an attached ring and are rejected here.
    """
    return _decode_frame(data)[0]


# ------------------------------------------------------------------- framing

def _recv_exact(sock: socket.socket, n: int,
                deadline: float | None = None) -> bytes:
    """Read exactly ``n`` bytes; ``deadline`` (``time.monotonic`` value) is
    an ABSOLUTE bound across all chunks — a peer trickling one byte per
    idle-timeout window cannot stretch it (each chunk's socket timeout is
    the *remaining* budget)."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise ProtocolError(f"deadline exceeded after {got}/{n} bytes")
            sock.settimeout(remaining)
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except socket.timeout:
            raise ProtocolError(
                f"deadline exceeded after {got}/{n} bytes") from None
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_msg(sock: socket.socket, obj: Any) -> int:
    """Encode + frame + send one message; returns bytes written."""
    body = encode(obj)
    cap = max_frame_bytes()
    if len(body) > cap:
        raise ProtocolError(
            f"frame of {len(body)} bytes exceeds the {cap}-byte cap "
            f"(raise {_MAX_FRAME_ENV} if this payload is legitimate)")
    sock.sendall(_U64.pack(len(body)) + body)
    return _U64.size + len(body)


def recv_msg_sized(sock: socket.socket, cap: int | None = None,
                   deadline: float | None = None) -> tuple[Any, int]:
    """Receive one framed message; returns ``(obj, wire_bytes_consumed)``.

    The byte count is the real on-wire size (length prefix included), which
    is what :class:`RpcConnection` accounts — blocks; raises
    :class:`ConnectionClosed` on EOF and :class:`ProtocolError` on a frame
    announcing more than ``cap`` (default :func:`max_frame_bytes`).
    ``deadline`` bounds the whole receive absolutely (the pre-auth
    handshake path passes both).
    """
    (n,) = _U64.unpack(_recv_exact(sock, _U64.size, deadline))
    if cap is None:
        cap = max_frame_bytes()
    if n > cap:
        raise ProtocolError(
            f"peer announced a {n}-byte frame exceeding the {cap}-byte cap "
            f"({_MAX_FRAME_ENV}); refusing")
    return decode(_recv_exact(sock, n, deadline)), _U64.size + n


def recv_msg(sock: socket.socket) -> Any:
    """Receive + decode one framed message (blocks; raises ConnectionClosed on EOF)."""
    return recv_msg_sized(sock)[0]


class RpcConnection:
    """One socket shared by many in-flight requests.

    Writes are serialized under a lock held only around the ``sendall``
    (frames must not interleave, but encoding happens OUTSIDE the lock —
    a large frame's codec work never convoys other senders); reads are
    left to exactly one owner — either a caller that knows it is the only
    reader (:meth:`request`, the worker-side sync pattern) or a dedicated
    reader thread that matches replies to requests by ``id`` (the frontend
    pattern — see ``cluster._WorkerHandle``). Mixing both on one
    connection is a caller bug.

    The connection accounts real wire traffic in both directions:
    ``bytes_sent`` / ``bytes_received`` are on-wire byte totals (length
    prefixes included; shm data-plane bytes are tallied separately in
    ``shm_bytes_sent`` / ``shm_bytes_received``), ``messages_sent`` /
    ``messages_received`` count frames, and ``encode_seconds`` /
    ``decode_seconds`` accumulate codec time — the per-worker wire totals
    ``ClusterFrontend.stats()`` surfaces.

    When a shared-memory data plane is attached (:meth:`attach_rings`),
    the connection handles the transport's bookkeeping frames internally:
    :meth:`recv` acks consumed ring spans back to the peer and applies the
    peer's acks to the send ring without ever surfacing either to the
    caller.
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._wlock = threading.Lock()
        self._bytes_sent = 0
        self._bytes_received = 0
        self._messages_sent = 0
        self._messages_received = 0
        self._encode_seconds = 0.0
        self._decode_seconds = 0.0
        self._shm_bytes_sent = 0
        self._shm_bytes_received = 0
        self._send_ring = None
        self._recv_ring = None
        self._shm_min = _shm_min_bytes()

    def attach_rings(self, send_ring, recv_ring) -> None:
        """Arm the shared-memory data plane (both directions)."""
        self._send_ring = send_ring
        self._recv_ring = recv_ring

    @property
    def transport(self) -> str:
        return "shm" if self._send_ring is not None else "tcp"

    def send(self, obj: Any, codec: str = "json") -> None:
        sends = 1
        if _faults.ENABLED:
            # Chaos hook: the plan may kill this process, delay the send,
            # drop the frame on the floor (the peer never sees it — the
            # wire analogue of a lost packet burst), or duplicate it.
            action = _faults.on_point("send", _faults.frame_op(obj))
            if action == "drop":
                return
            if action == "dup":
                sends = 2
        t0 = time.perf_counter()
        ring = self._send_ring if codec == "binary" else None
        body, shm_bytes = _encode_frame(obj, codec=codec, ring=ring,
                                        shm_min=self._shm_min)
        cap = max_frame_bytes()
        if len(body) > cap:
            raise ProtocolError(
                f"frame of {len(body)} bytes exceeds the {cap}-byte cap "
                f"(raise {_MAX_FRAME_ENV} if this payload is legitimate)")
        enc_s = time.perf_counter() - t0
        payload = _U64.pack(len(body)) + body
        with self._wlock:
            for _ in range(sends):
                self.sock.sendall(payload)
                self._bytes_sent += len(payload)
                self._messages_sent += 1
            self._encode_seconds += enc_s
            self._shm_bytes_sent += shm_bytes

    def recv(self, cap: int | None = None,
             deadline: float | None = None) -> Any:
        while True:
            (n,) = _U64.unpack(_recv_exact(self.sock, _U64.size, deadline))
            eff_cap = max_frame_bytes() if cap is None else cap
            if n > eff_cap:
                raise ProtocolError(
                    f"peer announced a {n}-byte frame exceeding the "
                    f"{eff_cap}-byte cap ({_MAX_FRAME_ENV}); refusing")
            data = _recv_exact(self.sock, n, deadline)
            t0 = time.perf_counter()
            msg, ack_end, shm_bytes = _decode_frame(data,
                                                    ring=self._recv_ring)
            self._decode_seconds += time.perf_counter() - t0
            self._bytes_received += _U64.size + n
            self._messages_received += 1
            self._shm_bytes_received += shm_bytes
            if ack_end is not None:
                # The blobs were copied out of the ring during decode;
                # release the span so the peer's next alloc can reuse it.
                try:
                    self.send({"op": "shm-ack", "pos": ack_end})
                except OSError:
                    pass        # connection is dying; the loop will notice
            if isinstance(msg, dict) and msg.get("op") == "shm-ack":
                ring, pos = self._send_ring, msg.get("pos")
                if ring is not None and isinstance(pos, int) and pos >= 0:
                    ring.ack(pos)
                continue        # transport bookkeeping, not a message
            if _faults.ENABLED:
                # Chaos hook (after decode, so a "drop" models a frame that
                # made it across the wire but was lost before the app saw
                # it — e.g. a result the frontend never resolves).
                action = _faults.on_point("recv", _faults.frame_op(msg))
                if action == "drop":
                    continue
            return msg

    def request(self, obj: Any) -> Any:
        """Sync send-then-recv for single-reader callers (no id matching)."""
        self.send(obj)
        return self.recv()

    @property
    def bytes_sent(self) -> int:
        return self._bytes_sent

    @property
    def bytes_received(self) -> int:
        return self._bytes_received

    @property
    def messages_sent(self) -> int:
        return self._messages_sent

    @property
    def messages_received(self) -> int:
        return self._messages_received

    def wire_stats(self) -> dict:
        """Snapshot of this connection's traffic totals (both directions)."""
        return {"bytes_sent": self._bytes_sent,
                "bytes_received": self._bytes_received,
                "messages_sent": self._messages_sent,
                "messages_received": self._messages_received,
                "encode_seconds": self._encode_seconds,
                "decode_seconds": self._decode_seconds,
                "shm_bytes_sent": self._shm_bytes_sent,
                "shm_bytes_received": self._shm_bytes_received,
                "transport": self.transport}

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()
        # Closing the rings wakes any sender blocked in alloc() with a
        # ProtocolError, so a dead connection can never strand a
        # dispatcher thread waiting for an ack that will not come.
        for ring in (self._send_ring, self._recv_ring):
            if ring is not None:
                ring.close()
        self._send_ring = self._recv_ring = None


# ----------------------------------------------------------------- handshake

def client_handshake(conn: RpcConnection, token: str | None = None,
                     ) -> dict:
    """Open a fresh connection: send ``hello``, validate the ``hello-ack``.

    Must be the FIRST exchange on the connection (before any reader thread
    starts). Returns the ack — which carries whatever the listener chose to
    advertise (worker pid, port, device-topology fingerprint) — or raises
    :class:`AuthError` / :class:`ProtocolError` with the server's reason.
    """
    conn.send({"op": "hello", "proto": PROTOCOL_VERSION, "token": token})
    reply = conn.recv()
    if not isinstance(reply, dict):
        raise ProtocolError(f"handshake reply is not a message: {reply!r}")
    if reply.get("op") == "error":
        detail = reply.get("error", "handshake rejected")
        if reply.get("code") == "auth":
            raise AuthError(detail)
        raise ProtocolError(detail)
    if reply.get("op") != "hello-ack" or reply.get("proto") != PROTOCOL_VERSION:
        raise ProtocolError(f"unexpected handshake reply: {reply!r}")
    return reply


def server_handshake(conn: RpcConnection, token: str | None = None,
                     info: dict | None = None,
                     timeout: float | None = None) -> dict:
    """Validate the first frame of an accepted connection; ack or reject.

    ``token=None`` disables auth (the local-spawn case, where the frontend
    generated the token AND the worker — still checked for protocol
    version). On any failure the peer gets an ``error`` frame (``code:
    "auth"`` for token mismatches so the client can raise the right type)
    before this side raises; the caller should then drop the connection.
    ``info`` is advertised in the ack (pid, port, topology fingerprint).

    The pre-auth surface is hardened: the hello frame is capped at
    :data:`HELLO_MAX_BYTES` (an unauthenticated peer never gets a
    multi-GiB allocation), ``timeout`` is an ABSOLUTE deadline across the
    whole receive (a one-byte-per-idle-window trickler cannot stretch
    it), and the token comparison is timing-safe.
    """
    import hmac

    deadline = (time.monotonic() + timeout) if timeout is not None else None
    msg = conn.recv(cap=HELLO_MAX_BYTES, deadline=deadline)

    def _reject(code: str, detail: str) -> None:
        try:
            conn.send({"op": "error", "code": code, "error": detail})
        except OSError:
            pass
        raise (AuthError if code == "auth" else ProtocolError)(detail)

    if not isinstance(msg, dict) or msg.get("op") != "hello":
        _reject("proto", "expected a hello frame to open the connection")
    if msg.get("proto") != PROTOCOL_VERSION:
        _reject("proto", f"protocol version mismatch: peer speaks "
                f"{msg.get('proto')!r}, this side {PROTOCOL_VERSION}")
    if token is not None:
        peer = msg.get("token")
        if not isinstance(peer, str) or not hmac.compare_digest(
                peer.encode("utf-8"), token.encode("utf-8")):
            _reject("auth", "bad or missing auth token")
    conn.send({"op": "hello-ack", "proto": PROTOCOL_VERSION,
               **(info or {})})
    return msg


def connect(host: str, port: int, timeout: float | None = None
            ) -> RpcConnection:
    """TCP-connect to a worker's RPC port (``TCP_NODELAY`` — frames are small)."""
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return RpcConnection(sock)


def listener(host: str = "127.0.0.1", port: int = 0) -> socket.socket:
    """A listening socket; ``port=0`` lets the OS pick (read ``getsockname``)."""
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind((host, port))
    sock.listen(16)
    return sock
