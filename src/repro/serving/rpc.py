"""Length-prefixed socket RPC: the cluster tier's wire layer (stdlib only).

The distributed frontend (:mod:`repro.serving.cluster`) needs exactly three
things from a wire protocol, and nothing a heavyweight RPC stack would add:

* **Framing** — one message per frame, length-prefixed (``struct``
  big-endian), so a reader never has to guess where a message ends. A
  frame is::

      [8B total] [4B header len] [header JSON utf-8]
                 [8B blob0 len] [blob0] [8B blob1 len] [blob1] ...

* **A pytree/tensor codec** — requests and replies carry buffer dicts whose
  leaves are jax/numpy arrays (including ``bfloat16`` and 0-d scalars),
  nested arbitrarily in dicts/lists/tuples. :func:`encode` walks the tree
  into a JSON-able skeleton plus a list of raw binary blobs (array bytes out
  of ``ndarray.tobytes()``; ``bytes`` values pass through untouched — that
  is how ``.aot`` artifact payloads ship in-band), and :func:`decode`
  rebuilds it exactly: tuples stay tuples, dict keys keep their types,
  arrays come back as numpy with the recorded dtype/shape.

* **Concurrent request/reply** — every message carries a caller-chosen
  ``id``; :class:`RpcConnection` serializes *writes* with a lock and lets a
  single reader thread dispatch replies by id, so many in-flight requests
  share one socket (which is what lets a worker's ``RegionServer`` coalesce
  requests that arrived over the same connection).

Array payloads are decoded to **numpy** (zero-copy ``frombuffer`` + reshape,
then a writable copy): the consumer is always about to hand them to jax,
which ingests numpy arrays (``bfloat16`` included, via ``ml_dtypes``'s numpy
registration) without an extra conversion step here.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Any

import numpy as np

_U32 = struct.Struct(">I")
_U64 = struct.Struct(">Q")

#: A frame larger than this is a protocol error, not a request — refuse it
#: instead of trying to allocate whatever a corrupt length prefix asks for.
#: The outer frame length is a u64 on the wire, so the cap (not the prefix
#: format) is what bounds allocation.
MAX_FRAME_BYTES = 1 << 33


class ConnectionClosed(ConnectionError):
    """The peer closed the socket (EOF mid-frame or before one)."""


class ProtocolError(RuntimeError):
    """The bytes on the wire do not parse as a frame we wrote."""


# --------------------------------------------------------------------- codec

def _enc(obj: Any, blobs: list[bytes]) -> Any:
    if obj is None or isinstance(obj, (bool, str)):
        return {"t": "p", "v": obj}
    if isinstance(obj, (int, float)) and not isinstance(obj, np.generic):
        return {"t": "p", "v": obj}
    if isinstance(obj, (bytes, bytearray, memoryview)):
        blobs.append(bytes(obj))
        return {"t": "b", "i": len(blobs) - 1}
    if isinstance(obj, tuple):
        return {"t": "t", "v": [_enc(x, blobs) for x in obj]}
    if isinstance(obj, list):
        return {"t": "l", "v": [_enc(x, blobs) for x in obj]}
    if isinstance(obj, dict):
        return {"t": "d",
                "v": [[_enc(k, blobs), _enc(v, blobs)]
                      for k, v in obj.items()]}
    if hasattr(obj, "shape") and hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        blobs.append(arr.tobytes())
        return {"t": "a", "i": len(blobs) - 1,
                "d": str(arr.dtype), "s": list(arr.shape)}
    raise TypeError(f"rpc codec cannot encode {type(obj).__name__}: {obj!r}")


def _dec(node: Any, blobs: list[bytes]) -> Any:
    t = node["t"]
    if t == "p":
        return node["v"]
    if t == "b":
        return blobs[node["i"]]
    if t == "t":
        return tuple(_dec(x, blobs) for x in node["v"])
    if t == "l":
        return [_dec(x, blobs) for x in node["v"]]
    if t == "d":
        return {_dec(k, blobs): _dec(v, blobs) for k, v in node["v"]}
    if t == "a":
        # np.dtype resolves "bfloat16" etc. because jax imports ml_dtypes,
        # which registers its extension dtypes with numpy.
        dtype = np.dtype(node["d"])
        arr = np.frombuffer(blobs[node["i"]], dtype=dtype)
        return arr.reshape(tuple(node["s"])).copy()
    raise ProtocolError(f"unknown codec node type {t!r}")


def encode(obj: Any) -> bytes:
    """Serialize ``obj`` (JSON-able skeleton + binary tensor blobs) to a frame body."""
    blobs: list[bytes] = []
    header = json.dumps(_enc(obj, blobs)).encode("utf-8")
    parts = [_U32.pack(len(header)), header]
    for b in blobs:
        parts.append(_U64.pack(len(b)))
        parts.append(b)
    return b"".join(parts)


def decode(data: bytes) -> Any:
    """Inverse of :func:`encode`."""
    if len(data) < _U32.size:
        raise ProtocolError("truncated frame: missing header length")
    (hlen,) = _U32.unpack_from(data, 0)
    off = _U32.size
    if off + hlen > len(data):
        raise ProtocolError("truncated frame: header overruns body")
    header = json.loads(data[off:off + hlen].decode("utf-8"))
    off += hlen
    blobs: list[bytes] = []
    while off < len(data):
        if off + _U64.size > len(data):
            raise ProtocolError("truncated frame: blob length")
        (blen,) = _U64.unpack_from(data, off)
        off += _U64.size
        if off + blen > len(data):
            raise ProtocolError("truncated frame: blob overruns body")
        blobs.append(data[off:off + blen])
        off += blen
    return _dec(header, blobs)


# ------------------------------------------------------------------- framing

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_msg(sock: socket.socket, obj: Any) -> int:
    """Encode + frame + send one message; returns bytes written."""
    body = encode(obj)
    if len(body) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame of {len(body)} bytes exceeds cap")
    sock.sendall(_U64.pack(len(body)) + body)
    return _U64.size + len(body)


def recv_msg(sock: socket.socket) -> Any:
    """Receive + decode one framed message (blocks; raises ConnectionClosed on EOF)."""
    (n,) = _U64.unpack(_recv_exact(sock, _U64.size))
    if n > MAX_FRAME_BYTES:
        raise ProtocolError(f"peer announced a {n}-byte frame; refusing")
    return decode(_recv_exact(sock, n))


class RpcConnection:
    """One socket shared by many in-flight requests.

    Writes are serialized under a lock (frames must not interleave); reads
    are left to exactly one owner — either a caller that knows it is the
    only reader (:meth:`request`, the worker-side sync pattern) or a
    dedicated reader thread that matches replies to requests by ``id`` (the
    frontend pattern — see ``cluster._WorkerHandle``). Mixing both on one
    connection is a caller bug.
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._wlock = threading.Lock()
        self._bytes_sent = 0
        self._bytes_received = 0

    def send(self, obj: Any) -> None:
        with self._wlock:
            self._bytes_sent += send_msg(self.sock, obj)

    def recv(self) -> Any:
        msg = recv_msg(self.sock)
        self._bytes_received += 1  # message count; sizes tracked on send side
        return msg

    def request(self, obj: Any) -> Any:
        """Sync send-then-recv for single-reader callers (no id matching)."""
        self.send(obj)
        return self.recv()

    @property
    def bytes_sent(self) -> int:
        return self._bytes_sent

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


def connect(host: str, port: int, timeout: float | None = None
            ) -> RpcConnection:
    """TCP-connect to a worker's RPC port (``TCP_NODELAY`` — frames are small)."""
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return RpcConnection(sock)


def listener(host: str = "127.0.0.1", port: int = 0) -> socket.socket:
    """A listening socket; ``port=0`` lets the OS pick (read ``getsockname``)."""
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind((host, port))
    sock.listen(16)
    return sock
