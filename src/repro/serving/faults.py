"""Deterministic fault injection for the cluster tier (the chaos layer).

The self-healing machinery in :mod:`repro.serving.cluster` — heartbeat
leases, supervised respawn, deadline shedding, retry/backoff — is only
trustworthy if it can be *exercised*: a recovery path that never runs in CI
is a recovery path that does not work. This module is the injection point:
a seeded, deterministic :class:`FaultPlan` that the wire layer
(:mod:`repro.serving.rpc`), the shared-memory data plane
(:mod:`repro.serving.shm`), the spawners (:mod:`repro.serving.spawner`)
and the frontend's artifact shipping consult at well-defined *points*:

==============  ============================================================
point           where it fires
==============  ============================================================
``send``        :meth:`rpc.RpcConnection.send`, once per frame, after encode
``recv``        :meth:`rpc.RpcConnection.recv`, once per decoded frame
``ring_ack``    :meth:`shm.ShmRing.ack` — a peer ack about to be applied
``spawn``       :meth:`spawner.LocalSpawner.launch` — a worker process start
``artifact``    :meth:`cluster.ClusterFrontend._register_on` — artifact
                bytes about to ship (``corrupt`` flips seeded bytes)
==============  ============================================================

A *rule* is a dict::

    {"role": "worker" | "frontend" | "any",   # which process kind
     "point": "send" | "recv" | "ring_ack" | "spawn" | "artifact",
     "op":    "submit_batch" | "result_batch" | ... | None,  # frame op
     "after": N,      # skip the first N matching events (default 0)
     "count": K,      # fire at most K times, -1 = unlimited (default 1)
     "action": "kill" | "drop" | "delay" | "dup" | "fail" | "corrupt",
     "secs":  0.25}   # for "delay"

Actions: ``kill`` hard-exits the process (``os._exit``, the closest
in-process stand-in for SIGKILL — no atexit, no flushes, sockets break
mid-conversation); ``drop`` suppresses the event (frame not sent / reply
discarded / ack not applied); ``delay`` sleeps ``secs`` first, then lets
the event proceed; ``dup`` performs a send twice; ``fail`` raises
:class:`InjectedFault` (the ``spawn`` point uses it to simulate a start
failure, exercising respawn backoff); ``corrupt`` rewrites seeded byte
positions of an artifact payload.

**Determinism.** Nothing here consults wall-clock randomness: rules fire
on exact per-``(role, point, op)`` event counters, and ``corrupt`` picks
byte positions from a ``random.Random(seed)`` owned by the plan. The same
plan against the same request schedule injects the same faults — which is
what lets ``benchmarks/chaos.py`` assert exact recovery behaviour in CI.

**Zero overhead when disabled.** Every hook site is guarded by the
module-level :data:`ENABLED` flag — one attribute load per frame when no
plan is installed, nothing else. The ``BENCH_cluster.json`` rpc-overhead
gate runs with faults disabled and must not move.

**Injection.** Ctor-style: build a :class:`FaultPlan` and
:func:`install` it (the frontend process). Env-style: set
``REPRO_FAULT_PLAN`` to the plan's JSON (``{"seed": S, "rules": [...]}``)
before processes start — spawned workers inherit the environment, so one
env var arms a whole fleet; :class:`~repro.serving.cluster.WorkerNode`
and :class:`~repro.serving.cluster.ClusterFrontend` call
:func:`init_from_env` with their role at construction.
"""
from __future__ import annotations

import json
import os
import random
import threading
import time
from typing import Any

FAULT_PLAN_ENV = "REPRO_FAULT_PLAN"

#: The zero-overhead guard. Hook sites check this module attribute before
#: doing ANY other fault work; it is True iff a plan is installed.
ENABLED = False

_POINTS = ("send", "recv", "ring_ack", "spawn", "artifact")
_ACTIONS = ("kill", "drop", "delay", "dup", "fail", "corrupt")
_ROLES = ("worker", "frontend", "any")


class InjectedFault(RuntimeError):
    """An injected failure (the ``fail`` action) — never a real error."""


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Thread-safe: hook sites are called from reader/dispatcher/conn threads
    concurrently; counters and rule budgets live under one lock (the plan
    is only ever consulted when faults are deliberately enabled, so the
    lock is not on any production path).
    """

    def __init__(self, rules: list[dict] | tuple = (), seed: int = 0):
        self.seed = int(seed)
        self.rules = [self._validate(dict(r)) for r in rules]
        self._rng = random.Random(self.seed)
        self._counts: dict[tuple, int] = {}
        self._fired: list[dict] = []
        self._lock = threading.Lock()

    @staticmethod
    def _validate(rule: dict) -> dict:
        point = rule.get("point")
        if point not in _POINTS:
            raise ValueError(f"fault rule point must be one of {_POINTS}, "
                             f"got {point!r}")
        action = rule.get("action")
        if action not in _ACTIONS:
            raise ValueError(f"fault rule action must be one of {_ACTIONS}, "
                             f"got {action!r}")
        role = rule.setdefault("role", "any")
        if role not in _ROLES:
            raise ValueError(f"fault rule role must be one of {_ROLES}, "
                             f"got {role!r}")
        rule.setdefault("op", None)
        rule["after"] = int(rule.get("after", 0))
        rule["count"] = int(rule.get("count", 1))
        rule["secs"] = float(rule.get("secs", 0.0))
        rule["_left"] = rule["count"]
        return rule

    # ------------------------------------------------------------- matching
    def consult(self, role: str, point: str, op: str | None) -> dict | None:
        """The action (if any) for one event; advances counters/budgets.

        Event counters key on ``(point, op)`` — every event at a point
        bumps both its op-specific and its op-agnostic counter, so a rule
        can target "the 3rd submit_batch frame" or "the 10th frame of any
        kind" with the same schema.
        """
        with self._lock:
            self._counts[(point, op)] = self._counts.get((point, op), 0) + 1
            if op is not None:      # op-agnostic counter sees every event
                self._counts[(point, None)] = \
                    self._counts.get((point, None), 0) + 1
            for rule in self.rules:
                if rule["_left"] == 0:
                    continue
                if rule["role"] != "any" and rule["role"] != role:
                    continue
                if rule["point"] != point:
                    continue
                if rule["op"] is not None and rule["op"] != op:
                    continue
                seen = self._counts.get((point, rule["op"]), 0)
                if seen <= rule["after"]:
                    continue
                if rule["_left"] > 0:
                    rule["_left"] -= 1
                self._fired.append({"role": role, "point": point, "op": op,
                                    "action": rule["action"],
                                    "event": seen})
                return rule
            return None

    def corrupt_bytes(self, data: bytes, n_flips: int = 16) -> bytes:
        """Deterministically flip ``n_flips`` seeded byte positions."""
        if not data:
            return data
        buf = bytearray(data)
        with self._lock:
            for _ in range(min(n_flips, len(buf))):
                i = self._rng.randrange(len(buf))
                buf[i] ^= 0xFF
        return bytes(buf)

    # ------------------------------------------------------------ reporting
    def fired(self) -> list[dict]:
        """Every rule firing so far (role/point/op/action/event index)."""
        with self._lock:
            return list(self._fired)

    def exhausted(self) -> bool:
        """True when every bounded rule has spent its budget."""
        with self._lock:
            return all(r["_left"] == 0 for r in self.rules
                       if r["count"] >= 0)

    def to_json(self) -> str:
        """The env-shippable form (counters/budgets not included)."""
        rules = [{k: v for k, v in r.items() if k != "_left"}
                 for r in self.rules]
        return json.dumps({"seed": self.seed, "rules": rules})

    @classmethod
    def from_json(cls, raw: str) -> "FaultPlan":
        try:
            spec = json.loads(raw)
        except ValueError as exc:
            raise ValueError(
                f"{FAULT_PLAN_ENV} is not valid JSON: {exc}") from exc
        if not isinstance(spec, dict) or not isinstance(
                spec.get("rules", []), list):
            raise ValueError(
                f'{FAULT_PLAN_ENV} must be {{"seed": S, "rules": [...]}}')
        return cls(rules=spec.get("rules", ()), seed=spec.get("seed", 0))


# ---------------------------------------------------------------------------
# Process-global installation
# ---------------------------------------------------------------------------

_plan: FaultPlan | None = None
_role: str = "any"


def install(plan: FaultPlan | None, role: str | None = None) -> None:
    """Arm ``plan`` process-globally (``None`` disarms — see :func:`clear`)."""
    global _plan, ENABLED, _role
    if role is not None:
        set_role(role)
    _plan = plan
    ENABLED = plan is not None


def clear() -> None:
    """Disarm fault injection; hook sites go back to the one-bool guard."""
    install(None)


def active() -> FaultPlan | None:
    return _plan


def set_role(role: str) -> None:
    """Declare which kind of process this is (rules filter on it)."""
    global _role
    if role not in _ROLES:
        raise ValueError(f"role must be one of {_ROLES}, got {role!r}")
    _role = role


def init_from_env(role: str) -> None:
    """Arm the plan from ``REPRO_FAULT_PLAN`` if set (worker bootstrap path).

    Called by ``WorkerNode`` / ``ClusterFrontend`` construction so a plan
    exported before the fleet starts arms every process, each knowing its
    role. A process that already has an installed plan keeps it (an
    explicit :func:`install` wins over the inherited env).
    """
    set_role(role)
    if _plan is not None:
        return
    raw = os.environ.get(FAULT_PLAN_ENV)
    if raw and raw.strip():
        install(FaultPlan.from_json(raw))


# ---------------------------------------------------------------------------
# Hook-site helpers (call ONLY under `if faults.ENABLED:`)
# ---------------------------------------------------------------------------

def on_point(point: str, op: str | None = None) -> str | None:
    """Consult the plan at a hook site; applies kill/delay here.

    Returns the remaining action for the caller to apply (``"drop"`` /
    ``"dup"``), raises :class:`InjectedFault` for ``"fail"``, or returns
    ``None`` (no fault, or a delay that has already been slept).
    """
    plan = _plan
    if plan is None:
        return None
    rule = plan.consult(_role, point, op)
    if rule is None:
        return None
    action = rule["action"]
    if action == "kill":
        os._exit(17)                    # crash, not a clean shutdown
    if action == "delay":
        time.sleep(rule["secs"])
        return None
    if action == "fail":
        raise InjectedFault(
            f"injected {point} failure (role={_role}, op={op})")
    return action                       # "drop" | "dup" | "corrupt"


def corrupt_artifact(data: bytes | None) -> bytes | None:
    """The ``artifact`` hook: corrupt shipped bytes when a rule says so."""
    plan = _plan
    if plan is None or data is None:
        return data
    if on_point("artifact") == "corrupt":
        return plan.corrupt_bytes(data)
    return data


def frame_op(obj: Any) -> str | None:
    """Best-effort op tag of a frame object (for rule matching)."""
    if isinstance(obj, dict):
        op = obj.get("op")
        if isinstance(op, str):
            return op
    return None
